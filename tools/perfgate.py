#!/usr/bin/env python3
"""Perf regression gate: diff a fresh benchmark run against the committed
``BENCH_*.json`` baseline, with per-suite metrics and tolerances.

  PYTHONPATH=src python tools/perfgate.py --suite serve \\
      --baseline BENCH_serve.json --fresh /tmp/BENCH_serve.fresh.json
  PYTHONPATH=src python tools/perfgate.py --self-test

Each suite names the metrics worth gating (the headline numbers the perf
trajectory tracks, not every row) and how to compare them:

  * ``time``  — microseconds, LOWER is better; fails when the fresh value
    exceeds ``baseline * tolerance``.  Tolerances are deliberately generous
    (1.6–2.0x): these runs share a CI box with everything else, and the gate
    exists to catch step-change regressions (an accidental per-query launch,
    a lost cache), not scheduler noise.
  * ``ratio`` — a derived quality ratio, HIGHER is better; fails when the
    fresh value drops below ``baseline * tolerance`` (e.g. the GFP launch
    reduction falling from 5x toward 1x means the guided walk stopped
    guiding).

Exit status: 0 = every metric within tolerance, 1 = regression (or a metric
missing from the fresh run — a silently vanished row must not read as a
pass).  ``tools/ci.sh`` runs each bench into a temp file, gates it against
the committed baseline, and only then moves the fresh record over the
baseline.  ``--self-test`` proves the gate actually fails: it injects a
synthetic regression into a copy of each baseline and requires the diff to
reject it (and the unmodified copy to pass).
"""
from __future__ import annotations

import argparse
import copy
import json
import sys
from typing import Callable, Dict, List, Optional, Tuple

# metric -> (value, kind, tolerance); kind in {"time", "ratio"}
Metrics = Dict[str, Tuple[float, str, float]]

TIME_TOL = 2.0      # fresh time may be up to 2.0x the baseline
WARM_TIME_TOL = 1.6  # warm-cache path is host-only and far less noisy
RATIO_TOL = 0.75    # a ratio may drop to 75% of the baseline


def _row(doc: dict, **match) -> Optional[dict]:
    for row in doc.get("rows", []):
        if all(row.get(k) == v for k, v in match.items()):
            return row
    return None


def _serve_metrics(doc: dict) -> Metrics:
    out: Metrics = {}
    cold = _row(doc, variant="micro_batched", batch=64, cache="off")
    if cold:
        out["micro_batched_b64_cold_us"] = (cold["us_per_query"], "time",
                                            TIME_TOL)
    warm = _row(doc, variant="micro_batched", batch=64, cache="on")
    if warm:
        out["micro_batched_b64_warm_us"] = (warm["us_per_query"], "time",
                                            WARM_TIME_TOL)
    return out


def _shard_metrics(doc: dict) -> Metrics:
    out: Metrics = {}
    best = None
    for row in doc.get("rows", []):
        if row.get("variant") == "sharded_mesh" and row.get("batch") == 64:
            us = row["us_per_query"]
            best = us if best is None else min(best, us)
    if best is not None:
        out["best_sharded_mesh_b64_us"] = (best, "time", TIME_TOL)
    return out


def _gfp_metrics(doc: dict) -> Metrics:
    out: Metrics = {}
    red = _row(doc, variant="launch_reduction")
    if red:
        out["launch_reduction_ratio"] = (red["ratio"], "ratio", RATIO_TOL)
    hyb = _row(doc, variant="gfp/hybrid")
    if hyb:
        out["gfp_hybrid_total_us"] = (hyb["total_us"], "time", TIME_TOL)
    return out


def _obs_metrics(doc: dict) -> Metrics:
    out: Metrics = {}
    ov = _row(doc, variant="overhead")
    if ov:
        # the bench already enforces its own absolute <5% gate in-run; the
        # perfgate additionally pins the trend against the committed record
        out["obs_overhead_pct"] = (max(0.0, ov["overhead_pct"]) + 1.0,
                                   "time", 5.0)
    return out


def _tune_metrics(doc: dict) -> Metrics:
    """Autotune gate: tuned-vs-default speedup per workload (a ratio — must
    not collapse below the baseline's floor) plus the tuned wall time."""
    out: Metrics = {}
    for variant in ("serve_warm", "gfp_depth6"):
        row = _row(doc, variant=variant)
        if row:
            out[f"{variant}_speedup"] = (row["speedup"], "ratio", RATIO_TOL)
            out[f"{variant}_tuned_us"] = (row["tuned_us"], "time", TIME_TOL)
    return out


def _disk_metrics(doc: dict) -> Metrics:
    """Disk-tier gate: the prefetch-overlapped spilled sweep's wall time and
    its ratio to the all-RAM sweep (the bench enforces the absolute 1.5x
    envelope in-run; the perfgate pins the trend against the committed
    record so overlap quality cannot silently erode)."""
    out: Metrics = {}
    pre = _row(doc, variant="spilled_prefetch")
    if pre:
        out["spilled_prefetch_us"] = (pre["us_per_sweep"], "time", TIME_TOL)
    ov = _row(doc, variant="overlap")
    if ov:
        out["ram_over_spilled_ratio"] = (ov["ratio"], "ratio", RATIO_TOL)
    return out


SUITES: Dict[str, Callable[[dict], Metrics]] = {
    "serve": _serve_metrics,
    "shard": _shard_metrics,
    "gfp": _gfp_metrics,
    "obs": _obs_metrics,
    "tune": _tune_metrics,
    "disk": _disk_metrics,
}


def diff(suite: str, baseline: dict, fresh: dict) -> List[str]:
    """Compare fresh vs baseline for one suite; returns failure messages
    (empty = pass).  A metric present in the baseline but missing from the
    fresh run FAILS — a vanished row must not read as a pass."""
    extract = SUITES[suite]
    base_m, fresh_m = extract(baseline), extract(fresh)
    failures = []
    for name, (bval, kind, tol) in base_m.items():
        if name not in fresh_m:
            failures.append(f"{suite}/{name}: missing from fresh run "
                            f"(baseline {bval:.3g})")
            continue
        fval = fresh_m[name][0]
        if kind == "time":
            limit = bval * tol
            if fval > limit:
                failures.append(
                    f"{suite}/{name}: {fval:.1f} > {limit:.1f} "
                    f"(baseline {bval:.1f} x{tol} tolerance)")
        else:   # ratio: higher is better
            floor = bval * tol
            if fval < floor:
                failures.append(
                    f"{suite}/{name}: {fval:.3g} < {floor:.3g} "
                    f"(baseline {bval:.3g} x{tol} floor)")
    if not base_m:
        failures.append(f"{suite}: no gated metrics found in baseline")
    return failures


def _inject_regression(suite: str, doc: dict) -> dict:
    """Make a copy of ``doc`` that every suite's gate must reject."""
    bad = copy.deepcopy(doc)
    extract = SUITES[suite]
    for row in bad.get("rows", []):
        if "us_per_query" in row:
            row["us_per_query"] *= 100.0
        if "us_per_sweep" in row:
            row["us_per_sweep"] *= 100.0
        if row.get("variant") == "overlap":
            row["ratio"] = row["ratio"] * 0.1
        if "total_us" in row:
            row["total_us"] *= 100.0
        if row.get("variant") == "launch_reduction":
            row["ratio"] = row["ratio"] * 0.1
        if "overhead_pct" in row:
            row["overhead_pct"] = 100.0
        if "speedup" in row:
            row["speedup"] *= 0.1
        if "tuned_us" in row:
            row["tuned_us"] *= 100.0
    assert extract(bad), f"{suite}: injection produced no metrics"
    return bad


def self_test(baselines: Dict[str, str]) -> int:
    """For every suite with a committed baseline: the unmodified record must
    pass its own gate, and a synthetically regressed copy must fail."""
    checked = 0
    for suite, path in baselines.items():
        try:
            with open(path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            print(f"self-test: {suite}: no baseline at {path}, skipped")
            continue
        clean = diff(suite, doc, doc)
        if clean:
            print(f"self-test FAILED: {suite}: identical run did not pass:")
            for m in clean:
                print(f"  {m}")
            return 1
        bad = _inject_regression(suite, doc)
        caught = diff(suite, doc, bad)
        if not caught:
            print(f"self-test FAILED: {suite}: injected regression passed")
            return 1
        print(f"self-test: {suite}: clean pass + injected regression "
              f"caught ({caught[0]})")
        checked += 1
    if checked == 0:
        print("self-test FAILED: no baselines found to check")
        return 1
    print(f"self-test OK ({checked} suites)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suite", choices=sorted(SUITES))
    ap.add_argument("--baseline", help="committed BENCH_*.json")
    ap.add_argument("--fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("--self-test", action="store_true",
                    help="prove the gate fails on a synthetic regression "
                         "and passes on the unmodified baselines")
    args = ap.parse_args()

    if args.self_test:
        return self_test({"serve": "BENCH_serve.json",
                          "shard": "BENCH_shard.json",
                          "gfp": "BENCH_gfp.json",
                          "obs": "BENCH_obs.json",
                          "tune": "BENCH_tune.json",
                          "disk": "BENCH_disk.json"})
    if not (args.suite and args.baseline and args.fresh):
        ap.error("--suite, --baseline and --fresh are required "
                 "(or use --self-test)")
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures = diff(args.suite, baseline, fresh)
    if failures:
        print(f"perfgate: {args.suite}: REGRESSION")
        for m in failures:
            print(f"  {m}")
        return 1
    for name, (val, kind, tol) in SUITES[args.suite](fresh).items():
        print(f"perfgate: {args.suite}/{name}: {val:.3g} ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
