#!/usr/bin/env python
"""repro-lint driver: run the repo's static-analysis checkers, gate on the
committed baseline, self-test the detectors, and report dead modules.

Usage:
    python tools/analyze.py                    # gate: fail on new findings
    python tools/analyze.py --update-baseline  # accept current findings
    python tools/analyze.py --json out.json    # machine-readable report
    python tools/analyze.py --self-test        # prove detectors catch
                                               # injected violations
    python tools/analyze.py --dead-modules     # advisory import-graph
                                               # report (always exit 0)

The baseline (``tools/analysis_baseline.json``) holds line-number-free
fingerprints of accepted findings; anything not in it fails the run.  The
shipped tree keeps the baseline EMPTY — suppressions with a rationale
comment are preferred over baselining, because they live next to the code
they excuse.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import (analyze_paths, default_checkers,  # noqa: E402
                            dead_module_report, engine)

DEFAULT_ROOT = os.path.join(REPO_ROOT, "src", "repro")
DEFAULT_BASELINE = os.path.join(REPO_ROOT, "tools",
                                "analysis_baseline.json")

# One injected violation per checker: the self-test writes these into a
# temp tree and requires every checker to catch its own (and to stay quiet
# on the clean twin) — the perfgate.py --self-test pattern.
_SELFTEST_VIOLATIONS = {
    "concurrency": (
        "CONC001",
        """\
import threading

class TwoLocks:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        threading.Thread(target=self._run).start()

    def forward(self):
        with self._a_lock:
            with self._b_lock:
                self.counter = 1

    def _run(self):
        with self._b_lock:
            with self._a_lock:
                self.counter = 2
"""),
    "jit_safety": (
        "JIT001",
        """\
import jax

@jax.jit
def bad(x):
    if x > 0:
        return float(x)
    return x
"""),
    "tuner_seam": (
        "TUNE001",
        """\
def launch(tx, tgt, w, itemset_counts):
    return itemset_counts(tx, tgt, w, block_k=256, accum="mxu_f32")
"""),
    "metric_hygiene": (
        "MET001",
        """\
def record(REGISTRY, n):
    REGISTRY.counter("rows_total", rows=f"{n}").inc()
"""),
    "exception_hygiene": (
        "EXC001",
        """\
def swallow(fn):
    try:
        fn()
    except Exception:
        pass
"""),
}

_SELFTEST_CLEAN = """\
import threading

class OneLock:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump(self):
        with self._lock:
            self.n += 1
"""


def run_gate(args) -> int:
    findings, n_files = analyze_paths([args.root], default_checkers(),
                                      root=args.root)
    baseline = engine.load_baseline(args.baseline)
    new = engine.new_findings(findings, baseline)
    known = len(findings) - len(new)

    if args.update_baseline:
        n = engine.write_baseline(args.baseline, findings)
        print(f"repro-lint: baseline updated: {n} fingerprint(s) "
              f"-> {os.path.relpath(args.baseline, REPO_ROOT)}")
        return 0

    if args.json:
        doc = {
            "files": n_files,
            "baselined": known,
            "new": [f.__dict__ for f in new],
            "all": [f.__dict__ for f in findings],
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1)
            fh.write("\n")

    for f in new:
        print(f.format())
    status = "FAIL" if new else "ok"
    print(f"repro-lint: {status}: {n_files} files, {len(new)} new "
          f"finding(s), {known} baselined")
    return 1 if new else 0


def run_self_test() -> int:
    failures = []
    with tempfile.TemporaryDirectory(prefix="repro_lint_selftest_") as tmp:
        # files live under serve/ so the path-scoped checkers (concurrency
        # watches serve/ + obs/) see them
        os.makedirs(os.path.join(tmp, "serve"))
        for checker_name, (code, source) in _SELFTEST_VIOLATIONS.items():
            path = os.path.join(tmp, "serve", f"bad_{checker_name}.py")
            with open(path, "w") as fh:
                fh.write(source)
        clean_path = os.path.join(tmp, "clean.py")
        with open(clean_path, "w") as fh:
            fh.write(_SELFTEST_CLEAN)

        findings, _ = analyze_paths([tmp], default_checkers(), root=tmp)
        by_file = {}
        for f in findings:
            by_file.setdefault(f.path, set()).add(f.code)

        for checker_name, (code, _) in _SELFTEST_VIOLATIONS.items():
            got = by_file.get(f"serve/bad_{checker_name}.py", set())
            if code in got:
                print(f"self-test: {checker_name}: caught injected "
                      f"{code} [ok]")
            else:
                failures.append(f"{checker_name}: injected {code} NOT "
                                f"caught (got {sorted(got) or 'nothing'})")
        if by_file.get("clean.py"):
            failures.append(f"clean twin flagged: "
                            f"{sorted(by_file['clean.py'])}")
        else:
            print("self-test: clean twin unflagged [ok]")

    for msg in failures:
        print(f"self-test: FAIL: {msg}")
    print(f"repro-lint self-test: "
          f"{'FAIL' if failures else 'ok'} "
          f"({len(_SELFTEST_VIOLATIONS)} injected violations)")
    return 1 if failures else 0


def run_dead_modules() -> int:
    rep = dead_module_report(REPO_ROOT)
    print(f"dead-module report (advisory): "
          f"{len(rep['reachable'])} reachable from "
          f"{len(rep['roots'])} roots; {len(rep['dead'])} unreferenced:")
    for path in rep["dead_paths"]:
        print(f"  {path}")
    if not rep["dead"]:
        print("  (none)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=DEFAULT_ROOT,
                    help="tree to analyze (default: src/repro)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report as JSON")
    ap.add_argument("--self-test", action="store_true",
                    help="verify every checker catches an injected "
                         "violation")
    ap.add_argument("--dead-modules", action="store_true",
                    help="advisory import-graph report (always exits 0)")
    args = ap.parse_args(argv)

    if args.self_test:
        return run_self_test()
    if args.dead_modules:
        return run_dead_modules()
    return run_gate(args)


if __name__ == "__main__":
    sys.exit(main())
