#!/usr/bin/env bash
# CI entry point: fast tier first (fail fast, no slow tests), then the full
# suite including the slow multi-device subprocess tests, then the streaming
# perf record (BENCH_streaming.json artifact).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== fast tier (pytest -m 'not slow') ==="
python -m pytest -x -q -m "not slow"

echo "=== full suite (--runslow) ==="
python -m pytest -q --runslow

echo "=== streaming perf record ==="
python -m benchmarks.streaming --json BENCH_streaming.json
