#!/usr/bin/env bash
# CI entry point: fast tier first (fail fast, no slow tests), then the full
# suite including the slow multi-device subprocess tests, then the serving
# smoke (end-to-end count server with exactness verify), then the perf
# records (BENCH_streaming.json / BENCH_serve.json artifacts).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== fast tier (pytest -m 'not slow') ==="
python -m pytest -x -q -m "not slow"

echo "=== static analysis (repro-lint: self-test, gate, dead modules) ==="
# self-test first: an analyzer that cannot catch an injected violation
# must not be allowed to greenlight the tree (perfgate --self-test rule)
python tools/analyze.py --self-test
python tools/analyze.py
# advisory only — import-graph report, never fails the build
python tools/analyze.py --dead-modules

echo "=== full suite (--runslow) ==="
python -m pytest -q --runslow

echo "=== serving smoke (count server submit/flush/append + verify) ==="
python -m repro.launch.serve_counts --rows 2000 --items 24 --rounds 4 \
    --batch 16 --appends 1 --append-rows 300 --pool 64 --theta 0.08 --verify

echo "=== shard-serve smoke (sharded store + async flush loop + verify) ==="
python -m repro.launch.serve_counts --rows 2000 --items 24 --rounds 4 \
    --batch 16 --appends 1 --append-rows 300 --pool 64 --shards 2 \
    --async-flush --max-delay-ms 25 --theta 0.08 --verify

echo "=== rule-serve smoke (minority rules over the count path + verify) ==="
python -m repro.launch.serve_counts --rows 2000 --items 24 --rounds 4 \
    --batch 16 --appends 2 --append-rows 300 --pool 64 --p-y 0.2 \
    --theta 0.02 --rules --min-conf 0.1 --verify

echo "=== mine-loop smoke (cross-backend parity + driver bench sanity) ==="
python -m pytest -q tests/test_mining_driver.py
python -m benchmarks.mine_loop --smoke

echo "=== gfp smoke (differential battery + chooser pins + launch gate) ==="
python -m pytest -q tests/test_gfp_backend.py tests/test_chooser.py
python -m benchmarks.gfp_hybrid --smoke

echo "=== obs smoke (telemetry tests + overhead bench liveness) ==="
python -m pytest -q tests/test_obs.py
python -m benchmarks.obs_overhead --smoke

echo "=== autotune smoke (lattice invariance + sweep/save/load/resolve) ==="
python -m pytest -q tests/test_autotune.py
python tools/autotune.py --smoke
python -m benchmarks.autotune --smoke

echo "=== disk-tier smoke (spill/mmap store + prefetch + compactor) ==="
python -m pytest -q tests/test_spill.py tests/test_lockwatch.py
python -m benchmarks.disk_tier --smoke
# end-to-end: a tiny spill budget forces REAL on-disk segments under the
# serving loop, with the background compactor folding appended deltas
SPILL_DIR="$(mktemp -d)"
python -m repro.launch.serve_counts --rows 2000 --items 24 --rounds 4 \
    --batch 16 --appends 2 --append-rows 300 --pool 64 \
    --spill-dir "$SPILL_DIR" --spill-threshold-bytes 4096 --bg-compact \
    --min-compact-rows 64 --theta 0.08 --verify
rm -rf "$SPILL_DIR"

echo "=== perfgate self-test (gate must reject an injected regression) ==="
python tools/perfgate.py --self-test

echo "=== streaming perf record ==="
python -m benchmarks.streaming --json BENCH_streaming.json

# Gated suites: the fresh record is written to a temp file, diffed against
# the COMMITTED baseline by tools/perfgate.py (nonzero exit on regression,
# leaving the baseline untouched for debugging), and only then promoted.
gate() {  # gate <suite> <bench-module> <baseline.json>
    local suite="$1" module="$2" baseline="$3"
    local fresh="${baseline%.json}.fresh.json"
    python -m "$module" --json "$fresh"
    if [ -f "$baseline" ]; then
        python tools/perfgate.py --suite "$suite" \
            --baseline "$baseline" --fresh "$fresh"
    else
        echo "perfgate: $suite: no committed baseline, seeding $baseline"
    fi
    mv "$fresh" "$baseline"
}

echo "=== serving perf record (perfgate vs committed baseline) ==="
gate serve benchmarks.serve BENCH_serve.json

echo "=== mining-loop perf record ==="
python -m benchmarks.mine_loop --json BENCH_mine.json

echo "=== shard-serve perf record (perfgate vs committed baseline) ==="
gate shard benchmarks.shard_serve BENCH_shard.json

echo "=== rule-serve perf record ==="
python -m benchmarks.rule_serve --json BENCH_rules.json

echo "=== gfp perf record (launch-reduction + perfgate vs baseline) ==="
gate gfp benchmarks.gfp_hybrid BENCH_gfp.json

echo "=== obs perf record (<5% overhead gate enforced in-run) ==="
gate obs benchmarks.obs_overhead BENCH_obs.json

echo "=== autotune perf record (tuned >= default floor + perfgate) ==="
gate tune benchmarks.autotune BENCH_tune.json

echo "=== disk-tier perf record (spilled-vs-RAM overlap + perfgate) ==="
gate disk benchmarks.disk_tier BENCH_disk.json
