#!/usr/bin/env python3
"""Offline autotune sweep: micro-benchmark the launch-config lattice and
persist the per-(device-kind, geometry-bucket) winners as a versioned JSON
tuning table (``src/repro/roofline/autotune.py`` is the library; this is
the operator entry point).

  PYTHONPATH=src python tools/autotune.py                 # CI preset
  PYTHONPATH=src python tools/autotune.py --preset serve
  PYTHONPATH=src python tools/autotune.py -g 16384,256,2,2 -g 4096,256,2,2
  PYTHONPATH=src python tools/autotune.py --smoke         # CI sanity check

The table lands at the in-repo committed path for this device kind by
default (``--out ~/.cache/...`` for a user-local table; the resolution
seam prefers ``$REPRO_TUNE_TABLE`` → user cache → repo table).  Every run
round-trips the saved file through the schema-checked loader and proves it
resolves before reporting success.
"""
from __future__ import annotations

import argparse
import sys
import time


# Geometry presets: (N, K, W, C) per launch.  "ci" covers the buckets the
# benchmark workloads touch, with >= 2 distinct row buckets so the derived
# chooser thresholds (launch-cost fit) have a slope to fit.
PRESETS = {
    "ci": [(16384, 256, 2, 2), (4096, 256, 2, 2), (1024, 256, 2, 2)],
    "serve": [(16384, 256, 2, 2), (65536, 256, 2, 2)],
    "mine": [(30000, 512, 1, 1), (4096, 512, 1, 1), (1024, 256, 1, 1)],
}


def _parse_geometry(text: str):
    parts = [int(p) for p in text.replace("x", ",").split(",") if p]
    if len(parts) != 4 or any(p <= 0 for p in parts):
        raise argparse.ArgumentTypeError(
            f"geometry must be 4 positive ints N,K,W,C — got {text!r}")
    return tuple(parts)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-g", "--geometry", action="append", default=[],
                    type=_parse_geometry, metavar="N,K,W,C",
                    help="launch geometry to tune (repeatable)")
    ap.add_argument("--preset", choices=sorted(PRESETS), default="ci",
                    help="geometry preset when no -g given (default: ci)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="best-of-N timing per candidate (default: 5)")
    ap.add_argument("--out", default=None,
                    help="output path (default: the in-repo committed "
                         "table for this device kind)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep to a temp file; assert the table "
                         "saves, loads, and resolves")
    args = ap.parse_args()

    from repro.roofline import autotune

    kind = autotune.device_kind()
    created = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())

    if args.smoke:
        import tempfile

        geometries = [(256, 16, 1, 1), (1024, 16, 1, 1)]
        table = autotune.sweep(geometries, repeats=2, block_ks=(128, 256),
                               created=created, log=print)
        out = args.out or tempfile.mktemp(prefix="autotune_smoke_",
                                          suffix=".json")
        autotune.save_table(table, out)
        loaded = autotune.load_table(out)
        assert loaded.entries, "smoke sweep produced an empty table"
        assert loaded.device_kind == kind
        autotune.set_active_table(loaded)
        try:
            cfg = autotune.resolve_launch_config(256, 16, 1, 1)
            assert cfg.source == "table", cfg
        finally:
            autotune.set_active_table(None)
        print(f"autotune smoke OK ({len(loaded.entries)} entries, "
              f"saved+loaded+resolved via {out})")
        return 0

    geometries = args.geometry or PRESETS[args.preset]
    t0 = time.perf_counter()
    table = autotune.sweep(geometries, repeats=args.repeats,
                           created=created, log=print)
    dt = time.perf_counter() - t0

    out = args.out or autotune.repo_table_path(kind)
    autotune.save_table(table, out)
    loaded = autotune.load_table(out)     # prove the round trip
    assert len(loaded.entries) == len(table.entries)

    print(f"\ntuning table [{kind}] {len(table.entries)} buckets "
          f"in {dt:.1f}s -> {out}")
    for bucket, e in sorted(table.entries.items()):
        print(f"  {bucket}: bk{e.config.block_k}/{e.config.accum}"
              f" chunk_rows={e.config.chunk_rows or 'auto'}"
              f" serve_block_k={e.serve_block_k or 'default'}"
              f" ({e.us:.0f}us, eff={e.efficiency:.3g})")
    derived = autotune.derived_chooser_thresholds(loaded)
    if derived:
        print(f"derived chooser thresholds: {derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
