"""Merge fixup records into sweep JSONLs and inject the §Dry-run/§Roofline
tables into EXPERIMENTS.md between the HTML-comment markers.

  PYTHONPATH=src python tools/finalize_results.py \
      --single results_single_pod.jsonl --fix-single /tmp/fixup.jsonl \
      --multi results_multi_pod.jsonl  --fix-multi  /tmp/fixup_mp.jsonl
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.report import dryrun_table, load, roofline_table  # noqa: E402


def merge(base_path: str, fix_path: str) -> list:
    recs = {(r["arch"], r["shape"]): r for r in load(base_path)}
    n = 0
    if fix_path and os.path.exists(fix_path):
        for r in load(fix_path):
            recs[(r["arch"], r["shape"])] = r
            n += 1
    out = sorted(recs.values(), key=lambda r: (r["arch"], r["shape"]))
    with open(base_path, "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")
    print(f"{base_path}: merged {n} fixups, {len(out)} records")
    return out


def inject(md_path: str, marker: str, content: str) -> None:
    src = open(md_path).read()
    tag = f"<!-- {marker} -->"
    assert tag in src, marker
    begin = src.index(tag)
    # replace from the marker to the next section break (--- or ## at bol)
    rest = src[begin + len(tag):]
    src = src[:begin] + tag + "\n\n" + content + "\n" + _tail_after_block(rest)
    open(md_path, "w").write(src)


def _tail_after_block(rest: str) -> str:
    # keep everything from the first line starting a new section
    lines = rest.splitlines(keepends=True)
    for i, l in enumerate(lines):
        if l.startswith("---") or l.startswith("## ") or l.startswith("### Dry-run: mining"):
            return "".join(lines[i:])
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", default="results_single_pod.jsonl")
    ap.add_argument("--fix-single", default=None)
    ap.add_argument("--multi", default="results_multi_pod.jsonl")
    ap.add_argument("--fix-multi", default=None)
    ap.add_argument("--md", default="EXPERIMENTS.md")
    args = ap.parse_args()

    single = merge(args.single, args.fix_single)
    multi = merge(args.multi, args.fix_multi)

    dr = ("#### Single pod (16×16 = 256 chips)\n\n" + dryrun_table(single) +
          "\n\n#### Multi-pod (2×16×16 = 512 chips) — compile proof "
          "(`pod` axis shards; roofline single-pod only per spec)\n\n" +
          dryrun_table(multi))
    inject(args.md, "DRYRUN_TABLES", dr)
    inject(args.md, "ROOFLINE_TABLE", roofline_table(single))
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
