"""Data-pipeline determinism/elasticity + abstract-spec fidelity tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenPipeline, TransactionPipeline, census_like_db
from repro.models import get_model
from repro.models.common import abstract_params
from repro.train.optimizer import AdamWConfig, abstract_state, init_state


def test_token_pipeline_deterministic_and_elastic():
    pipe = TokenPipeline(vocab_size=100, seq_len=8, global_batch=8, seed=3)
    a = pipe.batch_at(5)
    b = pipe.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host slices partition the SAME logical batch regardless of topology
    full = pipe.batch_at(7)["tokens"]
    parts = [pipe.host_slice(7, process_index=i, process_count=4)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    # labels are next-token shifted
    raw = pipe.batch_at(0)
    assert raw["tokens"].shape == raw["labels"].shape


def test_transaction_pipeline_blocks_deterministic():
    pipe = TransactionPipeline(n_items=16, p_x=0.2, p_y=0.1, block_rows=64, seed=1)
    b1, w1 = pipe.block(3)
    b2, w2 = pipe.block(3)
    np.testing.assert_array_equal(b1, b2)
    np.testing.assert_array_equal(w1, w2)
    assert b1.shape == (64, 1) and w1.shape == (64, 2)
    b3, _ = pipe.block(4)
    assert not np.array_equal(b1, b3)


def test_census_like_schema():
    tx, y = census_like_db(200, 0.2, seed=0)
    assert len(tx) == 200 and len(set(len(t) for t in tx)) == 1
    items = {a for t in tx for a in t}
    assert len(items) <= 115
    assert 0 < y.sum() < 200


@pytest.mark.parametrize("arch", ["qwen3-8b", "arctic-480b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-large-v2"])
def test_abstract_params_match_real_init(arch):
    """Dry-run ShapeDtypeStructs must exactly mirror real initialization."""
    model = get_model(arch, reduced=True)
    real = model.init(jax.random.key(0))
    abstract = abstract_params(model.specs, jnp.dtype(model.cfg.dtype))
    ra, aa = jax.tree.leaves(real), jax.tree.leaves(abstract)
    assert len(ra) == len(aa)
    assert jax.tree.structure(real) == jax.tree.structure(abstract)
    for r, a in zip(ra, aa):
        assert r.shape == a.shape and r.dtype == a.dtype


def test_abstract_opt_state_matches_real():
    model = get_model("qwen3-8b", reduced=True)
    params = model.init(jax.random.key(0))
    cfg = AdamWConfig(state_dtype="float32")
    real = init_state(params, cfg)
    abstract = abstract_state(jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params), cfg)
    for r, a in zip(jax.tree.leaves(real), jax.tree.leaves(abstract)):
        assert r.shape == a.shape and r.dtype == a.dtype


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-large-v2"])
def test_cache_specs_match_init_cache(arch):
    model = get_model(arch, reduced=True)
    specs = model.cache_specs(batch=2, max_len=16)
    cache = model.init_cache(batch=2, max_len=16)
    sl = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, tuple)
                         and len(x) == 2 and isinstance(x[0], tuple))
    cl = jax.tree.leaves(cache)
    assert len(sl) == len(cl)
    for (shape, _), arr in zip(sl, cl):
        assert tuple(shape) == arr.shape
