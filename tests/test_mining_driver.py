"""Unified mining driver: one level-wise loop over the CountBackend protocol.

Cross-backend parity (dense / streaming / distributed / versioned all yield
the host oracle's frequent sets and counts through the ONE driver loop),
kill/resume via MiningCheckpoint on every backend — including mid-level
partials on single-chunk backends and the versioned store's version-pinned
checkpoint — and the consolidation meta-check (exactly one apriori_gen-based
engine loop in src/)."""
import json
import pathlib

import numpy as np
import pytest

from repro.core import mine_frequent
from repro.core.incremental import ceil_count
from repro.mining import (DenseBackend, DenseDB, StreamingBackend,
                          StreamingDB, dense_mine_frequent,
                          mine_frequent_backend, streaming_mine_frequent)
from repro.mining.distributed import DistributedMiner, MiningCheckpoint
from repro.serve import (CountServer, VersionedCountBackend, VersionedDB,
                         versioned_mine_frequent)


def _db(seed=0, n=220, m=12, p=0.35):
    rng = np.random.default_rng(seed)
    return [[i for i in range(m) if rng.random() < p] for _ in range(n)]


class _Preempted(Exception):
    pass


# ----------------------------------------------------------- parity: 4 ways
def test_four_backends_identical_frequent_sets():
    tx = _db(0)
    want = mine_frequent(tx, 40)
    assert len(want) > len([k for k in want if len(k) == 1])  # multi-level

    assert dense_mine_frequent(DenseDB.encode(tx), 40) == want
    assert streaming_mine_frequent(
        StreamingDB.encode(tx, chunk_rows=32), 40) == want

    import jax
    from repro.mining import ItemVocab, encode_bitmap
    vocab = ItemVocab.from_transactions(tx)
    bits = encode_bitmap(tx, vocab)
    w = np.ones((len(tx), 1), np.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert DistributedMiner(mesh).mine_frequent(bits, w, vocab, 40) == want

    store = VersionedDB(tx[:150], merge_ratio=2.0)  # keep the delta resident
    store.append(tx[150:])               # delta segment live: composed sweep
    assert store.delta_rows > 0
    assert versioned_mine_frequent(store, 40) == want

    # the driver called directly over a backend is the same function
    assert mine_frequent_backend(DenseBackend(DenseDB.encode(tx)), 40) == want
    assert mine_frequent_backend(VersionedCountBackend(store), 40) == want


def test_parity_with_class_column():
    rng = np.random.default_rng(1)
    tx = _db(1, n=260, m=10, p=0.4)
    y = [int(rng.random() < 0.3) for _ in tx]
    rare = [t for t, c in zip(tx, y) if c == 1]
    want = mine_frequent(rare, 12)

    ddb = DenseDB.encode(tx, classes=y, n_classes=2)
    assert dense_mine_frequent(ddb, 12, class_column=1) == want
    sdb = StreamingDB.encode(tx, classes=y, n_classes=2, chunk_rows=32)
    assert streaming_mine_frequent(sdb, 12, class_column=1) == want

    import jax
    from repro.mining import ItemVocab, class_weights, encode_bitmap
    vocab = ItemVocab.from_transactions(tx)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = DistributedMiner(mesh).mine_frequent(
        encode_bitmap(tx, vocab), class_weights(y, 2), vocab, 12,
        class_column=1)
    assert got == want

    store = VersionedDB(tx, classes=y, n_classes=2)
    assert versioned_mine_frequent(store, 12, class_column=1) == want


def test_level1_shortcut_identical_and_guarded():
    tx = _db(2)
    ddb = DenseDB.encode(tx)
    via_shortcut = mine_frequent_backend(DenseBackend(ddb), 40)
    via_engine = mine_frequent_backend(DenseBackend(ddb), 40,
                                       level1_shortcut=False)
    assert via_shortcut == via_engine == mine_frequent(tx, 40)
    # a backend without the shortcut refuses a forced request
    sdb = StreamingDB.encode(tx, chunk_rows=64)
    with pytest.raises(ValueError):
        mine_frequent_backend(StreamingBackend(sdb), 40, level1_shortcut=True)


def test_on_level_hook_reports_levels():
    tx = _db(3)
    seen = []
    got = mine_frequent_backend(
        DenseBackend(DenseDB.encode(tx)), 40,
        on_level=lambda lvl, n_cands, n_freq: seen.append(
            (lvl, n_cands, n_freq)))
    assert [lvl for lvl, _, _ in seen] == list(range(1, len(seen) + 1))
    for lvl, n_cands, n_freq in seen:
        assert n_freq == len([k for k in got if len(k) == lvl]) <= n_cands


# ------------------------------------------------- kill/resume: dense backend
class _CountingDense(DenseBackend):
    def __init__(self, db, **kw):
        super().__init__(db, **kw)
        self.launches = 0

    def counts(self, masks, *, start_chunk=0, init=None, on_chunk=None):
        if start_chunk < self.n_count_chunks:
            self.launches += 1
        return super().counts(masks, start_chunk=start_chunk, init=init,
                              on_chunk=on_chunk)


def test_dense_backend_mid_level_kill_resume(tmp_path):
    tx = _db(4, n=300, m=9, p=0.5)
    want = mine_frequent(tx, 45)
    assert max(len(k) for k in want) >= 3  # needs a level after the kill

    ddb = DenseDB.encode(tx)
    ckpt = MiningCheckpoint(str(tmp_path / "dense.json"))
    calls = []

    def die_at_level_2(level, chunk):
        calls.append((level, chunk))
        if level == 2:
            raise _Preempted()

    with pytest.raises(_Preempted):
        mine_frequent_backend(_CountingDense(ddb), 45, checkpoint=ckpt,
                              on_chunk=die_at_level_2)
    # durable partial: level 2 fully counted (single chunk), not yet absorbed
    state = json.load(open(str(tmp_path / "dense.json")))
    assert state["level"] == 1
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 1
    assert state["partial"]["backend"] == "dense"

    resumed = []
    backend = _CountingDense(ddb)
    got = mine_frequent_backend(backend, 45, checkpoint=ckpt,
                                on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0][0] == 3              # level 2 absorbed from the partial
    # level 1 came from the column-sum shortcut, level 2 from the saved
    # accumulator: every launch of the resumed run is level >= 3
    assert backend.launches == len(resumed)


def test_distributed_level_resume_skips_counted_levels(tmp_path):
    import jax
    from repro.mining import ItemVocab, encode_bitmap

    tx = _db(5)
    want = mine_frequent(tx, 40)
    vocab = ItemVocab.from_transactions(tx)
    bits = encode_bitmap(tx, vocab)
    w = np.ones((len(tx), 1), np.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ckpt = MiningCheckpoint(str(tmp_path / "dist.json"))

    class _Counting(DistributedMiner):
        n_calls = 0

        def counts(self, *a, **kw):
            _Counting.n_calls += 1
            return super().counts(*a, **kw)

    _Counting(mesh, checkpoint=ckpt).mine_frequent(bits, w, vocab, 40,
                                                   max_len=2)
    first = _Counting.n_calls
    got = _Counting(mesh, checkpoint=ckpt).mine_frequent(bits, w, vocab, 40)
    assert got == want
    # the resumed run launched strictly fewer levels than a fresh run would
    assert _Counting.n_calls - first < first + 1


# --------------------------------------------- kill/resume: versioned backend
def test_versioned_backend_mid_level_kill_resume(tmp_path):
    tx = _db(6, n=260, m=10, p=0.4)
    store = VersionedDB(tx[:200], streaming=True, chunk_rows=32,
                        merge_ratio=2.0)
    store.append(tx[200:])
    assert store.delta_rows > 0            # base chunks + one delta chunk
    backend = VersionedCountBackend(store)
    assert backend.n_count_chunks == store.base.n_chunks + 1
    want = mine_frequent(tx, 40)
    assert versioned_mine_frequent(store, 40) == want

    ckpt = MiningCheckpoint(str(tmp_path / "versioned.json"))
    calls = []

    def die_mid_level_2(level, chunk):
        calls.append((level, chunk))
        if level == 2 and chunk == 2:
            raise _Preempted()             # mid base sweep of level 2

    with pytest.raises(_Preempted):
        versioned_mine_frequent(store, 40, checkpoint=ckpt,
                                on_chunk=die_mid_level_2)
    state = json.load(open(str(tmp_path / "versioned.json")))
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 3
    assert state["partial"]["version"] == store.version
    assert state["meta"]["version"] == store.version

    resumed = []
    got = versioned_mine_frequent(
        store, 40, checkpoint=ckpt,
        on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0] == (2, 3)            # resumed mid-level, chunk 3


def test_versioned_checkpoint_discarded_after_append(tmp_path):
    tx = _db(7, n=200, m=10, p=0.35)
    store = VersionedDB(tx)
    ckpt = MiningCheckpoint(str(tmp_path / "stale.json"))
    old = versioned_mine_frequent(store, 30, checkpoint=ckpt)
    assert old == mine_frequent(tx, 30)

    extra = _db(8, n=120, m=10, p=0.6)     # denser rows: counts shift
    store.append(extra)
    got = versioned_mine_frequent(store, 30, checkpoint=ckpt)
    want = mine_frequent(tx + extra, 30)
    assert got == want                     # stale version state NOT reused
    assert got != old                      # and the answer genuinely moved


def test_count_server_mine_resumable_over_streaming_store(tmp_path):
    tx = _db(9, n=300, m=10, p=0.4)
    theta = 0.18
    fresh = CountServer(tx, streaming=True, chunk_rows=32)
    want = fresh.mine(theta)
    baseline_launches = fresh.store.kernel_launches

    srv = CountServer(tx, streaming=True, chunk_rows=32)
    mc = ceil_count(theta * srv.store.n_rows)
    ckpt = MiningCheckpoint(str(tmp_path / "server.json"))
    calls = []

    def die_mid_mine(level, chunk):
        calls.append((level, chunk))
        if len(calls) == srv.store.base.n_chunks + 2:
            raise _Preempted()             # 2 chunks into level 2

    with pytest.raises(_Preempted):
        versioned_mine_frequent(srv.store, mc, checkpoint=ckpt,
                                on_chunk=die_mid_mine)
    killed_launches = srv.store.kernel_launches

    got = srv.mine(theta, checkpoint=ckpt)   # the server bootstrap, resumed
    assert got == want
    assert srv.frequent == want              # incremental maintenance armed
    resumed_launches = srv.store.kernel_launches - killed_launches
    assert resumed_launches < baseline_launches  # skipped completed chunks

    # maintenance keeps working after a resumed bootstrap
    inc = _db(10, n=60, m=10, p=0.4)
    srv.append(inc)
    assert srv.frequent == {
        k: v for k, v in
        mine_frequent(tx + inc, ceil_count(theta * (len(tx) + len(inc)))).items()
    }


# ------------------------------------------------------- consolidation check
def test_exactly_one_engine_level_loop():
    """The four engine entry points are shims: outside the paper-faithful
    host baselines in core/, only the driver references apriori_gen."""
    src = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    offenders = sorted(
        p.relative_to(src).as_posix() for p in src.rglob("*.py")
        if "apriori_gen" in p.read_text()
        and not p.relative_to(src).as_posix().startswith("core/"))
    assert offenders == ["mining/driver.py"]
