"""Shared test config: ``--runslow`` gating for slow tests + seeded RNG."""
import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (subprocess / multi-device end-to-end)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to enable")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture
def rng():
    """Deterministic per-test numpy RNG — reproducible failures."""
    return np.random.default_rng(0xA5EED)


@pytest.fixture(autouse=True)
def _default_launch_configs():
    """Pin the autotuner to the compiled-in defaults for every test: the
    committed CI tuning table must not perturb tests that pinned behavior
    under the default block shapes.  Tests that exercise the table call
    ``set_active_table`` themselves (the teardown re-pins defaults)."""
    from repro.roofline import autotune

    autotune.set_active_table(None)
    yield
    autotune.set_active_table(None)
