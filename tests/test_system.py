"""End-to-end behaviour tests for the framework: train loop convergence,
checkpoint/restart equivalence, elastic resume, preemption handling, and the
serving path."""
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, StragglerMonitor
from repro.data import TokenPipeline
from repro.models import get_model
from repro.train import AdamWConfig, init_state, make_train_step

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(arch="qwen3-8b", seq=32, batch=4):
    model = get_model(arch, reduced=True)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=40, warmup_steps=2)
    pipe = TokenPipeline(vocab_size=model.cfg.vocab_size, seq_len=seq,
                         global_batch=batch, seed=0)
    params = model.init(jax.random.key(0))
    opt_state = init_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    return model, opt_cfg, pipe, params, opt_state, step_fn


def test_training_reduces_loss():
    model, _, pipe, params, opt_state, step_fn = _setup()
    losses = []
    for step in range(15):
        batch = {k: jnp.asarray(v) for k, v in pipe.host_slice(step).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
    assert np.isfinite(losses).all()


def test_microbatch_accumulation_matches_full_batch():
    model, opt_cfg, pipe, params, opt_state, _ = _setup(batch=8)
    f1 = jax.jit(make_train_step(model, opt_cfg, n_microbatches=1))
    f4 = jax.jit(make_train_step(model, opt_cfg, n_microbatches=4))
    batch = {k: jnp.asarray(v) for k, v in pipe.host_slice(0).items()}
    p1, _, m1 = f1(params, opt_state, batch)
    p4, _, m4 = f4(params, opt_state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-5)
    # parameters close (accumulation is fp32; ordering differences only)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-3, atol=3e-5)


def test_gradient_compression_modes_run():
    model, opt_cfg, pipe, params, opt_state, _ = _setup()
    batch = {k: jnp.asarray(v) for k, v in pipe.host_slice(0).items()}
    base = None
    for mode in (None, "bf16", "int8"):
        fn = jax.jit(make_train_step(model, opt_cfg, compression=mode))
        _, _, m = fn(params, opt_state, batch)
        if base is None:
            base = float(m["loss"])
        assert abs(float(m["loss"]) - base) < 1e-3  # loss is pre-compression


def test_checkpoint_restart_bitexact(tmp_path):
    model, opt_cfg, pipe, params, opt_state, step_fn = _setup()
    mgr = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    for step in range(6):
        batch = {k: jnp.asarray(v) for k, v in pipe.host_slice(step).items()}
        params, opt_state, _ = step_fn(params, opt_state, batch)
        if step == 2:
            mgr.save(3, (params, opt_state))
            saved = jax.tree.map(np.asarray, (params, opt_state))
    # fresh run resumed from step 3 must match the original exactly
    (p2, o2), manifest = mgr.restore(saved)
    assert manifest["step"] == 3
    p2 = jax.tree.map(jnp.asarray, p2)
    o2 = jax.tree.map(jnp.asarray, o2)
    for step in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in pipe.host_slice(step).items()}
        p2, o2, _ = step_fn(p2, o2, batch)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep_last=2, async_save=False)
    tree = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        mon.record(0.1)
    assert mon.record(0.5) is True
    assert mon.record(0.11) is False
    assert mon.flagged == 1


@pytest.mark.slow
def test_train_launcher_preemption_and_resume(tmp_path):
    """SIGTERM mid-run checkpoints; --resume continues to completion."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    ck = str(tmp_path / "run")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
           "--reduced", "--steps", "300", "--batch", "2", "--seq", "16",
           "--ckpt-dir", ck, "--ckpt-every", "5", "--log-every", "50"]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, cwd=ROOT)
    # wait for some progress then preempt
    import time
    deadline = time.time() + 300
    while time.time() < deadline:
        if os.path.isdir(ck) and any(
                n.startswith("step_") and not n.endswith(".tmp0")
                and os.path.exists(os.path.join(ck, n, "MANIFEST.json"))
                for n in os.listdir(ck)):
            break  # a COMPLETE checkpoint exists; safe to preempt
        time.sleep(1.0)
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=300)
    assert "SIGTERM received" in out or proc.returncode == 0, out[-2000:]

    mgr = CheckpointManager(ck)
    resumed_from = mgr.latest_step()
    assert resumed_from and resumed_from > 0

    cmd2 = [c for c in cmd]
    cmd2[cmd2.index("--steps") + 1] = str(resumed_from + 4)
    cmd2.append("--resume")
    proc2 = subprocess.run(cmd2, env=env, capture_output=True, text=True,
                           timeout=300, cwd=ROOT)
    assert proc2.returncode == 0, proc2.stdout[-2000:] + proc2.stderr[-2000:]
    assert f"resumed from step {resumed_from}" in proc2.stdout


def test_serve_launcher_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-8b",
         "--reduced", "--batch", "2", "--prompt-len", "8", "--gen", "4"],
        env=env, capture_output=True, text=True, timeout=300, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "decoded" in proc.stdout
