"""Serving subsystem: versioned resident DB exactness across appends, batcher
cross-client dedup, (itemset, version) cache invalidation, engine-backed
incremental re-mining parity with the host miner, the served-counts ==
dense_gfp_counts acceptance contract, sharded-vs-single-device count parity,
and the async background flush loop (occupancy/deadline triggers, clean
close)."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ItemOrder, TISTree, brute_force_counts, mine_frequent
from repro.core.incremental import IncrementalMiner, incremental_candidates
from repro.kernels.itemset_count import itemset_counts
from repro.mining import (DenseDB, StreamingDB, dense_gfp_counts,
                          dense_mine_frequent, encode_targets, extend_vocab,
                          pad_words, ItemVocab)
from repro.mining.distributed import MiningCheckpoint
from repro.serve import (CountCache, CountServer, MicroBatcher,
                         ShardedCountBackend, ShardedDB,
                         VersionedCountBackend, VersionedDB, build_masks,
                         canonical_itemset, versioned_mine_frequent)
from repro.serve.cache import check_cache_ledger

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _Preempted(Exception):
    pass


def _db(rng, rows, items, p=0.3):
    return [[int(a) for a in range(items) if rng.random() < p]
            for _ in range(rows)]


def _fresh_counts(history, classes, n_classes, keys):
    """Oracle: counts from a fresh dense encode of the full history."""
    ddb = DenseDB.encode(history, classes=classes, n_classes=n_classes)
    out = np.zeros((len(keys), n_classes), np.int32)
    known = [i for i, k in enumerate(keys)
             if all(a in ddb.vocab for a in k)]
    if known:
        masks = encode_targets([keys[i] for i in known], ddb.vocab)
        got = np.asarray(itemset_counts(ddb.bits, jnp.asarray(masks),
                                        ddb.weights))
        out[np.array(known)] = got
    return out


# ------------------------------------------------------------ encode helpers
def test_pad_words_and_extend_vocab():
    bits = np.array([[1, 2], [3, 4]], np.uint32)
    np.testing.assert_array_equal(pad_words(bits, 2), bits)
    wide = pad_words(bits, 4)
    assert wide.shape == (2, 4) and (wide[:, 2:] == 0).all()
    np.testing.assert_array_equal(wide[:, :2], bits)
    with pytest.raises(ValueError):
        pad_words(bits, 1)

    vocab = ItemVocab((5, 3, 1))
    same = extend_vocab([[5], [3, 1]], vocab)
    assert same is vocab                      # nothing new: same object
    ext = extend_vocab([[5, 9], [9, 7], [9]], vocab)
    assert ext.items[:3] == (5, 3, 1)         # existing columns keep positions
    assert ext.items[3:] == (9, 7)            # new items batch-frequency desc


# ------------------------------------------------------------- VersionedDB
@pytest.mark.parametrize("merge_ratio", [0.25, 1e9])
def test_versioned_db_append_exact_across_batches(merge_ratio):
    """≥2 appends (incl. unseen items), delta-kept and compacted policies:
    served counts stay bit-identical to a fresh encode of the history."""
    rng = np.random.default_rng(0)
    tx = _db(rng, 200, 10)
    y = [int(rng.random() < 0.3) for _ in tx]
    db = VersionedDB(tx, classes=y, n_classes=2, merge_ratio=merge_ratio,
                     min_compact_rows=0)   # floor off: the 60-row deltas here
    # are exactly what auto-compaction should fold under merge_ratio=0.25
    assert db.version == 0 and db.n_rows == 200
    history, classes = list(tx), list(y)
    probes = [(0, 1), (2,), (3, 7, 9), (11,), (4, 12)]  # 11, 12 unseen so far
    for step in range(1, 4):
        batch = _db(rng, 60, 10 + step)       # widens the item universe
        yb = [int(rng.random() < 0.3) for _ in batch]
        assert db.append(batch, classes=yb) == step
        history += batch
        classes += yb
        np.testing.assert_array_equal(
            db.counts(probes), _fresh_counts(history, classes, 2, probes))
    assert db.version == 3 and db.n_rows == len(history)
    if merge_ratio > 1:
        assert db.delta_rows > 0              # delta actually exercised
    else:
        assert db.n_compactions > 0
    db.compact()                              # explicit fold: counts unchanged
    assert db.delta_rows == 0 and db.version == 3
    np.testing.assert_array_equal(
        db.counts(probes), _fresh_counts(history, classes, 2, probes))


@pytest.mark.parametrize("streaming", [False, True])
def test_versioned_db_append_across_word_boundary(streaming):
    """An uncompacted append that widens the bitmap past a 32-item word
    boundary: masks are wider than the resident base, so the out-of-width
    zeroing path runs on the device result (regression: read-only view)."""
    rng = np.random.default_rng(9)
    tx = _db(rng, 80, 40)                     # 40 items -> W=2 words
    db = VersionedDB(tx, streaming=streaming, chunk_rows=16,
                     merge_ratio=1e9)         # keep the narrow base resident
    batch = [[int(a) for a in range(100, 125)] for _ in range(5)]  # W -> 3
    db.append(batch)
    assert db.vocab.n_words == 3
    assert int(np.asarray(db.base.bits).shape[1]) == 2   # base left narrow
    probes = [(0, 1), (104,), (0, 104), (39,)]
    np.testing.assert_array_equal(
        db.counts(probes), _fresh_counts(tx + batch, None, 1, probes))


def test_versioned_db_empty_append_and_unknown_targets():
    rng = np.random.default_rng(1)
    tx = _db(rng, 50, 6)
    db = VersionedDB(tx)
    assert db.append([]) == 0                 # no-op: no count can change
    got = db.counts([("never-seen",), (0, "never-seen")])
    np.testing.assert_array_equal(got, np.zeros((2, 1), np.int32))


def test_versioned_db_failed_compaction_preserves_delta(monkeypatch):
    """compact() must not drop the delta when building the new base fails:
    composed counts stay exact after the failure."""
    rng = np.random.default_rng(14)
    tx = _db(rng, 100, 8)
    db = VersionedDB(tx, merge_ratio=1e9)
    db.append(_db(rng, 30, 8))
    assert db.delta_rows > 0
    probes = [(0,), (1, 2)]
    want = db.counts(probes)
    monkeypatch.setattr(db, "_make_base",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device OOM")))
    with pytest.raises(RuntimeError, match="OOM"):
        db.compact()
    monkeypatch.undo()
    assert db.delta_rows > 0                  # delta NOT lost
    np.testing.assert_array_equal(db.counts(probes), want)
    db.compact()                              # and a healthy retry works
    assert db.delta_rows == 0
    np.testing.assert_array_equal(db.counts(probes), want)


def test_versioned_db_streaming_resident():
    rng = np.random.default_rng(2)
    tx = _db(rng, 150, 8)
    dense = VersionedDB(tx)
    stream = VersionedDB(tx, streaming=True, chunk_rows=16)
    # explicit chunk_rows opts into streaming, like the mining stack
    assert VersionedDB(tx, chunk_rows=16).resident == "streaming"
    assert VersionedDB(tx, streaming=False, chunk_rows=16).resident == "dense"
    assert dense.resident == "dense" and stream.resident == "streaming"
    probes = [(0,), (1, 2), (3, 4, 5)]
    np.testing.assert_array_equal(dense.counts(probes), stream.counts(probes))
    # appends keep the streaming base exact too
    batch = _db(rng, 40, 8)
    dense.append(batch)
    stream.append(batch)
    np.testing.assert_array_equal(dense.counts(probes), stream.counts(probes))
    assert stream.resident == "streaming"


def test_versioned_db_multiclass_requires_classes():
    """Classless rows on a multi-class store would count once PER class
    column — must be rejected, mirroring DenseDB.encode's classes=None ⇒ C=1."""
    rng = np.random.default_rng(10)
    tx = _db(rng, 30, 6)
    y = [int(rng.random() < 0.5) for _ in tx]
    db = VersionedDB(tx, classes=y, n_classes=2)
    vocab_before = db.vocab
    with pytest.raises(ValueError, match="classes"):
        db.append([[0, 1, "new-item"]])
    with pytest.raises(ValueError, match="classes"):
        VersionedDB(tx, n_classes=2)
    # rejected append leaves NO trace: no version bump, no vocab tail
    assert db.version == 0
    assert db.vocab is vocab_before and "new-item" not in db.vocab
    # single-class stores still take classless appends
    db1 = VersionedDB(tx)
    db1.append([[0, 1]])
    assert int(db1.counts([(0, 1)])[0].sum()) == \
        sum(1 for t in tx + [[0, 1]] if {0, 1} <= set(t))


def test_versioned_db_append_overflow_guard():
    db = VersionedDB([[0]], vocab=ItemVocab((0,)))
    db._class_totals[:] = np.iinfo(np.int32).max - 1
    with pytest.raises(OverflowError):
        db.append([[0], [0]])
    # same guard at construction (counts would wrap on the dense path)
    with pytest.raises(OverflowError):
        VersionedDB._guard_totals(np.array([1 << 31], np.int64))


# ------------------------------------------------------------------ batcher
def test_canonical_itemset():
    assert canonical_itemset((3, 1, 3, 2)) == (1, 2, 3)
    assert canonical_itemset((1, 2)) == canonical_itemset([2, 1])


def test_batcher_cross_client_dedup_and_scatter():
    b = MicroBatcher(block_k=8)
    t1 = b.submit("a", [(2, 1), (5,), (1, 2)])  # (1,2) twice within request
    t2 = b.submit("b", [(1, 2), (7,)])          # and again across clients
    assert b.pending == 2
    plan = b.take()
    assert b.pending == 0
    assert plan.unique_keys == [(1, 2), (5,), (7,)]
    assert plan.n_queries == 5
    assert b.n_deduped == 2
    assert [r.request_id for r in plan.requests] == [t1, t2]
    assert plan.requests[0].keys == [(1, 2), (5,), (1, 2)]
    assert plan.rows[(1, 2)] == 0 and plan.rows[(7,)] == 2


def test_build_masks_padding_and_unknown():
    vocab = ItemVocab(tuple(range(40)))       # W = 2 words
    keys = [(0, 39), (3,), ("nope",)]
    masks, known = build_masks(keys, vocab, block_k=8)
    assert masks.shape == (8, 2)              # padded to the block_k multiple
    assert known.tolist() == [True, True, False]
    np.testing.assert_array_equal(masks[2], 0)    # unknown -> zero mask
    np.testing.assert_array_equal(masks[3:], 0)   # padding rows
    want = encode_targets([(0, 39), (3,)], vocab)
    np.testing.assert_array_equal(masks[:2], want)
    big, known = build_masks([(i,) for i in range(9)], vocab, block_k=8)
    assert big.shape == (16, 2) and known.all()


# -------------------------------------------------------------------- cache
def test_cache_hit_miss_lru_and_purge():
    c = CountCache(capacity=2)
    assert c.get((1,), 0) is None and c.misses == 1
    c.put((1,), 0, np.array([3, 4]))
    hit = c.get((1,), 0)
    np.testing.assert_array_equal(hit, [3, 4])
    assert c.hits == 1
    hit[0] = 99                               # defensive copy: cache unharmed
    np.testing.assert_array_equal(c.get((1,), 0), [3, 4])
    assert c.get((1,), 1) is None             # other version: miss
    c.put((2,), 0, np.array([1, 1]))
    c.get((1,), 0)                            # (1,) now most-recent
    c.put((3,), 1, np.array([2, 2]))          # evicts LRU (2,)
    assert c.evictions == 1
    assert c.get((2,), 0) is None
    assert c.get((1,), 0) is not None
    assert c.purge_stale(current_version=1) == 1   # drops ((1,), 0)
    assert len(c) == 1 and c.get((3,), 1) is not None


def test_append_survives_compaction_failure():
    """Compaction is an optimization: if it dies, the append stays committed
    and the store keeps serving exact composed base+delta counts (an escaping
    error would look like a rejected batch and invite a double-count retry)."""
    rng = np.random.default_rng(55)
    tx = _db(rng, 80, 8)
    store = VersionedDB(tx, merge_ratio=0.01,   # any append triggers compact
                        min_compact_rows=0)

    def boom():
        raise MemoryError("simulated compactor OOM")

    store.compact = boom
    extra = _db(rng, 40, 8)
    v = store.append(extra)                     # must NOT raise
    assert v == 1 and store.delta_rows > 0
    assert store.stats()["failed_compactions"] == 1
    probes = [(0,), (1, 2)]
    np.testing.assert_array_equal(
        store.counts(probes), _fresh_counts(tx + extra, None, 1, probes))


def test_cache_byte_budget_eviction_and_stats():
    row = np.arange(4, dtype=np.int32)        # 16 bytes per entry
    c = CountCache(capacity=1000, max_bytes=3 * row.nbytes)
    for i in range(3):
        c.put((i,), 0, row)
    assert len(c) == 3 and c.nbytes == 3 * row.nbytes
    assert c.stats()["bytes"] == 3 * row.nbytes
    assert c.stats()["max_bytes"] == 3 * row.nbytes
    c.get((0,), 0)                            # (0,) now most-recent
    c.put((3,), 0, row)                       # over budget: evicts LRU (1,)
    assert len(c) == 3 and c.evictions == 1
    assert c.get((1,), 0) is None and c.get((0,), 0) is not None
    # replacing an entry re-accounts its bytes instead of double-counting
    c.put((0,), 0, row)
    assert c.nbytes == 3 * row.nbytes
    # purge updates the byte ledger too
    c.put((9,), 1, row)
    c.purge_stale(current_version=1)
    assert len(c) == 1 and c.nbytes == row.nbytes
    # the full shared invariants (byte recount, inserts-evictions-purged ==
    # size, budgets) — populated out-of-band, so not miss_driven
    check_cache_ledger(c)
    # an entry bigger than the whole budget cannot be admitted
    tight = CountCache(capacity=10, max_bytes=8)
    tight.put((1,), 0, row)
    assert len(tight) == 0 and tight.nbytes == 0
    assert check_cache_ledger(tight)["oversized_rejects"] == 1
    with pytest.raises(ValueError):
        CountCache(capacity=10, max_bytes=0)


def test_server_cache_bytes_budget():
    rng = np.random.default_rng(33)
    tx = _db(rng, 100, 10)
    srv = CountServer(tx, cache_bytes=4 * 4)  # room for four 1-class rows
    srv.query([(i,) for i in range(8)])
    assert len(srv.cache) == 4                # LRU kept only the budget
    assert srv.cache.nbytes <= 16
    assert srv.stats()["cache"]["bytes"] <= 16
    # serving follows get-miss-compute-put, so the full miss-driven ledger
    # identities hold on top of the budget checks
    assert check_cache_ledger(srv.cache, miss_driven=True)["evictions"] == 4
    # still exact: evicted probes recount on the engine
    np.testing.assert_array_equal(
        srv.query([(0,)]), _fresh_counts(tx, None, 1, [(0,)]))


def test_cache_invalidation_after_append_serves_fresh_counts():
    rng = np.random.default_rng(3)
    tx = _db(rng, 120, 8)
    srv = CountServer(tx)
    probes = [(0,), (1, 2)]
    before = srv.query(probes)
    launches = srv.store.kernel_launches
    again = srv.query(probes)                 # pure cache: no device work
    np.testing.assert_array_equal(again, before)
    assert srv.store.kernel_launches == launches
    assert srv.cache.hits == len(probes)

    batch = [[0, 1, 2]] * 10                  # changes every probe's count
    srv.append(batch)
    assert len(srv.cache) == 0                # stale entries purged eagerly
    after = srv.query(probes)                 # version bump: cache missed
    assert srv.store.kernel_launches > launches
    np.testing.assert_array_equal(
        after, _fresh_counts(tx + batch, None, 1, probes))
    assert (after != before).any()


# -------------------------------------------------------------- CountServer
def test_server_cross_client_dedup_bit_identical():
    """Acceptance: deduped cross-client answers == direct itemset_counts."""
    rng = np.random.default_rng(4)
    tx = _db(rng, 180, 12)
    y = [int(rng.random() < 0.4) for _ in tx]
    srv = CountServer(tx, classes=y, cache=False, block_k=8)
    t1 = srv.submit("a", [(0, 1), (2,), (1, 0)])
    t2 = srv.submit("b", [(0, 1), (5, 6, 7)])
    launches0 = srv.store.kernel_launches
    res = srv.flush()
    assert srv.store.kernel_launches == launches0 + 1   # ONE composed pass
    ddb = DenseDB.encode(tx, classes=y, n_classes=2)
    masks = encode_targets([(0, 1), (2,), (5, 6, 7)], ddb.vocab)
    want = np.asarray(itemset_counts(ddb.bits, jnp.asarray(masks),
                                     ddb.weights))
    np.testing.assert_array_equal(res[t1], want[[0, 1, 0]])
    np.testing.assert_array_equal(res[t2], want[[0, 2]])
    assert res[t1].dtype == np.int32


@pytest.mark.parametrize("streaming", [False, True])
def test_server_exact_vs_dense_gfp_counts_after_appends(streaming):
    """Acceptance: served counts == dense_gfp_counts at the same version,
    after ≥2 append batches, with the cache enabled."""
    rng = np.random.default_rng(5)
    tx = _db(rng, 150, 10)
    y = [int(rng.random() < 0.3) for _ in tx]
    srv = CountServer(tx, classes=y, streaming=streaming, chunk_rows=32,
                      merge_ratio=1e9)        # keep the delta segment live
    history, classes = list(tx), list(y)
    queries = [(0, 1), (2,), (4, 5, 6), (9,), (3, 8)]
    for step in range(2):
        batch = _db(rng, 50, 10)
        yb = [int(rng.random() < 0.3) for _ in batch]
        srv.append(batch, classes=yb)
        history += batch
        classes += yb
        srv.query(queries)                    # populate the cache mid-history
    assert srv.store.version == 2 and srv.store.delta_rows > 0
    got = srv.query(queries)                  # served (partly) from cache

    counts = {a: sum(1 for t in history if a in t) for a in range(10)}
    tis = TISTree(ItemOrder.from_counts(counts))
    for q in queries:
        tis.insert(list(q), target=True)
    want = dense_gfp_counts(tis, DenseDB.encode(history, classes=classes,
                                                n_classes=2))
    for i, q in enumerate(queries):
        np.testing.assert_array_equal(got[i], want[canonical_itemset(q)])
    oracle = brute_force_counts(history, queries)
    assert all(int(got[i].sum()) == oracle[canonical_itemset(q)]
               for i, q in enumerate(queries))


def test_server_interleaved_query_leaves_pending_requests_queued():
    """A query() between another client's submit() and flush() must neither
    orphan that client's ticket nor freeze its counts at an older version:
    the pending request stays queued and is answered at flush-time state."""
    rng = np.random.default_rng(11)
    tx = _db(rng, 90, 8)
    srv = CountServer(tx)
    ticket = srv.submit("a", [(0, 1), (2,)])
    got_q = srv.query([(3,)])                 # must NOT drain the batcher
    np.testing.assert_array_equal(got_q, _fresh_counts(tx, None, 1, [(3,)]))
    assert srv.batcher.pending == 1
    batch = [[0, 1, 2]] * 5
    srv.append(batch)                         # version bump BEFORE a's flush
    res = srv.flush()                         # a gets flush-time (v1) counts
    np.testing.assert_array_equal(
        res[ticket], _fresh_counts(tx + batch, None, 1, [(0, 1), (2,)]))
    assert srv.flush() == {}                  # delivered exactly once


def test_server_failed_flush_is_retryable(monkeypatch):
    """A counting-pass failure must not orphan drained tickets: the plan is
    restored to the batcher and a retried flush answers them."""
    rng = np.random.default_rng(12)
    tx = _db(rng, 60, 6)
    srv = CountServer(tx, cache=False)
    ticket = srv.submit("a", [(0, 1)])
    monkeypatch.setattr(srv.store, "counts_masks",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device lost")))
    with pytest.raises(RuntimeError, match="device lost"):
        srv.flush()
    assert srv.batcher.pending == 1           # request re-queued
    monkeypatch.undo()
    res = srv.flush()
    np.testing.assert_array_equal(
        res[ticket], _fresh_counts(tx, None, 1, [(0, 1)]))


def test_server_no_cache_and_empty_flush():
    rng = np.random.default_rng(6)
    srv = CountServer(_db(rng, 40, 6), cache=False)
    assert srv.cache is None
    assert srv.flush() == {}
    t = srv.submit("a", [])
    assert srv.flush()[t].shape == (0, 1)


# ---------------------------------------------- incremental mining satellite
def test_incremental_candidates_partition_and_completeness():
    prev = [(1,), (2,), (1, 2)]
    inc = [(2,), (3,), (2, 3)]
    previously, newly = incremental_candidates(prev, inc)
    assert previously == sorted(prev, key=repr)
    assert newly == [(2, 3), (3,)]            # repr-sorted, prev excluded
    assert not (set(previously) & set(newly))
    assert set(previously) | set(newly) == set(prev) | set(inc)
    assert incremental_candidates([], []) == ([], [])


def test_incremental_miner_state_lifecycle():
    m = IncrementalMiner(0.1)
    assert m.state is None
    with pytest.raises(RuntimeError, match="fit"):
        m.update([[1, 2]])
    with pytest.raises(RuntimeError, match="fit"):
        m.frequent
    with pytest.raises(RuntimeError, match="fit"):
        m.n_seen
    m.fit([[1, 2], [1], [2]])
    assert m.n_seen == 3
    assert m.frequent == m.state.frequent
    with pytest.raises(ValueError):
        IncrementalMiner(0.0)


def test_incremental_parity_host_vs_engine_recount():
    """Satellite parity: host IncrementalMiner (guided FP-tree recounts) ==
    CountServer engine-backed recount, across several append batches."""
    rng = np.random.default_rng(7)
    theta = 0.08
    tx = _db(rng, 250, 12, p=0.25)
    miner = IncrementalMiner(theta)
    srv = CountServer(tx, merge_ratio=1e9)    # delta path must stay exact too
    assert miner.fit(tx) == srv.mine(theta)
    for step in range(3):
        batch = _db(rng, 80, 12 + 2 * step, p=0.25)  # new items mid-stream
        want = miner.update(batch)
        srv.append(batch)
        assert srv.frequent == want, step
    # and equals a full re-mine of everything (host oracle)
    history = miner._require_state()          # sanity: state present
    assert history.n == srv.store.n_rows


def test_versioned_mine_frequent_matches_engines():
    rng = np.random.default_rng(8)
    tx = _db(rng, 200, 9, p=0.35)
    want = mine_frequent(tx, 40)
    store = VersionedDB(tx)
    assert versioned_mine_frequent(store, 40) == want
    assert dense_mine_frequent(DenseDB.encode(tx), 40) == want
    # still exact with an uncompacted delta in play
    store2 = VersionedDB(tx[:150], merge_ratio=1e9)
    store2.append(tx[150:])
    assert store2.delta_rows > 0
    assert versioned_mine_frequent(store2, 40) == want


def test_server_frequent_requires_mine():
    srv = CountServer([[1, 2]])
    with pytest.raises(RuntimeError, match="mine"):
        srv.frequent
    with pytest.raises(ValueError):
        srv.mine(0.0)


def test_server_mining_failures_disarm_incremental_maintenance(monkeypatch):
    """A failed mine() must not arm incremental maintenance, and a failed
    refresh during append() must disarm it: §5.2 completeness requires the
    previous EXACT frequent set, so stale baselines raise instead of serve."""
    rng = np.random.default_rng(13)
    tx = _db(rng, 80, 6)
    srv = CountServer(tx)
    import repro.serve.service as service_mod
    monkeypatch.setattr(service_mod, "versioned_mine_frequent",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device lost")))
    with pytest.raises(RuntimeError, match="device lost"):
        srv.mine(0.1)
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="mine"):
        srv.frequent                          # mine never succeeded
    srv.append([[0, 1]])                      # and appends don't refresh

    want = srv.mine(0.1)
    assert srv.frequent == want
    from repro.serve import MiningRefreshError
    monkeypatch.setattr(srv.store, "counts",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device lost")))
    with pytest.raises(MiningRefreshError, match="do not retry") as ei:
        srv.append([[0, 1, 2]] * 5)
    monkeypatch.undo()
    assert ei.value.version == srv.store.version  # batch WAS committed
    with pytest.raises(RuntimeError, match="mine"):
        srv.frequent                          # stale baseline disarmed


# ------------------------------------------------- serving-path bug sweep
def test_cache_oversized_put_rejected_without_eviction():
    """Regression: a put larger than max_bytes used to evict EVERY resident
    entry before the oversized entry itself was dropped — one oversized row
    nuked a warm cache.  It must be rejected up front, counted separately."""
    row = np.arange(4, dtype=np.int32)            # 16 bytes
    c = CountCache(capacity=10, max_bytes=4 * row.nbytes)
    for i in range(4):
        c.put((i,), 0, row)
    big = np.arange(64, dtype=np.int32)           # 256 bytes > budget
    c.put((99,), 0, big)
    assert len(c) == 4 and c.nbytes == 4 * row.nbytes   # warm set intact
    assert c.evictions == 0
    assert c.oversized_rejects == 1
    assert c.stats()["oversized_rejects"] == 1
    assert c.get((99,), 0) is None                # never admitted
    for i in range(4):                            # every resident row hits
        assert c.get((i,), 0) is not None
    # replacing a resident key with an oversized value keeps the (still
    # correct: same key+version = same counts) resident entry
    c.put((0,), 0, big)
    assert c.get((0,), 0) is not None and c.oversized_rejects == 2


def test_batcher_restore_rolls_back_dedup_stats():
    """Regression: a failed flush's restore() kept take()'s n_deduped
    increments, so the re-take double-counted every dedup."""
    b = MicroBatcher(block_k=8)
    b.submit("a", [(1, 2), (2, 1), (3,)])         # (2,1) dedups onto (1,2)
    b.submit("b", [(1, 2)])                       # cross-client dedup
    plan = b.take()
    assert b.n_deduped == 2
    b.restore(plan.requests)
    assert b.n_deduped == 0                       # rolled back exactly
    b.take()
    assert b.n_deduped == 2                       # retry counts once, not 4
    assert b.stats()["requests"] == 2 and b.stats()["queries"] == 4


def test_server_retried_flush_reports_exact_dedup_stats(monkeypatch):
    rng = np.random.default_rng(20)
    srv = CountServer(_db(rng, 60, 6), cache=False)
    srv.submit("a", [(0, 1), (1, 0)])             # one in-request dedup
    srv.submit("b", [(0, 1)])                     # one cross-client dedup
    monkeypatch.setattr(srv.store, "counts_masks",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("device lost")))
    with pytest.raises(RuntimeError, match="device lost"):
        srv.flush()
    assert srv.batcher.stats()["deduped"] == 0    # failed take rolled back
    monkeypatch.undo()
    srv.flush()
    assert srv.batcher.stats()["deduped"] == 2    # exact after the retry


def test_store_class_label_validation_no_trace():
    """Regression: out-of-range labels must raise the documented no-trace
    ValueError at the store boundary, for construction AND append."""
    rng = np.random.default_rng(21)
    tx = _db(rng, 40, 6)
    with pytest.raises(ValueError, match="negative"):
        VersionedDB(tx, classes=[-1] * len(tx))
    with pytest.raises(ValueError, match="out of range"):
        VersionedDB(tx, classes=[3] * len(tx), n_classes=2)
    with pytest.raises(ValueError, match="n_classes"):
        VersionedDB(tx, classes=[0] * len(tx), n_classes=-2)
    with pytest.raises(ValueError, match="integer"):
        VersionedDB(tx, classes=[0.5] * len(tx), n_classes=2)

    y = [int(rng.random() < 0.5) for _ in tx]
    db = VersionedDB(tx, classes=y, n_classes=2)
    vocab_before, totals_before = db.vocab, db._class_totals.copy()
    for bad in ([-1], [2], [0.5]):
        with pytest.raises(ValueError):
            db.append([[0, "new-item"]], classes=bad)
    assert db.version == 0 and db.n_rows == len(tx)
    assert db.vocab is vocab_before and "new-item" not in db.vocab
    np.testing.assert_array_equal(db._class_totals, totals_before)
    assert db.delta_rows == 0                     # no delta segment appeared

    # the sharded store rejects with no trace on ANY shard either
    sh = ShardedDB(tx, classes=y, n_classes=2, n_shards=2)
    with pytest.raises(ValueError):
        sh.append([[0, "new-item"]], classes=[5])
    assert sh.version == 0 and "new-item" not in sh.vocab
    assert all(s.version == 0 for s in sh.shards)
    # length-mismatched labels rejected at construction (surplus labels
    # would otherwise silently drop after widening n_classes; short lists
    # would IndexError mid-partition)
    with pytest.raises(ValueError, match="length"):
        ShardedDB(tx, classes=y + [3], n_shards=2)
    with pytest.raises(ValueError, match="length"):
        ShardedDB(tx, classes=y[:-1], n_shards=2)
    with pytest.raises(ValueError, match="length"):
        sh.append([[0], [1]], classes=[0])


def test_empty_store_chunk_accounting_and_kill_resume(tmp_path):
    """Regression: an empty store claimed a 1-chunk grid but never fired
    on_chunk, so a checkpointed mine recorded zero chunk progress — the
    (trivially exact) sweep must complete its claimed chunk."""
    store = VersionedDB(vocab=ItemVocab((0, 1, 2)))
    backend = VersionedCountBackend(store)
    assert backend.n_count_chunks == 1
    fired = []
    got = backend.counts(np.zeros((2, 1), np.uint32),
                         on_chunk=lambda i, acc: fired.append(i))
    assert fired == [0]                           # grid and progress agree
    np.testing.assert_array_equal(got, 0)

    ckpt = MiningCheckpoint(str(tmp_path / "empty.json"))

    def die(level, chunk):
        raise _Preempted()

    with pytest.raises(_Preempted):
        versioned_mine_frequent(store, 1, checkpoint=ckpt, on_chunk=die)
    state = json.load(open(str(tmp_path / "empty.json")))
    assert state["partial"]["next_chunk"] == 1    # == n_count_chunks
    resumed = []
    got = versioned_mine_frequent(store, 1, checkpoint=ckpt,
                                  on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == {} and resumed == []            # level 1 resumed, no recount


# ------------------------------------------------------------ sharded store
def test_sharded_vs_single_device_parity_interleaved():
    """Acceptance: sharded counts bit-identical to the single-device
    VersionedDB at EVERY version across ≥3 interleaved append/flush rounds
    (vocab-widening batches, live deltas, unknown-item probes)."""
    rng = np.random.default_rng(30)
    tx = _db(rng, 180, 10)
    y = [int(rng.random() < 0.4) for _ in tx]
    single = VersionedDB(tx, classes=y, n_classes=2, merge_ratio=1e9)
    sharded = ShardedDB(tx, classes=y, n_classes=2, n_shards=3,
                        merge_ratio=1e9)
    assert sharded.n_rows == single.n_rows == len(tx)
    probes = [(0, 1), (2,), (3, 7, 9), (11,), ("nope",), (0, 12)]
    np.testing.assert_array_equal(single.counts(probes),
                                  sharded.counts(probes))
    history, classes = list(tx), list(y)
    for step in range(1, 4):
        batch = _db(rng, 50, 10 + step)           # widens the item universe
        yb = [int(rng.random() < 0.4) for _ in batch]
        assert single.append(batch, classes=yb) == step
        assert sharded.append(batch, classes=yb) == step
        history += batch
        classes += yb
        got = sharded.counts(probes)
        np.testing.assert_array_equal(got, single.counts(probes))
        np.testing.assert_array_equal(
            got, _fresh_counts(history, classes, 2, probes))
    assert sharded.delta_rows > 0                 # deltas genuinely in play
    assert max(s.n_rows for s in sharded.shards) \
        - min(s.n_rows for s in sharded.shards) <= len(batch)
    sharded.compact()                             # counts unchanged
    assert sharded.delta_rows == 0 and sharded.version == 3
    np.testing.assert_array_equal(sharded.counts(probes),
                                  single.counts(probes))
    with pytest.raises(ValueError):
        ShardedDB(tx, n_shards=0)


def test_sharded_append_routes_to_least_loaded_shard():
    rng = np.random.default_rng(31)
    sh = ShardedDB(_db(rng, 90, 8), n_shards=3)
    rows_before = [s.n_rows for s in sh.shards]
    target = min(range(3), key=lambda i: rows_before[i])
    sh.append(_db(rng, 10, 8))
    rows_after = [s.n_rows for s in sh.shards]
    assert rows_after[target] == rows_before[target] + 10
    assert sum(rows_after) == sum(rows_before) + 10


def test_sharded_mine_parity_kill_resume_and_stale_version(tmp_path):
    rng = np.random.default_rng(32)
    tx = _db(rng, 240, 10, p=0.4)
    store = ShardedDB(tx, n_shards=3)
    backend = ShardedCountBackend(store)
    assert backend.n_count_chunks == 3            # one chunk per shard
    want = mine_frequent(tx, 40)
    assert versioned_mine_frequent(store, 40) == want

    ckpt = MiningCheckpoint(str(tmp_path / "sharded.json"))

    def die_mid_level_2(level, chunk):
        if level == 2 and chunk == 1:
            raise _Preempted()                    # mid shard sweep

    with pytest.raises(_Preempted):
        versioned_mine_frequent(store, 40, checkpoint=ckpt,
                                on_chunk=die_mid_level_2)
    state = json.load(open(str(tmp_path / "sharded.json")))
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 2
    assert state["partial"]["n_shards"] == 3      # shard grid in signature
    # meta = backend identity + the MINING-PARAMETER identity (a checkpoint
    # must not answer a resume with a different threshold/class/cap)
    assert state["meta"] == {"version": 0, "n_shards": 3,
                             "min_count": 40.0, "class_column": None,
                             "max_len": 0}

    resumed = []
    got = versioned_mine_frequent(
        store, 40, checkpoint=ckpt,
        on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0] == (2, 2)                   # resumed at shard chunk 2

    extra = _db(rng, 100, 10, p=0.6)              # denser: counts shift
    store.append(extra)
    got = versioned_mine_frequent(store, 40, checkpoint=ckpt)
    assert got == mine_frequent(tx + extra, 40)   # stale checkpoint discarded


def test_sharded_server_end_to_end(monkeypatch):
    """CountServer(shards=): submit/flush/query/append/mine/frequent all run
    unchanged over the sharded store, exactly."""
    rng = np.random.default_rng(33)
    tx = _db(rng, 200, 10, p=0.3)
    y = [int(rng.random() < 0.4) for _ in tx]
    srv = CountServer(tx, classes=y, shards=2, block_k=8)
    plain = CountServer(tx, classes=y, block_k=8)
    t1 = srv.submit("a", [(0, 1), (2,), (1, 0)])
    res = srv.flush()
    want = plain.query([(0, 1), (2,), (1, 0)])
    np.testing.assert_array_equal(res[t1], want)

    theta = 0.12
    assert srv.mine(theta) == plain.mine(theta)
    batch = _db(rng, 60, 12, p=0.3)
    yb = [int(rng.random() < 0.4) for _ in batch]
    srv.append(batch, classes=yb)
    plain.append(batch, classes=yb)
    assert srv.frequent == plain.frequent         # §5.2 maintenance parity
    np.testing.assert_array_equal(srv.query([(0, 1), (11,)]),
                                  plain.query([(0, 1), (11,)]))
    with pytest.raises(ValueError, match="shards"):
        CountServer(tx, mesh=object())


# ------------------------------------------------------------- async flush
def test_async_occupancy_and_deadline_triggers():
    rng = np.random.default_rng(40)
    tx = _db(rng, 80, 8)
    srv = CountServer(tx, async_flush=True, max_delay_ms=40, min_batch=4)
    try:
        futs = [srv.submit_async(f"c{i}", [(0, 1), (2,)]) for i in range(4)]
        results = [f.result(timeout=15) for f in futs]   # occupancy fires
        want = _fresh_counts(tx, None, 1, [(0, 1), (2,)])
        for got in results:
            np.testing.assert_array_equal(got, want)
        lone = srv.submit_async("lone", [(3,)])          # below min_batch
        np.testing.assert_array_equal(lone.result(timeout=15),
                                      _fresh_counts(tx, None, 1, [(3,)]))
        st = srv.stats()["async"]
        assert st["flushes"] >= 2 and st["pending_tickets"] == 0
        assert st["by_trigger"]["deadline"] >= 1         # the lone ticket
    finally:
        srv.close()
    assert srv.stats()["async"]["closed"]
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit_async("late", [(0,)])
    # the server stays usable synchronously after close
    np.testing.assert_array_equal(srv.query([(3,)]),
                                  _fresh_counts(tx, None, 1, [(3,)]))


def test_async_close_drains_pending_tickets():
    """Acceptance: close() never orphans a submitted ticket — triggers that
    would never fire (huge min_batch, long deadline) still get answered by
    the close() drain."""
    rng = np.random.default_rng(41)
    tx = _db(rng, 60, 6)
    srv = CountServer(tx, async_flush=True, max_delay_ms=60_000,
                      min_batch=10_000)
    futs = [srv.submit_async(f"c{i}", [(0,), (1, 2)]) for i in range(3)]
    assert not any(f.done() for f in futs)
    srv.close()
    want = _fresh_counts(tx, None, 1, [(0,), (1, 2)])
    for f in futs:
        assert f.done()
        np.testing.assert_array_equal(f.result(timeout=1), want)
    assert srv.stats()["async"]["by_trigger"]["drain"] == 1


def test_async_failed_flush_retries_then_answers():
    rng = np.random.default_rng(42)
    tx = _db(rng, 60, 6)
    srv = CountServer(tx, cache=False, async_flush=True, max_delay_ms=30,
                      min_batch=1)
    calls = {"n": 0}
    orig = srv.store.counts_masks

    def flaky(*a, **k):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient device loss")
        return orig(*a, **k)

    srv.store.counts_masks = flaky
    try:
        fut = srv.submit_async("a", [(0, 1)])
        np.testing.assert_array_equal(fut.result(timeout=15),
                                      _fresh_counts(tx, None, 1, [(0, 1)]))
        assert srv.stats()["async"]["flush_errors"] >= 1
    finally:
        srv.close()


def test_async_close_with_failing_store_raises_on_futures():
    rng = np.random.default_rng(43)
    tx = _db(rng, 40, 6)
    srv = CountServer(tx, cache=False, async_flush=True, max_delay_ms=60_000,
                      min_batch=10_000)
    srv.store.counts_masks = \
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("dead device"))
    fut = srv.submit_async("a", [(0,)])
    with pytest.raises(RuntimeError, match="dead device"):
        srv.close()
    assert fut.done()
    with pytest.raises(RuntimeError, match="dead device"):
        fut.result(timeout=1)


def test_async_background_flush_preserves_sync_tickets():
    """Regression: a synchronously submitted ticket drained by a BACKGROUND
    flush must not vanish — the next explicit flush() hands it back."""
    rng = np.random.default_rng(44)
    tx = _db(rng, 60, 6)
    srv = CountServer(tx, async_flush=True, max_delay_ms=20, min_batch=2)
    try:
        t = srv.submit("sync", [(0, 1)])          # plain sync ticket
        fut = srv.submit_async("async", [(2,)])   # fills min_batch: bg flush
        fut.result(timeout=15)                    # ... drained BOTH tickets
        assert srv.stats()["async"]["unclaimed_sync_tickets"] == 1
        out = srv.flush()                         # sync ticket handed back
        np.testing.assert_array_equal(
            out[t], _fresh_counts(tx, None, 1, [(0, 1)]))
        assert srv.stats()["async"]["unclaimed_sync_tickets"] == 0
    finally:
        srv.close()


def test_async_future_result_is_a_private_copy():
    """A manual flush() answering an async ticket returns the block to its
    own caller too — the future must hold an independent copy."""
    rng = np.random.default_rng(45)
    tx = _db(rng, 50, 6)
    srv = CountServer(tx, async_flush=True, max_delay_ms=60_000,
                      min_batch=10_000)
    try:
        fut = srv.submit_async("a", [(0, 1)])
        out = srv.flush()                     # manual flush answers it
        out[fut.ticket][:] = -7               # flush caller mutates its rows
        np.testing.assert_array_equal(fut.result(timeout=1),
                                      _fresh_counts(tx, None, 1, [(0, 1)]))
    finally:
        srv.close()


def test_submit_async_requires_async_flush():
    srv = CountServer([[1, 2]])
    with pytest.raises(RuntimeError, match="async_flush"):
        srv.submit_async("a", [(1,)])


# ------------------------------------------------------- sharded mesh path
def test_sharded_mesh_single_device_parity():
    """Mesh (1,) path runs in-process: the fused psum launch over the stacked
    resident placement matches the host all-reduce loop bit-identically."""
    import jax

    rng = np.random.default_rng(50)
    tx = _db(rng, 150, 40)
    y = [int(rng.random() < 0.4) for _ in tx]
    mesh = jax.make_mesh((1,), ("data",))
    meshed = ShardedDB(tx, classes=y, n_classes=2, n_shards=2, mesh=mesh,
                       merge_ratio=1e9)
    hosted = ShardedDB(tx, classes=y, n_classes=2, n_shards=2,
                       merge_ratio=1e9)
    probes = [(0, 1), (2,), (3, 7, 39), (44,)]
    np.testing.assert_array_equal(meshed.counts(probes),
                                  hosted.counts(probes))
    batch = [[int(a) for a in range(100, 125)] for _ in range(5)]  # widens W
    meshed.append(batch, classes=[0] * 5)
    hosted.append(batch, classes=[0] * 5)
    probes += [(104,), (0, 104)]
    got = meshed.counts(probes)
    np.testing.assert_array_equal(got, hosted.counts(probes))
    np.testing.assert_array_equal(
        got, _fresh_counts(tx + batch, y + [0] * 5, 2, probes))
    assert meshed.stats()["mesh"] == {"data": 1}


MESH_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core import mine_frequent
from repro.serve import CountServer, ShardedDB, VersionedDB

rng = np.random.default_rng(51)
def _db(rows, items, p=0.3):
    return [[int(a) for a in range(items) if rng.random() < p]
            for _ in range(rows)]

tx = _db(400, 40)
y = [int(rng.random() < 0.4) for _ in tx]
mesh = jax.make_mesh((4,), ("data",))
single = VersionedDB(tx, classes=y, n_classes=2, merge_ratio=1e9)
sharded = ShardedDB(tx, classes=y, n_classes=2, n_shards=4, mesh=mesh,
                    merge_ratio=1e9)
probes = [(0, 1), (2,), (3, 7, 39), (11,)]
np.testing.assert_array_equal(single.counts(probes), sharded.counts(probes))
for step in range(1, 4):                 # interleaved appends + queries
    batch = _db(80, 40 + 30 * step)      # widens past word boundaries
    yb = [int(rng.random() < 0.4) for _ in batch]
    assert single.append(batch, classes=yb) == step
    assert sharded.append(batch, classes=yb) == step
    p2 = probes + [(41,), (0, 45)]
    np.testing.assert_array_equal(single.counts(p2), sharded.counts(p2))

srv = CountServer(tx, classes=y, shards=4, mesh=mesh)
freq = srv.mine(0.15)
from repro.core.incremental import ceil_count
assert freq == mine_frequent(tx, ceil_count(0.15 * len(tx)))
print(json.dumps({"ok": True, "launches": sharded.kernel_launches}))
"""


@pytest.mark.slow
def test_sharded_mesh_multidevice_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", MESH_SHARD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["launches"] > 0


# ----------------------------------------------------------------- launcher
def test_serve_counts_launcher_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_counts", "--rows", "600",
         "--items", "16", "--rounds", "3", "--batch", "8", "--appends", "1",
         "--append-rows", "100", "--pool", "32", "--theta", "0.1",
         "--verify"],
        env=env, capture_output=True, text=True, timeout=300, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "verified" in proc.stdout and "us/query" in proc.stdout
