"""Pipeline parallelism: GPipe schedule == sequential execution (multi-device
subprocess; the main process keeps 1 device)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.parallel.pipeline import pipeline_forward, split_stages

S, L, M, MB, D = 4, 8, 6, 4, 16
mesh = jax.make_mesh((S, 2), ("stage", "data"))
key = jax.random.key(0)
k1, k2, k3 = jax.random.split(key, 3)
w = jax.random.normal(k1, (L, D, D)) * 0.3
b = jax.random.normal(k2, (L, D)) * 0.1
x = jax.random.normal(k3, (M, MB, D))

def layer(w_l, b_l, h):
    return jnp.tanh(h @ w_l + b_l)

def stage_body(params, h):
    sw, sb = params
    for i in range(sw.shape[0]):
        h = layer(sw[i], sb[i], h)
    return h

# sequential reference
ref = x
for i in range(L):
    ref = layer(w[i], b[i], ref)

stages = split_stages((w, b), S)
out = pipeline_forward(stages, x, stage_body, mesh)
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err

# utilization sanity: schedule length is M + S - 1 ticks (structural)
print(json.dumps({"ok": True, "err": err}))
"""


@pytest.mark.slow
def test_pipeline_equals_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["err"] < 1e-5
