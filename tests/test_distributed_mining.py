"""Distributed mining: multi-device correctness via a subprocess (the main
test process must keep seeing exactly 1 CPU device; jax locks device count at
first init, so multi-device runs get their own interpreter)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import TISTree, ItemOrder, brute_force_counts, mine_frequent
from repro.mining import ItemVocab, class_weights, encode_bitmap
from repro.mining.distributed import DistributedMiner, MiningCheckpoint, distributed_counts

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(7)
M, N = 18, 500
db = [[i for i in range(M) if rng.random() < 0.3] for _ in range(N)]
y = rng.integers(0, 2, N)
vocab = ItemVocab.from_transactions(db)
bits = encode_bitmap(db, vocab)
w = class_weights(y, 2)

# --- distributed counts == brute force -------------------------------------
targets = [[0, 1], [2], [3, 4, 5], [1, 7], [9], [2, 11, 13]]
targets = [[a for a in t if a in vocab] for t in targets]
from repro.mining import encode_targets
rows = distributed_counts(bits, encode_targets(targets, vocab), w, mesh)
db0 = [t for t, c in zip(db, y) if c == 0]
db1 = [t for t, c in zip(db, y) if c == 1]
for t, row in zip(targets, rows):
    key = tuple(sorted(set(t), key=repr))
    assert row[0] == brute_force_counts(db0, [t])[key], (t, row)
    assert row[1] == brute_force_counts(db1, [t])[key], (t, row)

# --- distributed level mining == host FP-growth -----------------------------
miner = DistributedMiner(mesh)
got = miner.mine_frequent(bits, np.ones((N, 1), np.int32), vocab, min_count=60)
want = mine_frequent(db, 60)
assert got == want, (len(got), len(want))

# --- checkpoint/restart: kill after level 2, resume, same answer ------------
ckpt_path = os.environ["CKPT_PATH"]
ck = MiningCheckpoint(ckpt_path)
m2 = DistributedMiner(mesh, checkpoint=ck)
# simulate partial run: run levels manually by max_len=2 then 'crash'
m2.mine_frequent(bits, np.ones((N, 1), np.int32), vocab, min_count=60, max_len=2)
# resume with a DIFFERENT mesh shape (elastic restart)
mesh2 = jax.make_mesh((8,), ("data",))
m3 = DistributedMiner(mesh2, model_axis=None, checkpoint=ck)
got2 = m3.mine_frequent(bits, np.ones((N, 1), np.int32), vocab, min_count=60)
assert got2 == want, (len(got2), len(want))

print(json.dumps({"ok": True, "n_frequent": len(want)}))
"""


@pytest.mark.slow
def test_distributed_mining_multidevice(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["CKPT_PATH"] = str(tmp_path / "mine.ckpt.json")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["n_frequent"] > 0


def test_distributed_single_device_mesh():
    """Mesh (1,1) path runs in-process (no device-count games needed)."""
    import jax
    from repro.core import mine_frequent
    from repro.mining import ItemVocab, encode_bitmap
    from repro.mining.distributed import DistributedMiner

    rng = np.random.default_rng(3)
    M, N = 12, 200
    db = [[i for i in range(M) if rng.random() < 0.35] for _ in range(N)]
    vocab = ItemVocab.from_transactions(db)
    bits = encode_bitmap(db, vocab)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    miner = DistributedMiner(mesh)
    got = miner.mine_frequent(bits, np.ones((N, 1), np.int32), vocab, min_count=30)
    assert got == mine_frequent(db, 30)


def test_distributed_chunked_mid_level_kill_resume(tmp_path):
    """chunk_rows threads the N-axis sweep through the driver's chunk hooks:
    a mesh mine checkpoints MID-level (per host chunk) and a resume skips
    every counted chunk.  In-process over a (1,1) mesh — the chunk plumbing
    is mesh-shape independent (the multi-device variant runs under
    --runslow)."""
    import jax
    from repro.core import mine_frequent
    from repro.mining import ItemVocab, encode_bitmap
    from repro.mining.distributed import DistributedMiner, MiningCheckpoint

    rng = np.random.default_rng(11)
    M, N = 12, 600
    db = [[i for i in range(M) if rng.random() < 0.5] for _ in range(N)]
    want = mine_frequent(db, 50)
    assert max(len(k) for k in want) >= 3      # levels after the kill
    vocab = ItemVocab.from_transactions(db)
    bits = encode_bitmap(db, vocab)
    w = np.ones((N, 1), np.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    ckpt = MiningCheckpoint(str(tmp_path / "chunked.json"))
    miner = DistributedMiner(mesh, checkpoint=ckpt, chunk_rows=150)
    backend = miner.backend(bits, w, vocab)
    assert backend.n_count_chunks == 4         # 600 rows / 150
    assert backend.chunk_signature()["chunk_rows"] == 150

    class _Preempted(Exception):
        pass

    def die_mid_level_2(level, chunk):
        if level == 2 and chunk == 1:
            raise _Preempted()                 # 2 of 4 chunks counted

    with pytest.raises(_Preempted):
        miner.mine_frequent(bits, w, vocab, 50, on_chunk=die_mid_level_2)
    state = json.load(open(str(tmp_path / "chunked.json")))
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 2
    assert state["partial"]["backend"] == "distributed"
    assert state["partial"]["chunk_rows"] == 150

    resumed = []
    got = miner.mine_frequent(bits, w, vocab, 50,
                              on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0] == (2, 2)                # resumed mid-level, chunk 2

    # a changed chunk geometry restarts the in-flight level from chunk 0
    # (signature mismatch), still exact
    ckpt2 = MiningCheckpoint(str(tmp_path / "regeo.json"))
    with pytest.raises(_Preempted):
        DistributedMiner(mesh, checkpoint=ckpt2, chunk_rows=150).mine_frequent(
            bits, w, vocab, 50, on_chunk=die_mid_level_2)
    other = DistributedMiner(mesh, checkpoint=ckpt2, chunk_rows=200)
    regeo = []
    got2 = other.mine_frequent(bits, w, vocab, 50,
                               on_chunk=lambda l, c: regeo.append((l, c)))
    assert got2 == want
    assert regeo[0] == (2, 0)


CHUNKED_KILL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax

from repro.core import mine_frequent
from repro.mining import ItemVocab, encode_bitmap
from repro.mining.distributed import DistributedMiner, MiningCheckpoint

assert len(jax.devices()) == 8, jax.devices()
mesh = jax.make_mesh((4, 2), ("data", "model"))

rng = np.random.default_rng(13)
M, N = 14, 600
db = [[i for i in range(M) if rng.random() < 0.5] for _ in range(N)]
vocab = ItemVocab.from_transactions(db)
bits = encode_bitmap(db, vocab)
w = np.ones((N, 1), np.int32)

ck = MiningCheckpoint(os.environ["CKPT_PATH"])
miner = DistributedMiner(mesh, checkpoint=ck, chunk_rows=150)

if os.environ["PHASE"] == "kill":
    def die(level, chunk):
        if level == 2 and chunk == 1:
            os._exit(17)    # hard kill mid-level: no cleanup, no atexit
    miner.mine_frequent(bits, w, vocab, 60, on_chunk=die)
    raise SystemExit("kill hook never fired")

resumed = []
got = miner.mine_frequent(bits, w, vocab, 60,
                          on_chunk=lambda l, c: resumed.append((l, c)))
want = mine_frequent(db, 60)
assert got == want, (len(got), len(want))
assert tuple(resumed[0]) == (2, 2), resumed[:3]
print(json.dumps({"ok": True, "first_resumed": list(resumed[0]),
                  "n_frequent": len(got)}))
"""


@pytest.mark.slow
def test_distributed_chunked_kill_resume_subprocess(tmp_path):
    """Two-process kill/resume on a real 8-device mesh: the first process is
    hard-killed (os._exit) mid-level-2 of a chunked sweep; the second resumes
    from the durable checkpoint at the exact next chunk."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["CKPT_PATH"] = str(tmp_path / "chunked.ckpt.json")
    env.pop("XLA_FLAGS", None)

    env["PHASE"] = "kill"
    proc = subprocess.run([sys.executable, "-c", CHUNKED_KILL_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 17, (proc.returncode, proc.stderr[-4000:])
    state = json.load(open(env["CKPT_PATH"]))
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 2

    env["PHASE"] = "resume"
    proc = subprocess.run([sys.executable, "-c", CHUNKED_KILL_SCRIPT],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["first_resumed"] == [2, 2]
    assert out["n_frequent"] > 0
