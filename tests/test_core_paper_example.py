"""Golden tests: the paper's §4.2 worked example, verified number-for-number."""
import math

import pytest

from repro.core import (
    FPTree, ItemOrder, TISTree, brute_force_counts, fp_growth_into_tis,
    full_fpgrowth_rules, gfp_growth, mine_frequent, minority_report,
)

# Table 1 of the paper.
DB = [
    (list("facdgimp"), 0),   # TID 100
    (list("abcflmo"), 0),    # TID 200
    (list("bfhjo"), 0),      # TID 300
    (list("bcksp"), 0),      # TID 400
    (list("afcelpmn"), 0),   # TID 500
    (list("fm"), 1),         # TID 600
    (list("c"), 1),          # TID 700
    (list("b"), 1),          # TID 800
]
TX = [t for t, _ in DB]
Y = [y for _, y in DB]


def test_first_pass_item_selection():
    res = minority_report(TX, Y, min_support=0.125, min_confidence=0.2)
    assert sorted(res.items_kept) == ["b", "c", "f", "m"]


def test_tis_counts_match_paper():
    res = minority_report(TX, Y, min_support=0.125, min_confidence=0.2)
    # C1 counts (paper Figure 3): f:1 c:1 b:1 m:1 and {m,f}:1
    c1 = res.tis.as_dict("count")
    assert c1 == {("f",): 1, ("c",): 1, ("b",): 1, ("m",): 1, ("f", "m"): 1}
    # g-counts after GFP (paper Figure 4 / §4.2 walk-through):
    g = res.tis.as_dict("g_count")
    assert g == {("m",): 3, ("b",): 3, ("c",): 4, ("f",): 4, ("f", "m"): 3}


def test_rules_and_confidences_match_paper():
    res = minority_report(TX, Y, min_support=0.125, min_confidence=0.2)
    conf = {r.antecedent: r.confidence for r in res.rules}
    assert conf[("m",)] == pytest.approx(0.25)
    assert conf[("b",)] == pytest.approx(0.25)
    assert conf[("c",)] == pytest.approx(0.2)
    assert conf[("f",)] == pytest.approx(0.2)
    assert conf[("f", "m")] == pytest.approx(0.25)  # 1/(1+3)
    # all five rules reported, nothing else
    assert len(res.rules) == 5
    # support values: count / |DB| = 1/8
    for r in res.rules:
        assert r.support == pytest.approx(0.125)


def test_paper_reports_mf_confidence_erratum():
    """Paper §4.2 lists Confidence(m,f)=1/(1+4)=0.2 but its own Figure 4 shows
    g-count({m,f})=3 (the walk-through sets TIS-tree({m,f}).g-count = 3), which
    gives 1/(1+3)=0.25.  Brute force agrees with 3: transactions containing
    {m,f} in class 0 are TIDs 100, 200, 500.  We assert the exact value."""
    oracle = brute_force_counts([t for t, y in DB if y == 0], [("m", "f")])
    assert oracle[("f", "m")] == 3


def test_gfp_counts_equal_bruteforce_on_example():
    res = minority_report(TX, Y, min_support=0.125, min_confidence=0.2)
    db0 = [t for t, y in DB if y == 0]
    targets = list(res.tis.as_dict("g_count").keys())
    oracle = brute_force_counts(db0, targets)
    assert res.tis.as_dict("g_count") == oracle


def test_full_fpgrowth_baseline_agrees():
    mra = minority_report(TX, Y, min_support=0.125, min_confidence=0.2)
    base = full_fpgrowth_rules(TX, Y, min_support=0.125, min_confidence=0.2)
    mra_map = {r.antecedent: (r.count, r.g_count) for r in mra.rules}
    base_map = {r.antecedent: (r.count, r.g_count) for r in base}
    assert mra_map == base_map


def test_fp_tree_structure_of_fp1():
    """FP1 (Figure 1): three single-node branches f,c,b — plus m under f."""
    res = minority_report(TX, Y, min_support=0.125, min_confidence=0.2)
    # rebuild FP1 as MRA does
    order = res.order
    fp1 = FPTree(order)
    for t, y in DB:
        if y == 1:
            fp1.insert(order.sort_transaction(t))
    assert set(fp1.root.children) == {"f", "c", "b"}
    f_node = fp1.root.children["f"]
    assert f_node.count == 1 and set(f_node.children) == {"m"}


def test_header_linked_list_sums():
    order = ItemOrder(["f", "c", "b", "m"])
    fp0 = FPTree(order)
    for t, y in DB:
        if y == 0:
            fp0.insert(order.sort_transaction(t))
    for item in "fcbm":
        assert fp0.item_count(item) == fp0.item_count_via_links(item)
    assert fp0.item_count("f") == 4 and fp0.item_count("c") == 4
    assert fp0.item_count("b") == 3 and fp0.item_count("m") == 3
