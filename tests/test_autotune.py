"""Roofline-driven autotuner battery: geometry buckets, tuning-table
persistence + schema validation, the resolve seam, config-invariance
(bit-exactness across the whole candidate lattice on every counting path),
derived chooser thresholds, staleness feedback, and telemetry exposure.
"""
import json
import os

import numpy as np
import pytest

from repro import obs
from repro.mining.dense import DenseDB, dense_mine_frequent
from repro.mining.gfp_backend import GFPBackend
from repro.mining.plan import choose_chunk_rows
from repro.mining.stream import StreamingDB, streaming_counts
from repro.roofline import autotune
from repro.roofline.autotune import (ACCUM_LATTICE, BLOCK_K_LATTICE,
                                     DEFAULT_ACCUM, DEFAULT_BLOCK_K,
                                     DEFAULT_BLOCK_N, LaunchConfig,
                                     TableEntry, TableError, TuningTable,
                                     load_table, resolve_launch_config,
                                     save_table, table_from_dict,
                                     table_to_dict)
from repro.roofline.kernel_model import (GEOMETRY_OVERFLOW,
                                         MAX_GEOMETRY_BUCKETS,
                                         _reset_geometry_buckets,
                                         _SEEN_BUCKETS, bucket_shape,
                                         geometry_bucket, record_launch)

from _pbt import given, settings, strategies as st


def _mk_table(entries, kind="cpu", source="<test>"):
    return TuningTable(device_kind=kind, entries=entries, source=source)


def _entry(block_k=128, accum="vpu_int32", chunk_rows=None, us=100.0,
           efficiency=0.5, candidates=None, chunk_candidates=None,
           serve_block_k=None):
    return TableEntry(
        config=LaunchConfig(block_k=block_k, block_n=DEFAULT_BLOCK_N,
                            accum=accum, chunk_rows=chunk_rows,
                            source="table"),
        us=us, efficiency=efficiency, candidates=candidates or {},
        chunk_candidates=chunk_candidates or {},
        serve_block_k=serve_block_k)


def _small_db(seed=0, rows=300, items=10):
    rng = np.random.default_rng(seed)
    tx = [list(np.flatnonzero(rng.random(items) < 0.4)) for _ in range(rows)]
    y = (rng.random(rows) < 0.3).astype(int)
    return tx, y


# -- geometry buckets --------------------------------------------------------

def test_bucket_rounds_up_and_clamps():
    assert geometry_bucket(1000, 100, 2, 3) == "n1024_k128_w2_c4"
    assert geometry_bucket(1, 1, 1, 1) == "n128_k8_w1_c1"          # floors
    assert geometry_bucket(1 << 30, 1 << 22, 100, 50) == \
        f"n{1 << 26}_k{1 << 20}_w64_c16"                           # ceilings
    # already a power of two: unchanged (round UP, not to nearest)
    assert geometry_bucket(2048, 256, 4, 2) == "n2048_k256_w4_c2"


def test_bucket_shape_roundtrip_and_rejection():
    assert bucket_shape("n2048_k256_w4_c2") == (2048, 256, 4, 2)
    with pytest.raises(ValueError):
        bucket_shape(GEOMETRY_OVERFLOW)
    with pytest.raises(ValueError):
        bucket_shape("n12_k8")


def test_record_launch_uses_buckets_and_overflow_cap():
    saved = set(_SEEN_BUCKETS)
    obs.reset()
    _reset_geometry_buckets()
    try:
        record_launch(1000, 100, 2, 3, 1e-3)
        record_launch(1001, 101, 2, 3, 1e-3)   # same bucket
        snap = obs.snapshot()
        launches = snap["counters"]["kernel_launches_total"]
        assert launches == {"geometry=n1024_k128_w2_c4": 2.0}
        # fill the cap; the next NEW bucket collapses to overflow
        for i in range(MAX_GEOMETRY_BUCKETS - 1):
            _SEEN_BUCKETS.add(f"synthetic{i}")
        record_launch(1 << 20, 8, 1, 1, 1e-3)
        eff = obs.kernel_efficiency()
        assert GEOMETRY_OVERFLOW in eff
        # known buckets still record under their own label past the cap
        record_launch(1000, 100, 2, 3, 1e-3)
        snap = obs.snapshot()
        assert snap["counters"]["kernel_launches_total"][
            "geometry=n1024_k128_w2_c4"] == 3.0
    finally:
        _reset_geometry_buckets()
        _SEEN_BUCKETS.update(saved)
        obs.reset()


# -- table persistence + schema ----------------------------------------------

def test_table_json_roundtrip(tmp_path):
    t = _mk_table({
        "n1024_k256_w2_c2": _entry(block_k=512, chunk_rows=4096, us=42.0,
                                   candidates={"bk512/vpu_int32": 42.0,
                                               "bk256/vpu_int32": 50.0},
                                   chunk_candidates={"0": 60.0,
                                                     "4096": 42.0},
                                   serve_block_k=64),
        "n4096_k256_w1_c1": _entry(block_k=64, accum="mxu_f32", us=13.0),
    }, kind="cpu")
    path = str(tmp_path / "cpu.json")
    save_table(t, path)
    back = load_table(path)
    assert back.device_kind == "cpu"
    assert back.source == path
    assert set(back.entries) == set(t.entries)
    e = back.entries["n1024_k256_w2_c2"]
    assert e.config == LaunchConfig(512, DEFAULT_BLOCK_N, "vpu_int32",
                                    4096, "table")
    assert e.us == 42.0
    assert e.candidates["bk256/vpu_int32"] == 50.0
    assert e.serve_block_k == 64
    assert back.entries["n4096_k256_w1_c1"].config.accum == "mxu_f32"
    assert back.entries["n4096_k256_w1_c1"].config.chunk_rows is None
    assert back.entries["n4096_k256_w1_c1"].serve_block_k is None


@pytest.mark.parametrize("mutate", [
    lambda d: d.update(schema=99),
    lambda d: d.update(device_kind=""),
    lambda d: d.update(entries="nope"),
    lambda d: d["entries"].update({"not_a_bucket": d["entries"].pop(
        "n1024_k256_w2_c2")}),
    lambda d: d["entries"]["n1024_k256_w2_c2"].update(block_k=100),
    lambda d: d["entries"]["n1024_k256_w2_c2"].update(accum="int8"),
    lambda d: d["entries"]["n1024_k256_w2_c2"].update(chunk_rows=-1),
    lambda d: d["entries"]["n1024_k256_w2_c2"].update(us=0),
    lambda d: d["entries"]["n1024_k256_w2_c2"].update(serve_block_k=100),
])
def test_table_schema_rejection(mutate):
    doc = table_to_dict(_mk_table({"n1024_k256_w2_c2": _entry()}))
    mutate(doc)
    with pytest.raises(TableError):
        table_from_dict(doc)


def test_load_table_rejects_bad_json(tmp_path):
    p = tmp_path / "cpu.json"
    p.write_text("{not json")
    with pytest.raises(TableError):
        load_table(str(p))


def test_discovery_env_override_and_disable(tmp_path, monkeypatch):
    path = str(tmp_path / "mine.json")
    save_table(_mk_table({"n1024_k256_w2_c2": _entry(block_k=64)},
                         kind="whatever"), path)
    monkeypatch.setenv("REPRO_TUNE_TABLE", path)
    autotune.clear_active_table()
    try:
        t = autotune.active_table()
        assert t is not None and t.source == path
        assert resolve_launch_config(1000, 200, 2, 2).block_k == 64
        # REPRO_AUTOTUNE=0 wins over everything
        monkeypatch.setenv("REPRO_AUTOTUNE", "0")
        autotune.clear_active_table()
        assert autotune.active_table() is None
        assert resolve_launch_config(1000, 200, 2, 2).source == "default"
    finally:
        autotune.set_active_table(None)


def test_discovery_skips_corrupt_table(tmp_path, monkeypatch):
    path = tmp_path / "broken.json"
    path.write_text("{definitely not json")
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(path))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    # keep discovery away from any real user cache / repo table
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "empty"))
    autotune.clear_active_table()
    try:
        before = obs.counter_total(obs.snapshot(), "autotune_table_errors_total")
        t = autotune.active_table()
        after = obs.counter_total(obs.snapshot(), "autotune_table_errors_total")
        assert after == before + 1
        # fell through to the repo table or None — never the corrupt file
        assert t is None or t.source != str(path)
    finally:
        autotune.set_active_table(None)


# -- the resolve seam --------------------------------------------------------

def test_resolve_defaults_without_table():
    autotune.set_active_table(None)
    cfg = resolve_launch_config(5000, 100, 2, 1)
    assert (cfg.block_k, cfg.block_n, cfg.accum, cfg.chunk_rows) == \
        (DEFAULT_BLOCK_K, DEFAULT_BLOCK_N, DEFAULT_ACCUM, None)
    assert cfg.source == "default"


def test_resolve_hits_matching_bucket_and_misses_fall_back():
    bucket = geometry_bucket(5000, 100, 2, 1)
    autotune.set_active_table(_mk_table({bucket: _entry(block_k=512)}))
    assert resolve_launch_config(5000, 100, 2, 1).block_k == 512
    # different bucket -> default
    assert resolve_launch_config(50, 100, 2, 1).block_k == DEFAULT_BLOCK_K


def test_resolve_mxu_guard_falls_back_to_vpu():
    # the 2^26 N-clamp buckets huge row counts together: an mxu_f32 entry
    # tuned there must not leak to an actual N >= 2^24 launch
    n_big = 1 << 25
    bucket = geometry_bucket(n_big, 8, 1, 1)
    autotune.set_active_table(_mk_table({bucket: _entry(accum="mxu_f32",
                                                        block_k=64)}))
    cfg = resolve_launch_config(n_big, 8, 1, 1)
    assert cfg.accum == "vpu_int32"      # guard applied
    assert cfg.block_k == 64             # rest of the entry kept


def test_resolve_serve_block_k_uses_store_geometry():
    class Store:
        base_rows = 5000
        n_classes = 1

        class vocab:
            n_words = 2

    bucket = geometry_bucket(5000, DEFAULT_BLOCK_K, 2, 1)
    autotune.set_active_table(_mk_table(
        {bucket: _entry(block_k=512, serve_block_k=64)}))
    # only the padding-aware serve view steers the batcher — never the
    # fixed-K winner (different objective)
    assert autotune.resolve_serve_block_k(Store()) == 64
    autotune.set_active_table(_mk_table({bucket: _entry(block_k=512)}))
    assert autotune.resolve_serve_block_k(Store()) == DEFAULT_BLOCK_K
    autotune.set_active_table(None)
    assert autotune.resolve_serve_block_k(Store()) == DEFAULT_BLOCK_K
    assert autotune.resolve_serve_block_k(object()) == DEFAULT_BLOCK_K


def test_choose_chunk_rows_honors_table():
    bucket = geometry_bucket(100000, DEFAULT_BLOCK_K, 2, 2)
    autotune.set_active_table(_mk_table(
        {bucket: _entry(chunk_rows=5000)}))
    # tuned value, aligned down to the kernel N-block
    assert choose_chunk_rows(2, 2, n_rows=100000) == 4096
    # no n_rows -> pure heuristic, table untouched
    heur = choose_chunk_rows(2, 2)
    autotune.set_active_table(None)
    # table gone: the heuristic again, clamped to the aligned row count (the
    # 64MB staging budget allows far more rows than the DB has)
    assert choose_chunk_rows(2, 2, n_rows=100000) == min(heur, 100352)


def test_choose_chunk_rows_clamped_to_db_rows():
    """A tuned chunk_rows measured on a bigger bucket must be clamped to the
    aligned row count: handing a 2k-row DB a 16384-row chunk would zero-pad
    the single ragged chunk 8x (regression for the padding-waste bug)."""
    bucket = geometry_bucket(2000, DEFAULT_BLOCK_K, 2, 2)
    autotune.set_active_table(_mk_table({bucket: _entry(chunk_rows=16384)}))
    try:
        got = choose_chunk_rows(2, 2, n_rows=2000)
    finally:
        autotune.set_active_table(None)
    assert got == 2048                       # align_up(2000, 1024), not 16384
    # the budget heuristic clamps the same way (64MB budget >> 2000 rows)
    assert choose_chunk_rows(2, 2, n_rows=2000) == 2048
    # custom align: clamp rounds the row count up to one aligned chunk
    assert choose_chunk_rows(4, 2, budget_bytes=1 << 30, align=128,
                             n_rows=300) == 384
    # clamping never produces a chunk below one align unit
    assert choose_chunk_rows(2, 2, n_rows=1) == 1024


def test_oversized_tuned_chunk_never_launches_past_padded_rows(monkeypatch):
    """With a tuned table demanding oversized chunks, no streamed launch may
    exceed the align-rounded DB row count (the lattice-invariance battery's
    launch-size bound)."""
    import repro.mining.stream as stream_mod

    tx, y = _small_db(3, rows=300, items=10)
    db = DenseDB.encode(tx, classes=y, n_classes=2)
    bits, wts = np.asarray(db.bits), np.asarray(db.weights)
    n_unique = bits.shape[0]
    bucket = geometry_bucket(n_unique, DEFAULT_BLOCK_K, bits.shape[1], 2)
    masks = bits[:8].copy()
    from repro.kernels.itemset_count import itemset_counts
    want = np.asarray(itemset_counts(db.bits, masks, db.weights))

    launched = []
    real = stream_mod.itemset_counts_into

    def spy(acc, cur_tx, tgt, w, **kw):
        launched.append(int(cur_tx.shape[0]))
        return real(acc, cur_tx, tgt, w, **kw)

    monkeypatch.setattr(stream_mod, "itemset_counts_into", spy)
    autotune.set_active_table(_mk_table({bucket: _entry(chunk_rows=16384)}))
    try:
        sdb = StreamingDB.from_arrays(db.vocab, bits, wts, db.n_rows, 2)
        got = np.asarray(sdb.counts(masks))
    finally:
        autotune.set_active_table(None)
    assert launched, "streamed sweep never launched"
    bound = -(-n_unique // 1024) * 1024
    assert max(launched) <= bound, (launched, bound)
    np.testing.assert_array_equal(got, want)


# -- config invariance: the whole lattice is bit-exact -----------------------

_LATTICE = [(bk, acc) for bk in BLOCK_K_LATTICE for acc in ACCUM_LATTICE]


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(_LATTICE), st.integers(0, 10 ** 6))
def test_lattice_config_invariance_all_paths(cfg, seed):
    """Any lattice config produces bit-identical counts to the default on
    the dense, streaming, and GFP paths (speed may change, counts never)."""
    block_k, accum = cfg
    tx, y = _small_db(seed)
    db = DenseDB.encode(tx, classes=y, n_classes=2)
    bits = np.asarray(db.bits)
    wts = np.asarray(db.weights)
    masks = bits[:12].copy()

    from repro.kernels.itemset_count import itemset_counts
    want = np.asarray(itemset_counts(db.bits, masks, db.weights))
    got_dense = np.asarray(itemset_counts(db.bits, masks, db.weights,
                                          block_k=block_k, accum=accum))
    np.testing.assert_array_equal(got_dense, want)

    got_stream = np.asarray(streaming_counts(
        bits, masks, wts, chunk_rows=64, block_k=block_k, accum=accum))
    np.testing.assert_array_equal(got_stream, want)

    # GFP hybrid: force kernel blocks (host_rows=0) under the lattice config
    # via an active table covering every bucket this problem can hit
    table = _mk_table({
        geometry_bucket(n, k, bits.shape[1], wts.shape[1]): _entry(
            block_k=block_k, accum=accum)
        for n in (128, 256, 512, 1024)
        for k in (8, 16, 32, 64, 128, 256)})
    autotune.set_active_table(table)
    try:
        be = GFPBackend(db, host_rows=0)
        got_gfp_tuned = be.counts(masks)
    finally:
        autotune.set_active_table(None)
    be = GFPBackend(db, host_rows=0)
    got_gfp_default = be.counts(masks)
    np.testing.assert_array_equal(got_gfp_tuned, got_gfp_default)


def test_tuned_table_mine_identical_to_default():
    """End-to-end: a full mine under an aggressive tuning table returns the
    exact result dict of the default-config mine."""
    tx, y = _small_db(7, rows=400, items=12)
    db = DenseDB.encode(tx, classes=y, n_classes=2)
    want = dense_mine_frequent(db, 40)
    table = _mk_table({
        geometry_bucket(n, k, 1, 2): _entry(block_k=64, accum="mxu_f32",
                                            chunk_rows=1024)
        for n in (128, 256, 512, 1024)
        for k in (8, 16, 32, 64, 128, 256, 512, 1024)})
    autotune.set_active_table(table)
    try:
        got = dense_mine_frequent(db, 40)
    finally:
        autotune.set_active_table(None)
    assert got == want


# -- derived chooser thresholds ----------------------------------------------

def _throughput_table(overhead_us=100.0, per_row_us=0.05, rho=1.0):
    """Synthetic table whose winner timings follow us = overhead + per_row*n
    and whose chunk candidates encode a single-pass/chunked ratio rho."""
    entries = {}
    for n in (1024, 4096, 16384, 65536):
        us = overhead_us + per_row_us * n
        entries[geometry_bucket(n, 256, 2, 2)] = _entry(
            us=us, chunk_rows=None,
            chunk_candidates={"0": us, "4096": us / rho})
    return _mk_table(entries)


def test_derived_thresholds_scale_with_measured_overhead():
    from repro.mining.stream import DEFAULT_STREAM_THRESHOLD_BYTES

    base = autotune.derived_chooser_thresholds(_throughput_table())
    assert base["tiny_rows"] == 2000          # overhead / per_row
    assert base["min_depth"] == 4             # overhead == reference
    assert base["gfp_host_rows"] == 4096      # floored at the hybrid default
    assert base["stream_threshold_bytes"] == DEFAULT_STREAM_THRESHOLD_BYTES // 2

    pricey = autotune.derived_chooser_thresholds(
        _throughput_table(overhead_us=400.0))
    assert pricey["tiny_rows"] == 8000
    assert pricey["min_depth"] == 2           # 4 - log2(4)
    cheap = autotune.derived_chooser_thresholds(
        _throughput_table(overhead_us=25.0))
    assert cheap["min_depth"] == 6            # 4 - log2(1/4)

    # expensive chunking (chunked 2x slower than single pass) raises the
    # residency threshold; free chunking (rho ~ 2) lowers it
    slow_chunk = autotune.derived_chooser_thresholds(
        _throughput_table(rho=0.25))
    assert slow_chunk["stream_threshold_bytes"] == \
        2 * DEFAULT_STREAM_THRESHOLD_BYTES

    assert autotune.derived_chooser_thresholds(_mk_table({})) == {}
    autotune.set_active_table(None)
    assert autotune.derived_chooser_thresholds() == {}


def test_chooser_consumes_derived_thresholds():
    from repro.mining.chooser import DatasetTraits, choose_backend

    traits = DatasetTraits(n_rows=5000, n_unique=5000, vocab_size=20,
                           n_classes=1, nbytes=10 ** 6, density=0.05,
                           skew=1.0, dedup_ratio=1.0)
    autotune.set_active_table(None)
    assert choose_backend(traits).name == "dense"   # 5000 >= default 2048
    # a table measuring very expensive launches pushes tiny_rows above 5000:
    # the same traits now pick dense VIA the tiny-DB rule (reason changes)
    autotune.set_active_table(_throughput_table(overhead_us=400.0))
    try:
        choice = choose_backend(traits)
        assert choice.name == "dense"
        assert "tiny DB" in choice.reason          # 5000 < derived 8000
    finally:
        autotune.set_active_table(None)


# -- sweep + staleness -------------------------------------------------------

def test_sweep_smoke_produces_valid_winning_table(tmp_path):
    t = autotune.sweep([(256, 16, 1, 1)], repeats=1,
                       block_ks=(128, 256), accums=("vpu_int32",),
                       chunk_grid=(0,), kind="testkind")
    assert set(t.entries) == {geometry_bucket(256, 16, 1, 1)}
    e = t.entries[geometry_bucket(256, 16, 1, 1)]
    assert e.config.block_k in (128, 256)
    assert e.us > 0 and e.efficiency > 0
    assert set(e.candidates) == {"bk128/vpu_int32", "bk256/vpu_int32"}
    # k=16 can't shrink under any candidate block — no serve view
    assert e.serve_block_k is None and e.serve_candidates == {}
    # round-trips through the schema checker
    path = save_table(t, str(tmp_path / "testkind.json"))
    assert load_table(path).entries.keys() == t.entries.keys()


def test_sweep_serve_view_prefers_less_padding():
    """The serve view times each candidate at k = block_k (the batcher pads
    a flush up to the block), so the small block's 4x-less-work launch must
    win the padded-flush comparison — the structural effect the fixed-K
    candidates cannot see."""
    t = autotune.sweep([(16384, 256, 2, 2)], repeats=2,
                       block_ks=(64, 256), accums=("vpu_int32",),
                       chunk_grid=(0,), kind="testkind")
    e = t.entries[geometry_bucket(16384, 256, 2, 2)]
    assert set(e.serve_candidates) == {"64", "256"}
    assert e.serve_candidates["64"] < e.serve_candidates["256"]
    assert e.serve_block_k == 64


def test_sweep_leaves_telemetry_clean():
    obs.reset()
    autotune.sweep([(256, 16, 1, 1)], repeats=1, block_ks=(256,),
                   accums=("vpu_int32",), chunk_grid=(0,))
    assert obs.counter_total(obs.snapshot(), "kernel_launches_total") == 0
    assert obs.KERNEL_TIMING        # restored
    obs.reset()


def test_staleness_flags_drifted_entry():
    bucket = geometry_bucket(4096, 256, 2, 2)
    entry = _entry(block_k=512, us=100.0, efficiency=0.5,
                   candidates={"bk512/vpu_int32": 100.0,
                               "bk256/vpu_int32": 120.0})
    table = _mk_table({bucket: entry})
    # live ledger says this bucket now runs at efficiency 0.2 — well below
    # the runner-up's sweep-time 0.5 * (100/120) ~ 0.417 (x0.9 margin)
    obs.reset()
    obs.REGISTRY.counter("kernel_launches_total", geometry=bucket).inc(10)
    obs.REGISTRY.counter("kernel_measured_s_total", geometry=bucket).inc(1.0)
    obs.REGISTRY.counter("kernel_predicted_s_total", geometry=bucket).inc(0.2)
    rep = autotune.staleness_report(table)
    assert rep[bucket]["stale"] is True
    assert rep[bucket]["alternative"] == "bk256/vpu_int32"
    # healthy live efficiency: not stale
    obs.reset()
    obs.REGISTRY.counter("kernel_launches_total", geometry=bucket).inc(10)
    obs.REGISTRY.counter("kernel_measured_s_total", geometry=bucket).inc(1.0)
    obs.REGISTRY.counter("kernel_predicted_s_total", geometry=bucket).inc(0.5)
    rep = autotune.staleness_report(table)
    assert rep[bucket]["stale"] is False
    # no launches recorded: not stale, reason says why
    obs.reset()
    rep = autotune.staleness_report(table)
    assert rep[bucket]["stale"] is False and "reason" in rep[bucket]
    obs.reset()


def test_server_stats_expose_autotune_section():
    from repro.serve import CountServer

    tx, y = _small_db(3, rows=120, items=8)
    bucket = geometry_bucket(5000, 256, 1, 2)
    with CountServer(tx, classes=y, n_classes=2) as server:
        autotune.set_active_table(_mk_table({bucket: _entry(block_k=512)},
                                            source="<pinned>"))
        try:
            sec = server.stats()["telemetry"]["autotune"]
        finally:
            autotune.set_active_table(None)
        assert sec["active"] is True
        assert sec["source"] == "<pinned>"
        assert sec["entries"][bucket]["block_k"] == 512
        assert bucket in sec["stale"]
        sec_off = server.stats()["telemetry"]["autotune"]
        assert sec_off == {"active": False, "source": "default",
                           "entries": {}, "stale": {},
                           "fallbacks": dict(autotune.LAST_FALLBACKS)}


def test_describe_active_banner():
    autotune.set_active_table(None)
    assert "default launch configs" in autotune.describe_active()
    autotune.set_active_table(_mk_table({"n128_k8_w1_c1": _entry()},
                                        kind="cpu", source="x.json"))
    try:
        msg = autotune.describe_active()
        assert "cpu" in msg and "1 entries" in msg and "x.json" in msg
    finally:
        autotune.set_active_table(None)
