"""Differential oracle battery for the device-hybrid GFP-growth backend.

Three independent implementations of the same counting contract are pinned
bit-exactly against each other on randomized DBs and multitudes:

  * the paper-faithful HOST GFP-growth (``core/gfp.py`` walking a real
    FP-tree + TIS-tree, per class),
  * the device-hybrid ``GFPBackend`` (conditional-pattern-base counting over
    the encoded bitmap, host/kernel per block size) — in its default, its
    device-only (``host_rows=0``), and its unguided (``guide=False``)
    configurations,
  * the dense level-wise kernel path (``dense_gfp_counts`` /
    ``DenseBackend``).

Plus the edge contracts (class columns, empty multitude/DB, unknown-item
targets, at-threshold epsilon) and the backend's driver integration:
mid-flush kill/resume with no conditional block recounted, and whole-state
checkpoint discard on a stale store version.
"""
import json

import numpy as np
import pytest
from _pbt import given, settings, strategies as st

from repro.core import mine_frequent
from repro.core.fptree import FPTree, ItemOrder
from repro.core.gfp import gfp_growth
from repro.core.incremental import ceil_count
from repro.core.tis import TISTree
from repro.mining import (DenseBackend, DenseDB, GFPBackend,
                          dense_gfp_counts, gfp_mine_frequent,
                          gfp_multitude_counts, mine_frequent_backend)
from repro.mining.distributed import MiningCheckpoint
from repro.mining.encode import encode_targets
from repro.serve import VersionedDB


class _Preempted(Exception):
    pass


def _random_tx(rng, n, m, p):
    return [[i for i in range(m) if rng.random() < p] for _ in range(n)]


def _random_multitude(rng, m, n_targets, max_len):
    """Random target itemsets over items 0..m+1 — items m and m+1 do NOT
    exist in any transaction, exercising the unknown-item contract."""
    out = []
    for _ in range(n_targets):
        size = int(rng.integers(1, max_len + 1))
        out.append(sorted(rng.choice(m + 2, size=min(size, m + 2),
                                     replace=False).tolist()))
    return out


def _host_gfp(tx, classes, n_classes, vocab, targets):
    """The paper-faithful oracle: per class, a real FP-tree under the
    bitmap's arrangement order + a guided walk; unknown-item targets stay at
    their initial g_count of 0 (they never appear in any FP-tree)."""
    known = list(vocab.items)
    unknown = sorted({a for t in targets for a in t if a not in vocab},
                     key=repr)
    order = ItemOrder(known + unknown)   # extended: targets always insert
    out = {}
    for c in range(n_classes):
        tx_c = [t for t, y in zip(tx, classes) if y == c]
        fp = FPTree.build(tx_c, order)
        tis = TISTree(order)
        for t in targets:
            tis.insert(t)
        tis.finalize()
        gfp_growth(tis, fp)
        for key, g in tis.as_dict("g_count").items():
            out.setdefault(key, np.zeros(n_classes, np.int32))[c] = g
    return out


def _tis_of(targets, vocab):
    unknown = sorted({a for t in targets for a in t if a not in vocab},
                     key=repr)
    tis = TISTree(ItemOrder(list(vocab.items) + unknown))
    for t in targets:
        tis.insert(t)
    tis.finalize()
    return tis


# ------------------------------------------------ the differential battery
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_pbt_gfp_differential_battery(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 120))
    m = int(rng.integers(2, 13))
    p = float(rng.uniform(0.1, 0.7))
    n_classes = int(rng.integers(1, 4))
    tx = _random_tx(rng, n, m, p)
    classes = [int(rng.integers(0, n_classes)) for _ in tx]
    targets = _random_multitude(rng, m, n_targets=int(rng.integers(1, 25)),
                                max_len=4)

    db = DenseDB.encode(tx, classes=classes, n_classes=n_classes)
    tis = _tis_of(targets, db.vocab)
    oracle = _host_gfp(tx, classes, n_classes, db.vocab, targets)

    via_dense = dense_gfp_counts(tis, db)
    via_gfp = gfp_multitude_counts(tis, db)
    via_device = gfp_multitude_counts(tis, db, host_rows=0)   # kernel-only
    via_unguided = gfp_multitude_counts(tis, db, guide=False)

    assert set(oracle) == set(via_dense) == set(via_gfp) \
        == set(via_device) == set(via_unguided)
    for key in oracle:
        assert np.array_equal(via_gfp[key], oracle[key]), key
        assert np.array_equal(via_gfp[key], via_dense[key]), key
        assert np.array_equal(via_gfp[key], via_device[key]), key
        assert np.array_equal(via_gfp[key], via_unguided[key]), key


def test_gfp_counts_match_dense_backend_blockwise():
    rng = np.random.default_rng(42)
    tx = _random_tx(rng, 350, 11, 0.45)
    db = DenseDB.encode(tx)
    targets = _random_multitude(rng, 11, n_targets=60, max_len=5)
    known = [t for t in targets if all(a in db.vocab for a in t)]
    masks = encode_targets(known, db.vocab)

    dense = np.asarray(DenseBackend(db).counts(masks))
    for kw in ({}, {"host_rows": 0}, {"guide": False}):
        b = GFPBackend(db, **kw)
        assert np.array_equal(b.counts(masks), dense), kw
    # the hybrid default on this small DB never launches: all blocks host-
    # sized, every count still bit-identical to the kernel sweep
    b = GFPBackend(db)
    b.counts(masks)
    assert b.kernel_launches == 0 and b.host_blocks > 0


# ---------------------------------------------------------- edge contracts
def test_empty_multitude_and_empty_db():
    rng = np.random.default_rng(3)
    tx = _random_tx(rng, 60, 8, 0.4)
    db = DenseDB.encode(tx)

    tis = _tis_of([[0]], db.vocab)
    # a TIS-tree whose only node is a non-target prefix => no targets
    empty = TISTree(ItemOrder(list(db.vocab.items)))
    empty.insert([0, 1], target=False)
    empty.finalize()
    assert gfp_multitude_counts(empty, db) == {}

    # empty DB: every target counts 0, mining yields nothing
    edb = DenseDB.encode([], vocab=db.vocab)
    got = gfp_multitude_counts(tis, edb)
    assert all(np.array_equal(v, np.zeros(1, np.int32))
               for v in got.values())
    assert gfp_mine_frequent(edb, 1) == {}

    # empty target block through the raw protocol
    b = GFPBackend(db)
    out = b.counts(np.zeros((0, db.vocab.n_words), np.uint32))
    assert out.shape == (0, 1)


def test_unknown_item_targets_count_zero():
    rng = np.random.default_rng(4)
    tx = _random_tx(rng, 80, 6, 0.5)
    db = DenseDB.encode(tx)
    targets = [[0, 99], [99], [1, 2]]          # 99 never occurs
    tis = _tis_of(targets, db.vocab)
    got = gfp_multitude_counts(tis, db)
    assert np.array_equal(got[(0, 99)], np.zeros(1, np.int32))
    assert np.array_equal(got[(99,)], np.zeros(1, np.int32))
    want = dense_gfp_counts(tis, db)
    for k in got:
        assert np.array_equal(got[k], want[k]), k


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_pbt_gfp_mine_parity_with_epsilon_threshold(seed):
    rng = np.random.default_rng(seed)
    tx = _random_tx(rng, int(rng.integers(40, 200)), int(rng.integers(4, 10)),
                    float(rng.uniform(0.25, 0.6)))
    db = DenseDB.encode(tx)
    counts = sorted(mine_frequent(tx, 1).values())
    mc = counts[len(counts) // 2]              # an exactly-achieved count
    want = mine_frequent(tx, mc)

    assert gfp_mine_frequent(db, mc) == want
    # at-threshold epsilon: theta * n landing EXACTLY on mc must include the
    # count-mc itemsets (the repo-wide ceil_count(x - 1e-9) rule)
    theta = mc / len(tx)
    assert ceil_count(theta * len(tx)) == mc
    assert gfp_mine_frequent(db, ceil_count(theta * len(tx))) == want
    assert gfp_mine_frequent(db, mc, host_rows=0) == want


def test_gfp_class_column_parity():
    rng = np.random.default_rng(5)
    tx = _random_tx(rng, 260, 10, 0.4)
    y = [int(rng.random() < 0.3) for _ in tx]
    rare = [t for t, c in zip(tx, y) if c == 1]
    want = mine_frequent(rare, 12)
    db = DenseDB.encode(tx, classes=y, n_classes=2)
    assert gfp_mine_frequent(db, 12, class_column=1) == want


# ------------------------------------------------- driver kill/resume seam
def test_gfp_mid_flush_kill_resume(tmp_path):
    tx = _random_tx(np.random.default_rng(6), 400, 9, 0.5)
    want = mine_frequent(tx, 60)
    assert max(len(k) for k in want) >= 3      # levels after the kill
    db = DenseDB.encode(tx)

    fresh = GFPBackend(db)
    assert mine_frequent_backend(fresh, 60) == want
    assert fresh.kernel_launches == 0          # all blocks host-sized here
    assert fresh.blocks_counted > 2

    ckpt = MiningCheckpoint(str(tmp_path / "gfp.json"))
    killed = GFPBackend(db)

    def die_mid_flush(level, chunk):
        if level == 2 and chunk == 1:
            raise _Preempted()                 # two tail groups counted

    with pytest.raises(_Preempted):
        mine_frequent_backend(killed, 60, checkpoint=ckpt,
                              on_chunk=die_mid_flush)
    assert killed.blocks_counted == 2
    state = json.load(open(str(tmp_path / "gfp.json")))
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 2
    assert state["partial"]["backend"] == "gfp"

    resumed = []
    b2 = GFPBackend(db)
    got = mine_frequent_backend(b2, 60, checkpoint=ckpt,
                                on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0] == (2, 2)                # resumed MID-flush
    # no conditional block recounted: the resumed run counted exactly the
    # blocks the killed run didn't
    assert b2.blocks_counted == fresh.blocks_counted - killed.blocks_counted


def test_gfp_from_store_stale_signature_discard(tmp_path):
    rng = np.random.default_rng(7)
    tx = _random_tx(rng, 200, 10, 0.35)
    store = VersionedDB(tx, merge_ratio=2.0)   # keep the delta resident
    ckpt = MiningCheckpoint(str(tmp_path / "stale.json"))

    b = GFPBackend.from_store(store)
    assert b.mine_signature() == {"engine": "gfp", "version": store.version}
    old = mine_frequent_backend(b, 30, checkpoint=ckpt)
    assert old == mine_frequent(tx, 30)

    extra = _random_tx(rng, 120, 10, 0.6)      # denser rows: counts shift
    store.append(extra)
    b2 = GFPBackend.from_store(store)
    assert b2.mine_signature() != b.mine_signature()
    assert b2.n_rows == len(tx) + len(extra)   # composed base+delta rows
    got = mine_frequent_backend(b2, 30, checkpoint=ckpt)
    want = mine_frequent(tx + extra, 30)
    assert got == want                         # stale version state NOT used
    assert got != old
