"""repro-lint battery: every checker against its violation/clean fixture
pair, the suppression + fingerprint + baseline machinery, the whole-repo
gate (the shipped tree must be clean modulo the committed baseline), the
dead-module advisory, and the ``tools/analyze.py`` CLI self-test.

The fixtures under ``tests/analysis_fixtures/`` are PARSED, never imported
— they reference undefined helpers and fake registries on purpose.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (ConcurrencyChecker, ExceptionHygieneChecker,
                            Finding, JitSafetyChecker, MetricHygieneChecker,
                            TunerSeamChecker, analyze_paths, default_checkers,
                            find_cycle, load_baseline, new_findings,
                            write_baseline)
from repro.analysis.deadmods import dead_module_report

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "analysis_fixtures"
SRC = REPO / "src" / "repro"


def _run(checker, *names):
    """Run one fresh checker over fixture files; returns findings."""
    paths = [str(FIXTURES / n) for n in names]
    findings, n_files = analyze_paths(paths, [checker], root=str(FIXTURES))
    assert n_files == len(names)
    return findings


def _codes(findings):
    return sorted(f.code for f in findings)


# -- per-checker fixture pairs ------------------------------------------------

def test_concurrency_flags_inversion_and_unlocked_mutation():
    findings = _run(ConcurrencyChecker(path_prefixes=("",)), "conc_bad.py")
    codes = _codes(findings)
    assert "CONC001" in codes, findings
    assert "CONC002" in codes, findings
    # the unlocked mutation is the one in racy_bump, not the guarded ones
    conc2 = [f for f in findings if f.code == "CONC002"]
    assert any("shared" in f.message for f in conc2)


def test_concurrency_clean_twin_passes():
    assert _run(ConcurrencyChecker(path_prefixes=("",)),
                "conc_clean.py") == []


def test_concurrency_lock_edges_exposed():
    checker = ConcurrencyChecker(path_prefixes=("",))
    _run(checker, "conc_bad.py")
    adj = {}
    for (a, b) in checker.lock_edges:
        adj.setdefault(a, set()).add(b)
    assert find_cycle(adj) is not None


def test_jit_safety_flags_all_four_codes():
    codes = set(_codes(_run(JitSafetyChecker(hot_prefixes=("",)),
                            "jit_bad.py")))
    assert {"JIT001", "JIT002", "JIT003", "JIT004"} <= codes


def test_jit_safety_clean_twin_passes():
    # statics via keyword-only/static_argnames, shape-derived branching,
    # and a typed raise must all be allowed
    assert _run(JitSafetyChecker(hot_prefixes=("",)), "jit_clean.py") == []


def test_tuner_seam_flags_literals_and_local_constants():
    findings = _run(TunerSeamChecker(), "tune_bad.py")
    # one finding per hardcoded kwarg: block_k + accum in launch_hardcoded,
    # the local-constant block_k in launch_via_local
    assert _codes(findings) == ["TUNE001"] * 3
    messages = " ".join(f.message for f in findings)
    assert "block_k" in messages and "accum" in messages


def test_tuner_seam_clean_twin_passes():
    assert _run(TunerSeamChecker(), "tune_clean.py") == []


def test_metric_hygiene_flags_unbounded_labels_and_grid_conflict():
    findings = _run(MetricHygieneChecker(), "met_bad.py")
    codes = _codes(findings)
    assert codes.count("MET001") == 3, findings
    assert codes.count("MET002") == 1, findings


def test_metric_hygiene_clean_twin_passes():
    # the geometry_bucket call is the sanctioned unbounded->bounded funnel
    assert _run(MetricHygieneChecker(), "met_clean.py") == []


def test_exception_hygiene_flags_each_swallow_variant():
    findings = _run(ExceptionHygieneChecker(), "exc_bad.py")
    assert _codes(findings) == ["EXC001"] * 3
    reasons = [f.message for f in findings]
    assert any("without binding" in r for r in reasons)
    assert any("never uses" in r for r in reasons)
    assert any("never accounts" in r for r in reasons)


def test_exception_hygiene_clean_twin_passes():
    assert _run(ExceptionHygieneChecker(), "exc_clean.py") == []


# -- suppressions, fingerprints, baselines ------------------------------------

_SWALLOW = ("def f(fn):\n"
            "    try:\n"
            "        fn()\n"
            "    except Exception:{comment}\n"
            "        pass\n")


def _analyze_snippet(tmp_path, source, checker=None):
    p = tmp_path / "snippet.py"
    p.write_text(source)
    findings, _ = analyze_paths([str(p)],
                                [checker or ExceptionHygieneChecker()],
                                root=str(tmp_path))
    return findings


def test_same_line_suppression(tmp_path):
    noisy = _analyze_snippet(tmp_path, _SWALLOW.format(comment=""))
    assert _codes(noisy) == ["EXC001"]
    quiet = _analyze_snippet(
        tmp_path,
        _SWALLOW.format(comment="  # repro-lint: disable=EXC001 -- test"))
    assert quiet == []


def test_own_line_suppression_applies_to_next_line(tmp_path):
    src = ("def f(fn):\n"
           "    try:\n"
           "        fn()\n"
           "    # repro-lint: disable=EXC001 -- fixture\n"
           "    except Exception:\n"
           "        pass\n")
    # the finding anchors at the `except` line, below the comment
    assert _analyze_snippet(tmp_path, src) == []


def test_file_level_suppression(tmp_path):
    src = "# repro-lint: disable-file=EXC001\n" + _SWALLOW.format(comment="")
    assert _analyze_snippet(tmp_path, src) == []
    src_all = "# repro-lint: disable-file=all\n" + _SWALLOW.format(comment="")
    assert _analyze_snippet(tmp_path, src_all) == []


def test_unrelated_code_suppression_does_not_mask(tmp_path):
    src = _SWALLOW.format(comment="  # repro-lint: disable=JIT003")
    assert _codes(_analyze_snippet(tmp_path, src)) == ["EXC001"]


def test_fingerprint_survives_line_shift(tmp_path):
    before = _analyze_snippet(tmp_path, _SWALLOW.format(comment=""))
    shifted = _analyze_snippet(
        tmp_path, "\n\n\n# padding\n\n" + _SWALLOW.format(comment=""))
    assert len(before) == len(shifted) == 1
    assert before[0].line != shifted[0].line
    assert before[0].fingerprint == shifted[0].fingerprint


def test_baseline_roundtrip_and_diff(tmp_path):
    findings = _analyze_snippet(tmp_path, _SWALLOW.format(comment=""))
    bl = tmp_path / "baseline.json"
    assert write_baseline(str(bl), findings) == 1
    fps = load_baseline(str(bl))
    assert new_findings(findings, fps) == []
    other = Finding("elsewhere.py", 1, "EXC001", "m", "exception-hygiene",
                    "except Exception:")
    assert new_findings([other], fps) == [other]


def test_baseline_schema_mismatch_rejected(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"schema": 99, "fingerprints": []}))
    with pytest.raises(ValueError, match="schema"):
        load_baseline(str(bl))


# -- whole-repo gate ----------------------------------------------------------

def test_shipped_tree_is_clean_modulo_baseline():
    """The exact gate CI runs: all five checkers over src/repro, no
    findings beyond the committed baseline."""
    findings, n_files = analyze_paths([str(SRC)], default_checkers(),
                                      root=str(SRC))
    assert n_files > 80
    baseline = load_baseline(str(REPO / "tools" / "analysis_baseline.json"))
    fresh = new_findings(findings, baseline)
    assert fresh == [], "\n".join(f.format() for f in fresh)


def test_static_lock_graph_is_acyclic_with_known_edges():
    """The interprocedural lock graph over the serving+obs layers must be
    exactly the two known nestings, and acyclic."""
    checker = ConcurrencyChecker()
    analyze_paths([str(SRC)], [checker], root=str(SRC))
    edges = set(checker.lock_edges)
    assert ("CountServer._lock", "AsyncFlusher._lat_lock") in edges
    assert ("CountServer._lock", "MetricsRegistry._lock") in edges
    adj = {}
    for (a, b) in edges:
        adj.setdefault(a, set()).add(b)
    assert find_cycle(adj) is None, edges


def test_dead_module_report_sanity():
    report = dead_module_report(str(REPO))
    reachable = set(report["reachable"])
    assert "repro.serve.service" in reachable
    assert "repro.mining.dense" in reachable
    assert "repro.analysis.engine" in reachable
    # the advisory must not claim any live layer is dead
    for mod in report["dead"]:
        assert not mod.startswith(("repro.serve", "repro.kernels",
                                   "repro.mining", "repro.obs",
                                   "repro.analysis")), report["dead"]


# -- the CLI ------------------------------------------------------------------

def _run_analyze(*args):
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "analyze.py"), *args],
        capture_output=True, text=True, cwd=str(REPO))


def test_cli_gate_passes_on_shipped_tree():
    proc = _run_analyze()
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro-lint: ok" in proc.stdout


def test_cli_self_test():
    """Each checker must catch its injected violation and pass the clean
    twin — the analyzer proving it still analyzes, perfgate-style."""
    proc = _run_analyze("--self-test")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "self-test" in proc.stdout


def test_cli_fails_on_injected_violation(tmp_path):
    bad = tmp_path / "injected.py"
    bad.write_text(_SWALLOW.format(comment=""))
    proc = _run_analyze("--root", str(tmp_path))
    assert proc.returncode == 1
    assert "EXC001" in proc.stdout


def test_cli_dead_modules_is_advisory():
    proc = _run_analyze("--dead-modules")
    assert proc.returncode == 0, proc.stdout + proc.stderr
