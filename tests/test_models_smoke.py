"""Per-architecture smoke tests on REDUCED configs (CPU, 1 device):
one forward/train step asserting output shapes + finiteness, and
prefill->decode consistency against the full-sequence forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import get_model

ALL_ARCHS = sorted(ARCHS)


def _batch_for(model, b, s, key):
    cfg = model.cfg
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.encdec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (b, s, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    model = get_model(arch, reduced=True)
    params = model.init(jax.random.key(0))
    b, s = 2, 32
    batch = _batch_for(model, b, s, jax.random.key(1))
    logits = model.forward(params, batch["tokens"], frames=batch.get("frames"))
    vpad = ((model.cfg.vocab_size + 255) // 256) * 256
    assert logits.shape == (b, s, vpad)
    assert bool(jnp.isfinite(logits).all())
    loss = jax.jit(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    # near-uniform init => loss close to log(vocab)
    assert abs(float(loss) - np.log(model.cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grads_finite(arch):
    model = get_model(arch, reduced=True)
    params = model.init(jax.random.key(0))
    batch = _batch_for(model, 2, 16, jax.random.key(2))
    grads = jax.jit(jax.grad(model.loss))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # something nonzero actually flowed
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """logits(decode @ pos s-1 after prefill of s-1) == logits(forward)[:, -1]."""
    model = get_model(arch, reduced=True)
    params = model.init(jax.random.key(0))
    b, s = 2, 17
    batch = _batch_for(model, b, s, jax.random.key(3))
    toks = batch["tokens"]
    full_logits = model.forward(params, toks, frames=batch.get("frames"))

    max_len = 32
    last_logits, cache = model.prefill(
        params, toks[:, : s - 1], max_len, frames=batch.get("frames"))
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0]), np.asarray(full_logits[:, s - 2]),
        rtol=2e-4, atol=2e-4)

    dec_logits, cache = model.decode_step(
        params, cache, toks[:, s - 1:s], jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, s - 1]),
        rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-2.7b", "jamba-1.5-large-398b"])
def test_multistep_decode(arch):
    """Greedy 4-step decode equals teacher-forced forward argmax chain."""
    model = get_model(arch, reduced=True)
    params = model.init(jax.random.key(0))
    b, s, extra = 1, 9, 4
    toks = jax.random.randint(jax.random.key(4), (b, s + extra), 0,
                              model.cfg.vocab_size)
    full_logits = model.forward(params, toks)
    _, cache = model.prefill(params, toks[:, :s], 32)
    for i in range(extra):
        pos = s + i  # next unseen token (prefill consumed 0..s-1)
        logits, cache = model.decode_step(
            params, cache, toks[:, pos:pos + 1], jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, pos]),
            rtol=3e-4, atol=3e-4)


def test_param_counts_match_analytic():
    """Spec-derived parameter count ~ analytic 6ND count (within padding)."""
    for arch in ALL_ARCHS:
        model = get_model(arch, reduced=False)
        spec_n = model.n_params()
        analytic = model.cfg.n_params()
        assert abs(spec_n - analytic) / analytic < 0.02, (
            arch, spec_n, analytic)


def test_moe_gather_matches_einsum():
    """The two MoE dispatch implementations agree (same capacity drops)."""
    import dataclasses
    from repro.models.registry import Model
    from repro.configs import get_config

    cfg = get_config("arctic-480b").reduced()
    m1 = Model(cfg)
    params = m1.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(5), (2, 16), 0, cfg.vocab_size)
    out1 = m1.forward(params, toks)
    m2 = Model(dataclasses.replace(cfg, moe_impl="gather"))
    out2 = m2.forward(params, toks)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)
