"""Streaming out-of-core engine: chunked sweep == single-pass dense == oracle
for every chunking (incl. chunk > N and ragged tails), engine threading
through the mining stack, and mid-level checkpoint kill/resume."""
import json

import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings, strategies as st  # hypothesis or offline shim

from repro.core import mine_frequent, minority_report
from repro.kernels.itemset_count import (itemset_counts, itemset_counts_into,
                                         itemset_counts_ref)
from repro.mining import (DenseDB, ItemVocab, StreamingDB, choose_chunk_rows,
                          dense_gfp_counts, dense_mine_frequent, encode_bitmap,
                          encode_targets, minority_report_dense,
                          stream_chunks, streaming_counts,
                          streaming_mine_frequent)
from _testutil import random_problem as _random_problem
from repro.mining.distributed import MiningCheckpoint


# ------------------------------------------------------------- chunk planner
def test_stream_chunks_cover_and_ragged():
    assert stream_chunks(10, 4) == [(0, 4), (4, 8), (8, 10)]   # ragged tail
    assert stream_chunks(4, 4) == [(0, 4)]
    assert stream_chunks(3, 100) == [(0, 3)]                   # chunk > N
    assert stream_chunks(0, 4) == []
    with pytest.raises(ValueError):
        stream_chunks(10, 0)
    spans = stream_chunks(1001, 7)
    assert spans[0][0] == 0 and spans[-1][1] == 1001
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))


def test_choose_chunk_rows_budget_and_align():
    rows = choose_chunk_rows(4, 2, budget_bytes=1 << 20, align=128)
    assert rows % 128 == 0
    assert rows * 4 * (4 + 2) <= (1 << 20)
    # tiny budget still returns the alignment floor
    assert choose_chunk_rows(64, 8, budget_bytes=1, align=128) == 128


# ---------------------------------------------------- bit-identical counting
@pytest.mark.parametrize("chunk", [7, 64, 128, 300, 301, 10_000])
def test_streaming_counts_bit_identical(chunk):
    rng = np.random.default_rng(chunk)
    tx, tgt, wts = _random_problem(rng, 300, 17, 3, 2)
    got = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=chunk))
    dense = np.asarray(itemset_counts(jnp.asarray(tx), jnp.asarray(tgt),
                                      jnp.asarray(wts)))
    want = np.asarray(itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                                         jnp.asarray(wts)))
    np.testing.assert_array_equal(got, dense)
    np.testing.assert_array_equal(got, want)


def test_streaming_counts_empty_and_resume_args():
    tx, tgt, wts = _random_problem(np.random.default_rng(0), 50, 5, 2, 2)
    assert streaming_counts(tx, np.zeros((0, 2), np.uint32), wts).shape == (0, 2)
    assert streaming_counts(np.zeros((0, 2), np.uint32), tgt,
                            np.zeros((0, 2), np.int32)).shape == (5, 2)
    # manual two-stage resume == one sweep
    full = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=16))
    first = None

    def grab(j, acc):
        nonlocal first
        if j == 1:
            first = np.asarray(acc)

    np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=16, on_chunk=grab))
    resumed = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=16,
                                          start_chunk=2, init=first))
    np.testing.assert_array_equal(resumed, full)


def test_itemset_counts_into_accumulates():
    rng = np.random.default_rng(2)
    tx, tgt, wts = _random_problem(rng, 200, 9, 2, 3)
    acc = jnp.zeros((9, 3), jnp.int32)
    acc = itemset_counts_into(acc, jnp.asarray(tx[:120]), jnp.asarray(tgt),
                              jnp.asarray(wts[:120]))
    acc = itemset_counts_into(acc, jnp.asarray(tx[120:]), jnp.asarray(tgt),
                              jnp.asarray(wts[120:]))
    want = itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                              jnp.asarray(wts))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=200),    # n
    st.integers(min_value=1, max_value=20),     # k
    st.integers(min_value=1, max_value=3),      # w
    st.integers(min_value=1, max_value=3),      # c
    st.integers(min_value=1, max_value=250),    # chunk_rows
    st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_streaming_property_random(n, k, w, c, chunk, seed):
    rng = np.random.default_rng(seed)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    got = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=chunk))
    want = np.asarray(itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                                         jnp.asarray(wts)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("accum", ["vpu_int32", "mxu_f32"])
def test_streaming_accum_variants(accum):
    """Chunking re-establishes the mxu_f32 per-launch bound per chunk."""
    rng = np.random.default_rng(3)
    tx, tgt, wts = _random_problem(rng, 400, 11, 2, 2)
    got = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=96, accum=accum))
    want = np.asarray(itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                                         jnp.asarray(wts)))
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------ StreamingDB
def test_streaming_db_mirrors_dense_db():
    rng = np.random.default_rng(4)
    db = [[i for i in range(20) if rng.random() < 0.3] for _ in range(250)]
    y = rng.integers(0, 2, 250)
    ddb = DenseDB.encode(db, classes=list(y), n_classes=2)
    sdb = StreamingDB.encode(db, classes=list(y), n_classes=2, chunk_rows=32)
    assert sdb.vocab.items == ddb.vocab.items
    np.testing.assert_array_equal(sdb.bits, np.asarray(ddb.bits))
    np.testing.assert_array_equal(sdb.weights, np.asarray(ddb.weights))
    assert sdb.n_chunks == -(-sdb.bits.shape[0] // 32)

    targets = [(a,) for a in sdb.vocab.items[:6]]
    masks = encode_targets(targets, sdb.vocab)
    np.testing.assert_array_equal(
        np.asarray(sdb.counts(masks)),
        np.asarray(itemset_counts(ddb.bits, jnp.asarray(masks), ddb.weights)))

    proj = sdb.project(sdb.vocab.items[:5])
    dproj = ddb.project(ddb.vocab.items[:5])
    np.testing.assert_array_equal(proj.bits, np.asarray(dproj.bits))


def test_streaming_db_from_dense_roundtrip():
    rng = np.random.default_rng(5)
    db = [[i for i in range(10) if rng.random() < 0.4] for _ in range(100)]
    ddb = DenseDB.encode(db)
    sdb = StreamingDB.from_dense(ddb, chunk_rows=8)
    assert sdb.n_rows == ddb.n_rows and sdb.chunk_rows == 8
    np.testing.assert_array_equal(sdb.bits, np.asarray(ddb.bits))


# ------------------------------------------------- mining stack threading
def test_dense_gfp_counts_streaming_path():
    from repro.core import ItemOrder, TISTree, brute_force_counts

    rng = np.random.default_rng(6)
    db = [[i for i in range(12) if rng.random() < 0.35] for _ in range(150)]
    counts = {}
    for t in db:
        for a in set(t):
            counts[a] = counts.get(a, 0) + 1
    order = ItemOrder.from_counts(counts)
    tis = TISTree(order)
    for t in ([0, 1], [2], [3, 4], [1, 5, 6], [7]):
        t = [a for a in t if a in order]
        if t:
            tis.insert(t, target=True)
    ddb = DenseDB.encode(db)
    base = dense_gfp_counts(tis, ddb)
    via_flag = dense_gfp_counts(tis, ddb, streaming=True, chunk_rows=16)
    via_sdb = dense_gfp_counts(tis, StreamingDB.from_dense(ddb, chunk_rows=16))
    assert base.keys() == via_flag.keys() == via_sdb.keys()
    for k in base:
        np.testing.assert_array_equal(base[k], via_flag[k])
        np.testing.assert_array_equal(base[k], via_sdb[k])
    want = brute_force_counts(db, list(base.keys()))
    assert {k: int(v[0]) for k, v in via_flag.items()} == want


@pytest.mark.parametrize("chunk", [16, 64, 1000])
def test_streaming_mine_equals_dense_and_host(chunk):
    rng = np.random.default_rng(chunk)
    db = [[i for i in range(14) if rng.random() < 0.35] for _ in range(220)]
    want = mine_frequent(db, 35)
    ddb = DenseDB.encode(db)
    assert dense_mine_frequent(ddb, 35) == want
    assert dense_mine_frequent(ddb, 35, streaming=True, chunk_rows=chunk) == want
    sdb = StreamingDB.encode(db, chunk_rows=chunk)
    assert streaming_mine_frequent(sdb, 35) == want


def test_minority_report_dense_streaming_identical_rules():
    rng = np.random.default_rng(8)
    db = [[i for i in range(16) if rng.random() < 0.3] for _ in range(300)]
    y = [int(rng.random() < 0.15) for _ in range(300)]
    host = minority_report(db, y, min_support=0.02, min_confidence=0.1)
    dense = minority_report_dense(db, y, min_support=0.02, min_confidence=0.1)
    stream = minority_report_dense(db, y, min_support=0.02, min_confidence=0.1,
                                   streaming=True, chunk_rows=24)
    key = lambda rs: [(r.antecedent, r.count, r.g_count) for r in rs]
    assert key(stream.rules) == key(dense.rules) == key(host.rules)
    assert stream.engine == "streaming" and dense.engine == "dense"


def test_distributed_counts_chunked_single_device():
    import jax

    from repro.mining.distributed import distributed_counts

    rng = np.random.default_rng(9)
    db = [[i for i in range(12) if rng.random() < 0.35] for _ in range(180)]
    vocab = ItemVocab.from_transactions(db)
    bits = encode_bitmap(db, vocab)
    w = np.ones((180, 1), np.int32)
    targets = [(a,) for a in vocab.items[:8]]
    masks = encode_targets(targets, vocab)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    whole = distributed_counts(bits, masks, w, mesh)
    chunked = distributed_counts(bits, masks, w, mesh, chunk_rows=33)
    np.testing.assert_array_equal(whole, chunked)


# ------------------------------------------------- checkpoint kill/resume
class _Preempted(Exception):
    pass


def test_checkpoint_mid_level_kill_resume(tmp_path):
    rng = np.random.default_rng(10)
    db = [[i for i in range(10) if rng.random() < 0.4] for _ in range(200)]
    want = mine_frequent(db, 40)
    sdb = StreamingDB.encode(db, chunk_rows=16)
    assert sdb.n_chunks >= 4  # several chunks per level or the test is vacuous

    ckpt = MiningCheckpoint(str(tmp_path / "mine.json"))
    calls = []

    def die_mid_level_2(level, chunk):
        calls.append((level, chunk))
        if len(calls) == sdb.n_chunks + 3:  # 3 chunks into level 2
            raise _Preempted()

    with pytest.raises(_Preempted):
        streaming_mine_frequent(sdb, 40, checkpoint=ckpt,
                                on_chunk=die_mid_level_2)

    # the durable state holds a mid-level partial at the right chunk
    state = json.load(open(str(tmp_path / "mine.json")))
    assert state["level"] == 1  # level 1 complete, level 2 in flight
    assert state["partial"]["level"] == 2
    assert state["partial"]["next_chunk"] == 3

    resumed = []
    got = streaming_mine_frequent(
        sdb, 40, checkpoint=ckpt,
        on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want                      # identical rules after resume
    assert resumed[0] == (2, 3)             # resumed mid-level, chunk 3
    assert len(resumed) < len(calls) + sdb.n_chunks  # skipped counted work


def test_checkpoint_resume_after_complete_level(tmp_path):
    """Kill exactly on a level boundary: resume regenerates the next level."""
    rng = np.random.default_rng(11)
    db = [[i for i in range(9) if rng.random() < 0.45] for _ in range(150)]
    want = mine_frequent(db, 30)
    sdb = StreamingDB.encode(db, chunk_rows=20)
    ckpt = MiningCheckpoint(str(tmp_path / "mine.json"))
    calls = []

    def die_on_boundary(level, chunk):
        calls.append((level, chunk))
        if level == 2 and chunk == sdb.n_chunks - 1:
            raise _Preempted()  # after level 2's last chunk save, pre-absorb

    with pytest.raises(_Preempted):
        streaming_mine_frequent(sdb, 30, checkpoint=ckpt,
                                on_chunk=die_on_boundary)
    got = streaming_mine_frequent(sdb, 30, checkpoint=ckpt)
    assert got == want


def test_checkpoint_resume_rejects_changed_chunking(tmp_path):
    """A partial saved under one chunk geometry must NOT seed a resume under
    another (chunk indices don't transfer): the level restarts from chunk 0
    and the result stays exact."""
    from dataclasses import replace

    rng = np.random.default_rng(12)
    db = [[i for i in range(10) if rng.random() < 0.4] for _ in range(200)]
    want = mine_frequent(db, 40)
    sdb = StreamingDB.encode(db, chunk_rows=16)
    ckpt = MiningCheckpoint(str(tmp_path / "mine.json"))
    calls = []

    def die_mid_level_2(level, chunk):
        calls.append((level, chunk))
        if len(calls) == sdb.n_chunks + 3:
            raise _Preempted()

    with pytest.raises(_Preempted):
        streaming_mine_frequent(sdb, 40, checkpoint=ckpt,
                                on_chunk=die_mid_level_2)

    resumed = []
    got = streaming_mine_frequent(
        replace(sdb, chunk_rows=8), 40, checkpoint=ckpt,
        on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0] == (2, 0)  # level restarted, not resumed mid-sweep


def test_streaming_counts_int32_overflow_guard():
    tx = np.full((2, 1), 0xFFFFFFFF, np.uint32)
    tgt = np.zeros((1, 1), np.uint32)
    w = np.full((2, 1), 1 << 30, np.int32)  # column sum = 2^31 > int32 max
    with pytest.raises(OverflowError):
        streaming_counts(tx, tgt, w, chunk_rows=1)


def test_distributed_counts_int32_overflow_guard():
    import jax

    from repro.mining.distributed import distributed_counts

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tx = np.full((2, 1), 0xFFFFFFFF, np.uint32)
    tgt = np.zeros((1, 1), np.uint32)
    w = np.full((2, 1), 1 << 30, np.int32)
    with pytest.raises(OverflowError):
        distributed_counts(tx, tgt, w, mesh)


def test_explicit_streaming_false_wins_and_checkpoint_conflicts(tmp_path):
    """streaming=False must mean the same thing at every entry point, and a
    checkpoint (streaming-only feature) with streaming=False is an error."""
    rng = np.random.default_rng(13)
    db = [[i for i in range(8) if rng.random() < 0.4] for _ in range(60)]
    y = [int(rng.random() < 0.3) for _ in range(60)]
    ck = MiningCheckpoint(str(tmp_path / "c.json"))
    with pytest.raises(ValueError):
        minority_report_dense(db, y, min_support=0.05, min_confidence=0.1,
                              streaming=False, checkpoint=ck)
    with pytest.raises(ValueError):
        dense_mine_frequent(DenseDB.encode(db), 5, streaming=False,
                            checkpoint=ck)
    # explicit False + chunk_rows: dense engine, chunk_rows ignored
    res = minority_report_dense(db, y, min_support=0.05, min_confidence=0.1,
                                streaming=False, chunk_rows=7)
    assert res.engine == "dense"


def test_checkpoint_backward_compatible_load(tmp_path):
    """Old-format payloads (no 'partial' key) still load."""
    path = str(tmp_path / "old.json")
    with open(path, "w") as f:
        json.dump({"level": 2, "frequent": [[[1], 5], [[1, 2], 3]],
                   "meta": {}}, f)
    ck = MiningCheckpoint(path)
    level, freq, meta = ck.load()
    assert level == 2 and freq == {(1,): 5, (1, 2): 3}
    state = ck.load_state()
    assert state["partial"] is None
