"""``python -O`` regression battery for the converted assert sites.

Bare asserts vanish under ``-O``; this PR converted the input-validation
and exactness checks in the kernel oracle, the dense miner, and the cache
ledger to typed exceptions precisely so they survive optimized runs.  One
``-O`` subprocess exercises all three sites (amortizing the jax import)
and emits per-site verdicts; the tests just read them.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

_PROBE = r"""
import json

verdicts = {"O_active": not __debug__}

import numpy as np
import jax.numpy as jnp

from repro.kernels.itemset_count.ref import itemset_counts_ref
try:
    itemset_counts_ref(jnp.zeros((2, 2), jnp.uint32),
                       jnp.zeros((1, 3), jnp.uint32),
                       jnp.zeros((2, 1), jnp.int32))
    verdicts["ref"] = "no-raise"
except ValueError as e:
    verdicts["ref"] = f"ValueError: {e}"
except Exception as e:
    verdicts["ref"] = type(e).__name__

from repro.mining.dense import _crosscheck_fused
try:
    _crosscheck_fused((3,), 5, 6, "ref")
    verdicts["dense"] = "no-raise"
except RuntimeError as e:
    verdicts["dense"] = f"RuntimeError: {e}"
except Exception as e:
    verdicts["dense"] = type(e).__name__

from repro.serve.cache import CountCache, check_cache_ledger
cache = CountCache(capacity=4)
cache.put((1, 2), 0, np.zeros(3, np.int32))
cache.inserts += 5          # corrupt the ledger on purpose
try:
    check_cache_ledger(cache)
    verdicts["cache"] = "no-raise"
except AssertionError as e:
    verdicts["cache"] = f"AssertionError: {e}"
except Exception as e:
    verdicts["cache"] = type(e).__name__

print(json.dumps(verdicts))
"""


@pytest.fixture(scope="module")
def optimized_verdicts():
    proc = subprocess.run(
        [sys.executable, "-O", "-c", _PROBE],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp", "JAX_PLATFORMS": "cpu"}, timeout=300)
    assert proc.returncode == 0, proc.stderr
    verdicts = json.loads(proc.stdout.strip().splitlines()[-1])
    assert verdicts["O_active"], "probe did not actually run under -O"
    return verdicts


def test_kernel_oracle_validation_survives_O(optimized_verdicts):
    v = optimized_verdicts["ref"]
    assert v.startswith("ValueError"), v
    assert "word-width mismatch" in v


def test_dense_crosscheck_survives_O(optimized_verdicts):
    v = optimized_verdicts["dense"]
    assert v.startswith("RuntimeError"), v
    assert "exactness violation" in v


def test_cache_ledger_check_survives_O(optimized_verdicts):
    v = optimized_verdicts["cache"]
    assert v.startswith("AssertionError"), v
    assert "ledger violation" in v
