"""Shared test helpers (imported by name from the tests directory)."""
import numpy as np


def random_problem(rng, n, k, w, c, density=0.3):
    """Random counting problem as numpy arrays: sparse (N, W) transaction
    bitmap, (K, W) targets with 1-3 set bits (so containment happens), and
    small non-negative (N, C) weights."""
    tx = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    tx &= rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)  # sparsify
    tgt = np.zeros((k, w), dtype=np.uint32)
    for i in range(k):
        for _ in range(rng.integers(1, 4)):
            b = rng.integers(0, 32 * w)
            tgt[i, b >> 5] |= np.uint32(1) << np.uint32(b & 31)
    wts = rng.integers(0, 7, size=(n, c)).astype(np.int32)
    return tx, tgt, wts
