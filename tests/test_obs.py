"""Telemetry layer battery: registry exactness, span structure, exports,
zero-overhead-when-disabled, and the perf gate's self-test.

Everything here is fast-tier: tiny DBs, short thread storms, no slow marks.
"""
import json
import subprocess
import sys
import threading
import tracemalloc
import urllib.request
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs import (REGISTRY, TRACER, counter_total, counter_value,
                       hist_get, hist_merge, hist_quantile, nearest_rank)
from repro.obs.export import prometheus_text, start_metrics_server
from repro.serve import CountServer
from repro.serve.batcher import MicroBatcher
from repro.serve.cache import CountCache, check_cache_ledger

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _fresh_telemetry():
    """Every test starts from an empty registry/ring with default switches
    and leaves the same behind."""
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# metrics registry exactness
# ---------------------------------------------------------------------------

def test_histogram_bucket_boundaries_exact():
    h = REGISTRY.histogram("t_bounds_ms", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.0001, 2.0, 5.0, 5.1, 100.0):
        h.observe(v)
    got = hist_get(REGISTRY.snapshot(), "t_bounds_ms")
    assert got["buckets"] == [1.0, 2.0, 5.0]
    # bucket i holds v <= buckets[i]; boundary values land IN their bucket
    assert got["counts"] == [2, 2, 1, 2]
    assert got["count"] == 7 == sum(got["counts"])
    assert got["sum"] == pytest.approx(0.5 + 1.0 + 1.0001 + 2.0 + 5.0
                                       + 5.1 + 100.0)


def test_observe_many_matches_per_item_observe():
    a = REGISTRY.histogram("t_many_ms", buckets=(1.0, 10.0), kind="bulk")
    b = REGISTRY.histogram("t_many_ms", kind="single")
    values = [0.2, 1.0, 3.7, 9.9, 10.0, 250.0]
    a.observe_many(values)
    for v in values:
        b.observe(v)
    snap = REGISTRY.snapshot()
    bulk = hist_get(snap, "t_many_ms", "kind=bulk")
    single = hist_get(snap, "t_many_ms", "kind=single")
    assert bulk["counts"] == single["counts"]
    assert bulk["count"] == single["count"] == len(values)
    assert bulk["sum"] == pytest.approx(single["sum"])


def test_cross_thread_counter_merge_is_exact():
    c = REGISTRY.counter("t_cross_total")
    h = REGISTRY.histogram("t_cross_ms", buckets=(1.0,))
    n_threads, per_thread = 8, 500

    def work():
        for _ in range(per_thread):
            c.inc()
            h.observe(0.5)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = REGISTRY.snapshot()
    # thread-confined shards: no lost updates, the merge is exact
    assert counter_total(snap, "t_cross_total") == n_threads * per_thread
    assert hist_get(snap, "t_cross_ms")["count"] == n_threads * per_thread
    assert REGISTRY.n_shards >= n_threads


def test_counters_allow_negative_and_restore_rolls_back():
    b = MicroBatcher()
    b.submit("a", [(1, 2), (2, 3)])
    b.submit("b", [(2, 1)])          # canonical dup of (1, 2)
    plan = b.take()
    assert counter_value(REGISTRY.snapshot(),
                         "serve_deduped_queries_total") == 1
    b.restore(plan.requests)
    snap = REGISTRY.snapshot()
    # drain-time mirrors rolled back: a re-take must count each request once
    assert counter_value(snap, "serve_requests_total") == 0
    assert counter_value(snap, "serve_queries_total") == 0
    assert counter_value(snap, "serve_deduped_queries_total") == 0
    b.take()
    snap = REGISTRY.snapshot()
    assert counter_value(snap, "serve_requests_total") == 2
    assert counter_value(snap, "serve_queries_total") == 3
    assert counter_value(snap, "serve_deduped_queries_total") == 1


def test_exclusive_gauge_is_one_hot():
    REGISTRY.set_gauge("t_decision", 1, exclusive=True, backend="dense")
    REGISTRY.set_gauge("t_decision", 1, exclusive=True, backend="gfp")
    sets = REGISTRY.snapshot()["gauges"]["t_decision"]
    assert sets == {"backend=gfp": 1}


def test_histogram_bucket_grid_is_per_name():
    REGISTRY.histogram("t_grid_ms", buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        REGISTRY.histogram("t_grid_ms", buckets=(3.0,))


def test_nearest_rank_percentiles():
    assert nearest_rank([], 0.5) is None
    assert nearest_rank([7.0], 0.95) == 7.0
    # the old lat[int(p * n)] indexing overshot: p50 of [1, 2] read 2
    assert nearest_rank([1.0, 2.0], 0.50) == 1.0
    assert nearest_rank([1.0, 2.0], 0.51) == 2.0
    assert nearest_rank(list(range(1, 101)), 0.95) == 95
    with pytest.raises(ValueError):
        nearest_rank([1.0], 1.5)


def test_hist_quantile_conservative_bound():
    h = REGISTRY.histogram("t_q_ms", buckets=(1.0, 10.0, 100.0))
    h.observe_many([0.5] * 90 + [50.0] * 10)
    merged = hist_merge(REGISTRY.snapshot(), "t_q_ms")
    assert hist_quantile(merged, 0.5) == 1.0     # true 0.5 <= bound 1.0
    assert hist_quantile(merged, 0.95) == 100.0  # true 50 <= bound 100


# ---------------------------------------------------------------------------
# zero overhead when disabled
# ---------------------------------------------------------------------------

def test_disabled_hot_path_allocates_nothing():
    c = REGISTRY.counter("t_noalloc_total")
    h = REGISTRY.histogram("t_noalloc_ms")
    obs.disable_all()
    obs_dir = str(Path(obs.__file__).parent)

    def hot():
        for _ in range(200):
            c.inc()
            h.observe(1.0)
            with TRACER.span("t.noalloc"):
                pass
            TRACER.instant("t.noalloc")

    hot()                                   # warm up any lazy imports
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    hot()
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    leaks = [s for s in after.compare_to(before, "lineno")
             if s.size_diff > 0
             and s.traceback[0].filename.startswith(obs_dir)]
    assert not leaks, [str(s) for s in leaks]
    # and nothing was recorded either
    obs.configure(metrics=True)
    snap = REGISTRY.snapshot()
    assert counter_value(snap, "t_noalloc_total") == 0
    assert hist_get(snap, "t_noalloc_ms") is None
    assert TRACER.spans() == []


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

def test_span_nesting_and_error_attr():
    obs.configure(tracing=True)
    with TRACER.span("outer", {"a": 1}) as outer:
        with TRACER.span("inner") as inner:
            TRACER.instant("mark", {"k": "v"})
        with pytest.raises(RuntimeError):
            with TRACER.span("boom"):
                raise RuntimeError("x")
    spans = {s.name: s for s in TRACER.spans()}
    assert spans["inner"].parent_id == outer.span_id
    assert spans["mark"].parent_id == inner.span_id
    assert spans["boom"].attrs["error"] == "RuntimeError"
    assert spans["outer"].parent_id is None
    assert spans["outer"].t1 >= spans["inner"].t1 >= spans["inner"].t0
    assert "outer" in TRACER.summary()


def test_trace_chain_submit_flush_kernel_under_concurrent_async(rng):
    obs.configure(tracing=True)
    tx = [sorted(rng.choice(16, size=3, replace=False).tolist())
          for _ in range(300)]
    with CountServer(tx, async_flush=True, min_batch=4,
                     max_delay_ms=5.0) as server:
        def client(cid):
            futs = [server.submit_async(f"c{cid}", [(i % 16, (i + 1) % 16)])
                    for i in range(6)]
            for f in futs:
                assert f.result(timeout=30).shape == (1, 1)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    doc = TRACER.chrome_trace()
    events = doc["traceEvents"]
    assert events and all({"name", "ph", "ts", "pid", "tid"} <= set(e)
                          for e in events)
    json.dumps(doc)                         # valid JSON end to end
    by_id = {e["args"]["span_id"]: e for e in events}
    # the full chain: submit instants link by ticket, flush > count > kernel
    submits = [e for e in events if e["name"] == "serve.submit"]
    assert submits and all(e["ph"] == "i" and "ticket" in e["args"]
                           for e in submits)
    flushes = [e for e in events if e["name"] == "serve.flush"]
    assert flushes and all(e["ph"] == "X" for e in flushes)
    kernels = [e for e in events if e["name"] == "kernel.count"]
    assert kernels
    for k in kernels:
        count = by_id[k["args"]["parent_id"]]
        assert count["name"] == "serve.count"
        flush = by_id[count["args"]["parent_id"]]
        assert flush["name"] == "serve.flush"
        assert flush["args"]["trigger"] in ("occupancy", "deadline",
                                            "manual", "drain", "sync")


def test_span_ring_is_bounded():
    t = obs.Tracer(enabled=True, ring_spans=8)
    for i in range(32):
        with t.span(f"s{i}"):
            pass
    spans = t.spans()
    assert len(spans) == 8
    assert [s.name for s in spans] == [f"s{i}" for i in range(24, 32)]


# ---------------------------------------------------------------------------
# instrumented serving stack
# ---------------------------------------------------------------------------

def _tiny_server(rng, **kw):
    tx = [sorted(rng.choice(12, size=3, replace=False).tolist())
          for _ in range(200)]
    return tx, CountServer(tx, **kw)


def test_server_stats_expose_kernel_efficiency(rng):
    tx, server = _tiny_server(rng)
    server.submit("a", [(0, 1), (2,)])
    server.flush()
    stats = server.stats()
    tele = stats["telemetry"]
    assert tele["enabled"]
    eff = tele["kernel_efficiency"]
    assert eff, "no kernel launch was recorded"
    for geom, rec in eff.items():
        assert rec["launches"] >= 1
        assert rec["measured_s"] > 0
        assert rec["predicted_s"] > 0
        assert rec["efficiency"] == pytest.approx(
            rec["predicted_s"] / rec["measured_s"])
        assert geom.startswith("n")
    snap = tele["metrics"]
    assert counter_value(snap, "serve_requests_total") == 1
    assert counter_value(snap, "serve_queries_total") == 2
    assert counter_value(snap, "serve_flushes_total", trigger="sync") == 1
    assert hist_merge(snap, "serve_queue_wait_ms")["count"] == 1
    assert "kernel launches" in obs.summary_line(snap)


def test_cache_registry_mirrors_published_at_drain_points(rng):
    tx, server = _tiny_server(rng)
    for _ in range(3):                       # 1 cold + 2 warm rounds
        server.submit("a", [(0, 1), (1, 2)])
        server.flush()
    s = server.cache.stats()
    assert s["hits"] == 4 and s["misses"] == 2 and s["inserts"] == 2
    snap = REGISTRY.snapshot()
    # flush/stats are the publish points: mirrors agree exactly there
    assert counter_value(snap, "cache_hits_total", cache="CountCache") == 4
    assert counter_value(snap, "cache_misses_total", cache="CountCache") == 2
    assert counter_value(snap, "cache_inserts_total", cache="CountCache") == 2
    check_cache_ledger(server.cache, miss_driven=True)


def test_check_cache_ledger_under_eviction_and_oversized():
    cache = CountCache(capacity=4, max_bytes=64)
    version = 0
    for i in range(8):                       # get-miss-compute-put discipline
        key = (i,)
        if cache.get(key, version) is None:
            cache.put(key, version, np.full(4, i, np.int32))   # 16 bytes
    assert cache.get((7,), version) is not None
    if cache.get(("big",), version) is None:
        cache.put(("big",), version, np.zeros(64, np.int32))   # > max_bytes
    s = check_cache_ledger(cache, miss_driven=True)
    assert s["evictions"] == 4 and s["oversized_rejects"] == 1
    assert s["size"] == 4
    cache.purge_stale(current_version=1)
    s = check_cache_ledger(cache, miss_driven=True)
    assert s["purged"] == 4 and s["size"] == 0
    # ledger == registry mirror after the stats() publish
    snap = REGISTRY.snapshot()
    for field, name in [("hits", "cache_hits_total"),
                        ("misses", "cache_misses_total"),
                        ("evictions", "cache_evictions_total"),
                        ("inserts", "cache_inserts_total"),
                        ("oversized_rejects", "cache_oversized_rejects_total"),
                        ("purged", "cache_purged_total")]:
        assert counter_value(snap, name, cache="CountCache") == s[field], name


def test_async_stats_thread_safe_under_traffic(rng):
    tx, server = _tiny_server(rng, async_flush=True, min_batch=2,
                              max_delay_ms=2.0)
    errors = []

    def poll():
        try:
            for _ in range(200):
                lat = server.stats()["async"]["flush_latency_ms"]
                for k in ("p50", "p95", "max"):
                    assert lat[k] is None or lat[k] >= 0
        except Exception as e:   # pragma: no cover - the failure signal
            errors.append(e)

    with server:
        poller = threading.Thread(target=poll)
        poller.start()
        futs = [server.submit_async("c", [(i % 12,)]) for i in range(64)]
        for f in futs:
            f.result(timeout=30)
        poller.join()
    assert not errors
    st = server.stats()["async"]
    assert st["flushes"] >= 1
    # exact nearest-rank on the recorded window
    lat = sorted(server._flusher.latencies_ms)
    assert st["flush_latency_ms"]["p50"] == nearest_rank(lat, 0.50)
    assert st["flush_latency_ms"]["p95"] == nearest_rank(lat, 0.95)


# ---------------------------------------------------------------------------
# export + gate
# ---------------------------------------------------------------------------

def test_prometheus_text_format():
    REGISTRY.counter("t_exp_total", path="host").inc(3)
    REGISTRY.set_gauge("t_exp_gauge", 2.5)
    h = REGISTRY.histogram("t_exp_ms", buckets=(1.0, 10.0))
    h.observe_many([0.5, 5.0, 50.0])
    text = prometheus_text(REGISTRY.snapshot())
    assert '# TYPE t_exp_total counter' in text
    assert 't_exp_total{path="host"} 3' in text
    assert 't_exp_gauge 2.5' in text
    assert 't_exp_ms_bucket{le="1"} 1' in text
    assert 't_exp_ms_bucket{le="10"} 2' in text
    assert 't_exp_ms_bucket{le="+Inf"} 3' in text
    assert 't_exp_ms_count 3' in text


def test_metrics_http_server_roundtrip():
    REGISTRY.counter("t_http_total").inc(7)
    srv = start_metrics_server(0)
    try:
        port = srv.server_address[1]
        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        assert "t_http_total 7" in text
        snap = json.loads(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics.json", timeout=10).read())
        assert snap["counters"]["t_http_total"][""] == 7
    finally:
        srv.shutdown()


def test_summary_line_states():
    assert obs.summary_line() == "telemetry: no activity"
    obs.configure(metrics=False)
    assert obs.summary_line() == "telemetry: disabled"


def test_perfgate_self_test_passes_and_catches_regressions():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "perfgate.py"), "--self-test"],
        cwd=REPO, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "injected regression caught" in proc.stdout
    assert "self-test OK" in proc.stdout
