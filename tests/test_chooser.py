"""Adaptive backend chooser: decision-table pins + result invariance.

The chooser maps measured dataset traits to an engine; every engine is
exact, so the pins below are PERFORMANCE-policy regression tests (a changed
threshold shows up as a changed decision), and the invariance tests assert
the part that must never change: identical mining results whichever backend
is selected.
"""
import types

import numpy as np
import pytest

from repro.core import mine_frequent
from repro.core.incremental import ceil_count
from repro.mining import (DatasetTraits, DenseDB, GFPBackend,
                          backend_for_db, choose_backend,
                          mine_frequent_backend)
from repro.mining.backend import DenseBackend, StreamingBackend
from repro.serve import CountServer, VersionedCountBackend, VersionedDB


def _traits(**kw):
    base = dict(n_rows=10_000, n_unique=9_000, vocab_size=24, n_classes=1,
                nbytes=1 << 20, density=0.05, skew=1.5, dedup_ratio=0.9)
    base.update(kw)
    return DatasetTraits(**base)


def _tx(seed, n, m, p):
    rng = np.random.default_rng(seed)
    return [[i for i in range(m) if rng.random() < p] for _ in range(n)]


# ----------------------------------------------------------- decision table
def test_decision_table_pins():
    # dense + compressible, deep mine -> the GFP hybrid
    assert choose_backend(
        _traits(density=0.5, dedup_ratio=0.3)).name == "gfp"
    # heavy item skew alone also routes to GFP
    assert choose_backend(_traits(skew=10.0)).name == "gfp"
    # sparse, uniform, incompressible -> level-wise dense sweep
    assert choose_backend(_traits()).name == "dense"
    # footprint beyond device residency -> streaming, whatever else holds
    assert choose_backend(
        _traits(nbytes=600 << 20, density=0.5, dedup_ratio=0.3,
                skew=10.0)).name == "streaming"
    # tiny DBs never leave the dense sweep
    assert choose_backend(
        _traits(n_rows=500, density=0.5, dedup_ratio=0.3)).name == "dense"
    # a multi-device mesh wins over everything
    mesh = types.SimpleNamespace(size=8)
    assert choose_backend(_traits(), mesh=mesh).name == "distributed"
    # ... but a single-device mesh does not force sharding
    one = types.SimpleNamespace(size=1)
    assert choose_backend(_traits(density=0.5, dedup_ratio=0.3),
                          mesh=one).name == "gfp"
    # shallow mines don't pay FP-tree construction: bounded max_len under
    # the depth threshold stays level-wise even on GFP-shaped data
    assert choose_backend(_traits(density=0.5, dedup_ratio=0.3),
                          max_len=2).name == "dense"
    assert choose_backend(_traits(density=0.5, dedup_ratio=0.3),
                          max_len=4).name == "gfp"


def test_measured_traits_sane():
    tx = _tx(0, 4000, 12, 0.5)
    db = DenseDB.encode(tx)
    t = DatasetTraits.of_db(db)
    assert t.n_rows == 4000
    assert 0 < t.n_unique <= 4000
    assert t.vocab_size == 12
    assert 0.3 < t.density < 0.7          # p = 0.5 by construction
    assert t.skew >= 1.0
    assert t.dedup_ratio == t.n_unique / t.n_rows
    assert t.nbytes > 0

    empty = DatasetTraits.measure(np.zeros((0, 1), np.uint32),
                                  np.zeros((0, 1), np.int32), db.vocab, 0)
    assert empty.density == 0.0 and empty.skew == 1.0 \
        and empty.dedup_ratio == 1.0


# ------------------------------------------------- construction + invariance
def test_backend_for_db_constructs_choice_and_results_agree():
    tx = _tx(1, 5000, 10, 0.5)
    db = DenseDB.encode(tx)
    want = mine_frequent(tx, 800)

    be, choice = backend_for_db(db)
    # 10 items at p=0.5: <= 1024 unique rows over 5000 -> compressible+dense
    assert choice.name == "gfp"
    assert isinstance(be, GFPBackend)
    assert choice.traits is not None and choice.traits.dedup_ratio < 0.5

    forced_dense, cd = backend_for_db(db, name="dense")
    forced_stream, cs = backend_for_db(db, name="streaming")
    assert isinstance(forced_dense, DenseBackend)
    assert isinstance(forced_stream, StreamingBackend)
    assert cd.name == "dense" and cs.name == "streaming"
    assert cd.traits is None               # forced picks measure nothing

    assert mine_frequent_backend(be, 800) \
        == mine_frequent_backend(forced_dense, 800) \
        == mine_frequent_backend(forced_stream, 800) == want

    with pytest.raises(ValueError):
        backend_for_db(db, name="bogus")


def test_count_server_mine_backend_invariant():
    tx = _tx(2, 3000, 10, 0.5)
    theta = 0.2
    want = mine_frequent(tx, ceil_count(theta * len(tx)))

    srv = CountServer(tx)
    auto = srv.mine(theta)
    assert srv.last_backend_choice.name == "gfp"   # dense + compressible
    assert auto == want

    # identical results whichever backend mines the store
    assert srv.mine(theta, backend="store") == want
    assert srv.last_backend_choice.name == "store"
    assert srv.mine(theta, backend="gfp") == want
    assert srv.last_backend_choice.name == "gfp"
    assert srv.mine(theta, backend="dense") == want

    with pytest.raises(ValueError):
        srv.mine(theta, backend="bogus")

    # a sharded store always mines through its own all-reduced sweep
    sharded = CountServer(tx, shards=2)
    assert sharded.mine(theta) == want
    assert sharded.last_backend_choice.name == "store"


def test_store_records_adaptive_residency_choice():
    tx = _tx(3, 2500, 10, 0.5)
    store = VersionedDB(tx)
    assert store.backend_choice is not None
    assert store.backend_choice.name != "streaming"   # small footprint
    assert store.resident == "dense"
    assert store.stats()["backend_choice"] == store.backend_choice.name
    # explicit residency bypasses the chooser entirely
    forced = VersionedDB(tx, streaming=True)
    assert forced.backend_choice is None
    assert forced.resident == "streaming"
    assert forced.stats()["backend_choice"] is None
    # the composed backend exposes measured traits for CountServer.mine
    t = VersionedCountBackend(store).traits()
    assert t.n_rows == len(tx) and t.density > 0.3
