"""Disk-tier chunk store: spilled sweep bit-identical to streaming == dense ==
oracle (prefetch on AND off), segment-grid resume parity, prefetch-hit
telemetry, manifest/open validation, hard-kill resume with segments on disk,
VersionedDB spilled residency + generation cleanup, and the background
compactor (exactness under racing appends, build-failure absorption)."""
import json
import os
import shutil
import subprocess
import sys
import tempfile
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings, strategies as st  # hypothesis or offline shim
from _testutil import random_problem as _random_problem

from repro.core import mine_frequent
from repro.kernels.itemset_count import itemset_counts, itemset_counts_ref
from repro.mining import (DenseDB, ItemVocab, SpilledBackend, SpilledDB,
                          StreamingDB, encode_targets, spilled_counts,
                          streaming_counts)
from repro.mining import mine_frequent_backend
from repro.mining.chooser import DatasetTraits, backend_for_db, choose_backend
from repro.mining.distributed import MiningCheckpoint
from repro.mining.spill import MANIFEST_NAME
from repro.obs import REGISTRY, counter_total
from repro.serve import VersionedDB, versioned_mine_frequent

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class _Preempted(Exception):
    pass


def _db(rng, rows, items, p=0.3):
    return [[int(a) for a in range(items) if rng.random() < p]
            for _ in range(rows)]


def _spill_problem(tmp, rng_seed=0, n=300, k=17, w=3, c=2, chunk=64):
    """Random counting problem spilled to disk alongside its host arrays."""
    rng = np.random.default_rng(rng_seed)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    vocab = ItemVocab(tuple(range(32 * w)))
    db = SpilledDB.spill(vocab, tx, wts, n, c, str(tmp), chunk_rows=chunk)
    return db, tx, tgt, wts


# ------------------------------------------------------- roundtrip + facts
def test_spill_roundtrip_and_manifest_facts(tmp_path):
    db, tx, tgt, wts = _spill_problem(tmp_path, n=300, chunk=64)
    assert os.path.exists(os.path.join(str(tmp_path), MANIFEST_NAME))
    assert db.n_chunks == len(db.seg_rows) == -(-300 // 64)
    assert db.seg_rows == (64, 64, 64, 64, 44)
    assert db.n_unique == 300 and db.n_words == 3
    assert db.nbytes == 4 * (3 + 2) * 300
    # materialization (the compaction path) reproduces the host arrays
    np.testing.assert_array_equal(db.bits, tx)
    np.testing.assert_array_equal(db.weights, wts)
    hb, hw = db.head(10)
    np.testing.assert_array_equal(hb, tx[:10])
    np.testing.assert_array_equal(hw, wts[:10])

    # reopen from the manifest: same grid, same counts
    re = SpilledDB.open(str(tmp_path))
    assert re.seg_rows == db.seg_rows and re.chunk_rows == db.chunk_rows
    assert re.vocab.items == db.vocab.items
    np.testing.assert_array_equal(np.asarray(re.counts(tgt)),
                                  np.asarray(db.counts(tgt)))


def test_spill_from_streaming_keeps_grid(tmp_path):
    rng = np.random.default_rng(1)
    tx = _db(rng, 150, 12)
    sdb = StreamingDB.encode(tx, chunk_rows=32)
    spl = SpilledDB.from_streaming(sdb, str(tmp_path))
    assert spl.chunk_rows == 32 and spl.n_chunks == sdb.n_chunks
    np.testing.assert_array_equal(spl.bits, sdb.bits)
    masks = encode_targets([(a,) for a in sdb.vocab.items[:6]], sdb.vocab)
    np.testing.assert_array_equal(np.asarray(spl.counts(masks)),
                                  np.asarray(sdb.counts(masks)))


def test_spill_empty_and_single_segment(tmp_path):
    vocab = ItemVocab((0, 1))
    empty = SpilledDB.spill(vocab, np.zeros((0, 1), np.uint32),
                            np.zeros((0, 1), np.int32), 0, 1,
                            str(tmp_path / "empty"))
    assert empty.n_chunks == 0 and empty.bits.shape == (0, 1)
    tgt = np.zeros((3, 1), np.uint32)
    assert np.asarray(empty.counts(tgt)).shape == (3, 1)
    assert (np.asarray(empty.counts(tgt)) == 0).all()

    rng = np.random.default_rng(2)
    tx, tgt, wts = _random_problem(rng, 40, 5, 1, 1)
    one = SpilledDB.spill(ItemVocab(tuple(range(32))), tx, wts, 40, 1,
                          str(tmp_path / "one"), chunk_rows=4096)
    assert one.n_chunks == 1   # single segment: exact-rows launch, no prefetch
    want = np.asarray(itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                                         jnp.asarray(wts)))
    np.testing.assert_array_equal(np.asarray(one.counts(tgt)), want)


def test_spill_validation_errors(tmp_path):
    vocab = ItemVocab((("a", 1), ("b", 2)))  # tuples don't JSON-round-trip
    with pytest.raises(TypeError):
        SpilledDB.spill(vocab, np.zeros((2, 1), np.uint32),
                        np.ones((2, 1), np.int32), 2, 1, str(tmp_path / "t"))

    # int32 overflow guard (same contract as the streaming sweep)
    with pytest.raises(OverflowError):
        SpilledDB.spill(ItemVocab((0,)), np.zeros((2, 1), np.uint32),
                        np.full((2, 1), 1 << 30, np.int32), 2, 1,
                        str(tmp_path / "o"))

    db, _, tgt, _ = _spill_problem(tmp_path / "g", chunk=64)
    with pytest.raises(ValueError):      # immutable on-disk grid
        spilled_counts(db, tgt, chunk_rows=32)

    # torn store: manifest lists a segment that is gone
    os.remove(os.path.join(db.directory, "seg00002.bits.npy"))
    with pytest.raises(FileNotFoundError):
        SpilledDB.open(db.directory)

    # unknown format fails loudly
    bad = tmp_path / "bad"
    os.makedirs(str(bad))
    with open(os.path.join(str(bad), MANIFEST_NAME), "w") as f:
        json.dump({"format": "not-a-spill"}, f)
    with pytest.raises(ValueError):
        SpilledDB.open(str(bad))


# ------------------------------------------------- bit-identical counting
@pytest.mark.parametrize("chunk,prefetch", [(7, True), (64, True), (64, False),
                                            (300, True), (10_000, False)])
def test_spilled_counts_bit_identical(tmp_path, chunk, prefetch):
    db, tx, tgt, wts = _spill_problem(tmp_path, rng_seed=chunk, chunk=chunk)
    got = np.asarray(spilled_counts(db, tgt, prefetch=prefetch))
    stream = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=chunk))
    want = np.asarray(itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                                         jnp.asarray(wts)))
    np.testing.assert_array_equal(got, stream)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=1, max_value=150),    # n
    st.integers(min_value=1, max_value=12),     # k
    st.integers(min_value=1, max_value=3),      # w
    st.integers(min_value=1, max_value=3),      # c
    st.integers(min_value=1, max_value=200),    # chunk_rows
    st.sampled_from([True, False]),             # prefetch
    st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_spilled_property_random(n, k, w, c, chunk, prefetch, seed):
    rng = np.random.default_rng(seed)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    d = tempfile.mkdtemp(prefix="repro-spill-test-")
    try:
        db = SpilledDB.spill(ItemVocab(tuple(range(32 * w))), tx, wts,
                             n, c, d, chunk_rows=chunk)
        got = np.asarray(spilled_counts(db, tgt, prefetch=prefetch))
    finally:
        shutil.rmtree(d, ignore_errors=True)
    want = np.asarray(itemset_counts_ref(jnp.asarray(tx), jnp.asarray(tgt),
                                         jnp.asarray(wts)))
    np.testing.assert_array_equal(got, want)


def test_spilled_counts_resume_parity(tmp_path):
    """init/start_chunk/on_chunk resume == one sweep (the checkpoint seam)."""
    db, tx, tgt, wts = _spill_problem(tmp_path, rng_seed=5, chunk=48)
    full = np.asarray(spilled_counts(db, tgt))
    first = None

    def grab(j, acc):
        nonlocal first
        if j == 1:
            first = np.asarray(acc)

    np.asarray(spilled_counts(db, tgt, on_chunk=grab))
    resumed = np.asarray(spilled_counts(db, tgt, start_chunk=2, init=first))
    np.testing.assert_array_equal(resumed, full)
    # start past the last segment: the init accumulator comes back untouched
    done = np.asarray(spilled_counts(db, tgt, start_chunk=db.n_chunks,
                                     init=full))
    np.testing.assert_array_equal(done, full)


# ------------------------------------------------------ prefetch telemetry
def test_prefetch_hit_accounting(tmp_path):
    db, _, tgt, _ = _spill_problem(tmp_path, rng_seed=6, n=400, chunk=32)
    assert db.n_chunks >= 8
    before = REGISTRY.snapshot()

    np.asarray(spilled_counts(db, tgt, prefetch=True))
    after = REGISTRY.snapshot()
    handoffs = ((counter_total(after, "spill_prefetch_hits_total")
                 + counter_total(after, "spill_prefetch_misses_total"))
                - (counter_total(before, "spill_prefetch_hits_total")
                   + counter_total(before, "spill_prefetch_misses_total")))
    assert handoffs == db.n_chunks          # one handoff per segment
    assert "spill_prefetch_hit_ratio" in after.get("gauges", {})
    read = (counter_total(after, "spill_bytes_read_total")
            - counter_total(before, "spill_bytes_read_total"))
    assert read > 0

    # synchronous ablation performs no prefetcher handoffs at all
    base = REGISTRY.snapshot()
    np.asarray(spilled_counts(db, tgt, prefetch=False))
    sync = REGISTRY.snapshot()
    for name in ("spill_prefetch_hits_total", "spill_prefetch_misses_total"):
        assert counter_total(sync, name) == counter_total(base, name)


def test_prefetch_error_surfaces_on_consumer(tmp_path):
    db, _, tgt, _ = _spill_problem(tmp_path, rng_seed=7, n=300, chunk=32)
    os.remove(os.path.join(db.directory, "seg00003.bits.npy"))
    before = counter_total(REGISTRY.snapshot(), "spill_prefetch_errors_total")
    with pytest.raises(FileNotFoundError):
        spilled_counts(db, tgt, prefetch=True)
    assert counter_total(REGISTRY.snapshot(),
                         "spill_prefetch_errors_total") == before + 1
    # the synchronous path raises the same error on the consumer directly
    with pytest.raises(FileNotFoundError):
        spilled_counts(db, tgt, prefetch=False)


# ----------------------------------------------------- backend + chooser
def test_spilled_backend_mine_matches_host(tmp_path):
    rng = np.random.default_rng(8)
    tx = _db(rng, 200, 10, p=0.4)
    want = mine_frequent(tx, 40)
    sdb = StreamingDB.encode(tx, chunk_rows=16)
    spl = SpilledDB.from_streaming(sdb, str(tmp_path))
    backend = SpilledBackend(spl)
    assert backend.n_count_chunks == spl.n_chunks
    assert backend.chunk_signature()["backend"] == "spilled"
    got = mine_frequent_backend(backend, 40)
    assert got == want
    # traits report the TRUE on-disk footprint, not the head sample's
    t = backend.traits()
    assert t.nbytes == spl.nbytes and t.n_unique == spl.n_unique


def test_chooser_spill_verdict_and_backend_for_db(tmp_path, monkeypatch):
    rng = np.random.default_rng(9)
    tx = _db(rng, 120, 10, p=0.4)
    ddb = DenseDB.encode(tx)
    traits = DatasetTraits.of_db(ddb)
    # over-budget: disk tier wins (opt-in: threshold must be passed)
    c = choose_backend(traits, spill_threshold_bytes=64)
    assert c.name == "spilled" and "spill budget" in c.reason
    assert choose_backend(traits).name != "spilled"   # no budget, no spill

    monkeypatch.setenv("REPRO_SPILL_DIR", str(tmp_path / "auto"))
    os.makedirs(str(tmp_path / "auto"), exist_ok=True)
    backend, choice = backend_for_db(ddb, spill_threshold_bytes=64)
    assert choice.name == "spilled" and isinstance(backend, SpilledBackend)
    want = mine_frequent(tx, 30)
    assert mine_frequent_backend(backend, 30) == want


def test_spilled_backend_checkpoint_kill_resume(tmp_path):
    """In-process preemption mid-level; the resume reopens the store FROM
    DISK (SpilledDB.open) — segment files + checkpoint are the durable
    state, exactly the kill/resume contract of the streaming engine."""
    rng = np.random.default_rng(10)
    tx = _db(rng, 200, 10, p=0.4)
    want = mine_frequent(tx, 40)
    sdb = StreamingDB.encode(tx, chunk_rows=16)
    spl = SpilledDB.from_streaming(sdb, str(tmp_path / "seg"))
    assert spl.n_chunks >= 4
    ckpt = MiningCheckpoint(str(tmp_path / "mine.json"))
    calls = []

    def die_mid_level_2(level, chunk):
        calls.append((level, chunk))
        if len(calls) == spl.n_chunks + 3:
            raise _Preempted()

    with pytest.raises(_Preempted):
        mine_frequent_backend(SpilledBackend(spl), 40, checkpoint=ckpt,
                              on_chunk=die_mid_level_2)

    state = json.load(open(str(tmp_path / "mine.json")))
    assert state["partial"]["next_chunk"] == 3

    reopened = SpilledDB.open(str(tmp_path / "seg"))   # fresh object, disk-only
    resumed = []
    got = mine_frequent_backend(SpilledBackend(reopened), 40, checkpoint=ckpt,
                                on_chunk=lambda l, c: resumed.append((l, c)))
    assert got == want
    assert resumed[0][1] == 3                # resumed mid-level at chunk 3
    assert len(resumed) < len(calls) + spl.n_chunks


_KILL_SCRIPT = textwrap.dedent("""
    import os, sys
    from repro.mining import SpilledBackend, SpilledDB, mine_frequent_backend
    from repro.mining.distributed import MiningCheckpoint

    seg_dir, ckpt_path, min_count = sys.argv[1], sys.argv[2], int(sys.argv[3])
    db = SpilledDB.open(seg_dir)
    calls = []

    def hard_kill(level, chunk):
        calls.append((level, chunk))
        if len(calls) == db.n_chunks + 3:
            os._exit(17)       # SIGKILL-equivalent: no finally, no flush

    mine_frequent_backend(SpilledBackend(db), min_count,
                          checkpoint=MiningCheckpoint(ckpt_path),
                          on_chunk=hard_kill)
    os._exit(0)                # must not be reached
""")


def test_spilled_hard_kill_process_resume(tmp_path):
    """Process death mid-level (os._exit: no cleanup handlers run): the
    parent reopens the SAME on-disk segments + checkpoint and finishes the
    mine bit-identically to the never-killed run."""
    rng = np.random.default_rng(11)
    tx = _db(rng, 200, 10, p=0.4)
    want = mine_frequent(tx, 40)
    sdb = StreamingDB.encode(tx, chunk_rows=16)
    spl = SpilledDB.from_streaming(sdb, str(tmp_path / "seg"))
    assert spl.n_chunks >= 4
    ckpt_path = str(tmp_path / "mine.json")

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _KILL_SCRIPT, str(tmp_path / "seg"),
         ckpt_path, "40"], env=env, capture_output=True, text=True,
        timeout=600)
    assert proc.returncode == 17, proc.stderr   # it died where we told it to

    state = json.load(open(ckpt_path))
    assert state["partial"] is not None         # durable mid-level partial

    reopened = SpilledDB.open(str(tmp_path / "seg"))
    got = mine_frequent_backend(SpilledBackend(reopened), 40,
                                checkpoint=MiningCheckpoint(ckpt_path))
    assert got == want


# -------------------------------------------------- VersionedDB disk tier
def _oracle(history, classes, n_classes, keys):
    ddb = DenseDB.encode(history, classes=classes, n_classes=n_classes)
    out = np.zeros((len(keys), n_classes), np.int32)
    known = [i for i, k in enumerate(keys)
             if all(a in ddb.vocab for a in k)]
    if known:
        masks = encode_targets([keys[i] for i in known], ddb.vocab)
        got = np.asarray(itemset_counts(ddb.bits, jnp.asarray(masks),
                                        ddb.weights))
        out[np.array(known)] = got
    return out


def test_versioned_db_spilled_residency_and_gen_cleanup(tmp_path):
    rng = np.random.default_rng(12)
    tx = _db(rng, 200, 10)
    y = [int(rng.random() < 0.3) for _ in tx]
    db = VersionedDB(tx, classes=y, n_classes=2, spill=True,
                     spill_dir=str(tmp_path), chunk_rows=32,
                     merge_ratio=1e9)        # keep the delta resident
    assert db.resident == "spilled"
    st_ = db.stats()
    assert st_["resident"] == "spilled"
    assert st_["spill"]["segments"] == db.base.n_chunks >= 2
    assert st_["spill"]["chunk_rows"] == 32
    history, classes = list(tx), list(y)
    probes = [(0, 1), (2,), (3, 7, 9), (11,)]
    np.testing.assert_array_equal(db.counts(probes),
                                  _oracle(history, classes, 2, probes))

    batch = _db(rng, 40, 12)
    yb = [int(rng.random() < 0.3) for _ in batch]
    db.append(batch, classes=yb)
    history += batch
    classes += yb
    assert db.delta_rows > 0                 # composed base+delta sweep
    np.testing.assert_array_equal(db.counts(probes),
                                  _oracle(history, classes, 2, probes))

    old_dir = db.base.directory
    db.compact()                             # fold: new gen dir, old deleted
    assert db.resident == "spilled" and db.delta_rows == 0
    assert db.base.directory != old_dir
    assert not os.path.exists(old_dir)       # replaced gen cleaned up
    assert os.path.exists(os.path.join(db.base.directory, MANIFEST_NAME))
    np.testing.assert_array_equal(db.counts(probes),
                                  _oracle(history, classes, 2, probes))


def test_versioned_db_auto_spill_threshold(tmp_path):
    rng = np.random.default_rng(13)
    tx = _db(rng, 150, 10)
    db = VersionedDB(tx, spill_dir=str(tmp_path), spill_threshold_bytes=64,
                     chunk_rows=32)
    assert db.resident == "spilled"          # footprint > 64-byte budget
    probes = [(0,), (1, 2), (4, 5, 6)]
    np.testing.assert_array_equal(
        db.counts(probes), _oracle(tx, None, 1, probes))
    # under-budget store stays in host RAM
    small = VersionedDB(tx[:5], spill_dir=str(tmp_path / "small"),
                        spill_threshold_bytes=1 << 30)
    assert small.resident != "spilled"


def test_versioned_mine_over_spilled_base(tmp_path):
    rng = np.random.default_rng(14)
    tx = _db(rng, 200, 10, p=0.4)
    db = VersionedDB(tx, spill=True, spill_dir=str(tmp_path), chunk_rows=32)
    assert db.resident == "spilled"
    assert versioned_mine_frequent(db, 40) == mine_frequent(tx, 40)


# ------------------------------------------- compaction policy + compactor
def test_min_compact_rows_floor_stops_bootstrap_thrash(tmp_path):
    """Satellite-1 regression: a cold-start append loop used to compact on
    EVERY tiny batch (delta_rows > ratio * max(1, 0) is true immediately).
    The row floor keeps compaction off until the delta is worth folding."""
    rng = np.random.default_rng(15)

    def run(min_compact_rows):
        db = VersionedDB(n_classes=1, min_compact_rows=min_compact_rows)
        history = []
        for _ in range(20):
            batch = _db(rng, 8, 8)
            db.append(batch)
            history += batch
        probes = [(0,), (1, 2), (3,)]
        np.testing.assert_array_equal(
            db.counts(probes), _oracle(history, None, 1, probes))
        return db

    floored = run(min_compact_rows=None)     # default floor
    assert floored.n_compactions == 0        # no thrash on cold start
    assert floored.stats()["min_compact_rows"] > 0
    thrash = run(min_compact_rows=0)         # floor off: the old behavior
    assert thrash.n_compactions >= 10        # compacted on most tiny appends
    # (dedup folds some batches below the ratio trigger, hence not all 20)
    # explicit compact() ignores the floor (the operator asked for a fold)
    floored.compact()
    assert floored.delta_rows == 0 and floored.n_compactions == 1


def test_background_compactor_exact_under_racing_appends():
    rng = np.random.default_rng(16)
    tx = _db(rng, 120, 10)
    db = VersionedDB(tx, n_classes=1, merge_ratio=0.05, min_compact_rows=0,
                     background_compaction=True)
    history = list(tx)
    probes = [(0, 1), (2,), (3, 7)]
    try:
        for _ in range(6):
            batch = _db(rng, 40, 10)
            db.append(batch)
            history += batch
        db._compactor.drain()
        np.testing.assert_array_equal(
            db.counts(probes), _oracle(history, None, 1, probes))
        st_ = db.stats()
        assert st_["compactor"] is not None
        assert st_["compactor"]["runs"] >= 1
        assert db.n_compactions >= 1
        assert db.last_compaction_error is None
    finally:
        db.close()
    assert db.stats()["compactor"] is None   # close() reverts to inline


def test_background_compactor_build_failure_absorbed(monkeypatch):
    """Satellite-3 (background flavor): a failing off-lock base build must
    leave base+delta serving exactly, surface the error in stats(), and a
    later compact succeed once the fault clears."""
    rng = np.random.default_rng(17)
    tx = _db(rng, 120, 10)
    db = VersionedDB(tx, n_classes=1, merge_ratio=0.05, min_compact_rows=0,
                     background_compaction=True)
    history = list(tx)
    probes = [(0, 1), (2,), (3, 7)]
    real_make_base = db._make_base
    try:
        def boom(bits, weights, vocab=None):
            raise RuntimeError("disk full")

        monkeypatch.setattr(db, "_make_base", boom)
        batch = _db(rng, 60, 10)
        db.append(batch)                      # trigger: queues a bg compact
        history += batch
        db._compactor.drain()
        st_ = db.stats()
        assert st_["failed_compactions"] >= 1
        assert "disk full" in st_["last_compaction_error"]
        assert db.delta_rows > 0              # delta NOT dropped
        np.testing.assert_array_equal(        # base+delta still exact
            db.counts(probes), _oracle(history, None, 1, probes))

        monkeypatch.setattr(db, "_make_base", real_make_base)
        db.compact()                          # fault cleared: fold succeeds
        assert db.delta_rows == 0
        np.testing.assert_array_equal(
            db.counts(probes), _oracle(history, None, 1, probes))
    finally:
        db.close()


def test_inline_compaction_failure_metrics(monkeypatch):
    """Satellite-3 (inline flavor): an append-triggered compaction failure is
    absorbed (the append committed), surfaced through stats(), and leaves the
    base+delta composition exact; an EXPLICIT compact() re-raises."""
    rng = np.random.default_rng(18)
    tx = _db(rng, 100, 8)
    db = VersionedDB(tx, n_classes=1, merge_ratio=0.05, min_compact_rows=0)
    history = list(tx)
    monkeypatch.setattr(db, "_make_base",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("torn write")))
    batch = _db(rng, 30, 8)
    assert db.append(batch) == 1              # append commits despite the fail
    history += batch
    st_ = db.stats()
    assert st_["failed_compactions"] == 1
    assert "torn write" in st_["last_compaction_error"]
    assert db.delta_rows > 0                  # build-before-drop held
    probes = [(0,), (1, 2), (3, 4)]
    np.testing.assert_array_equal(
        db.counts(probes), _oracle(history, None, 1, probes))
    with pytest.raises(RuntimeError):
        db.compact()                          # explicit compact re-raises
    assert db.delta_rows > 0                  # delta still not dropped
    np.testing.assert_array_equal(
        db.counts(probes), _oracle(history, None, 1, probes))
