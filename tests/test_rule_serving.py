"""Oracle-parity battery for online minority-rule serving (serve/rules.py).

The serving contract: every rule verdict (count, g_count, support,
confidence, membership in the optimal set) served by ``RuleServer`` over the
count path is BIT-EXACT against the host ``minority_report`` /
``optimal_rule_set`` oracle on the same transaction history — at every
version, over appends, on a single store (dense or streaming) and on a
sharded store (host all-reduce loop, and the mesh psum path in a subprocess
under ``--runslow``).  Plus ``RuleCache`` invalidation/prefetch/ledger
regressions and an ``optimal_rule_set`` property test against a brute-force
subset-domination oracle.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import minority_report, optimal_rule_set
from repro.core.mra import Rule
from repro.serve import CountServer, RuleCache, RuleServer
from repro.serve.cache import check_cache_ledger

from _pbt import given, settings, strategies as st  # hypothesis or offline shim

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

THETA, MIN_CONF = 0.04, 0.36


def _db(rng, rows, items, p=0.3):
    return [[int(a) for a in range(items) if rng.random() < p]
            for _ in range(rows)]


def _labels(rng, tx, p=0.35):
    return [int(rng.random() < p) for _ in tx]


def _battery(make_server, rounds=2, seed=7):
    """Serve rules over ``rounds`` append rounds; every round must match the
    host oracle exactly (complete rule list, optimal set, per-antecedent
    verdicts)."""
    rng = np.random.default_rng(seed)
    tx = _db(rng, 300, 24)
    y = _labels(rng, tx)
    ruler = RuleServer(make_server(tx, y))
    hist, ys = [list(t) for t in tx], list(y)
    for rnd in range(rounds + 1):
        res = minority_report(hist, ys, target_class=1,
                              min_support=THETA, min_confidence=MIN_CONF)
        assert res.rules, f"round {rnd}: oracle mined no rules (bad params)"
        got = ruler.top_rules(THETA, MIN_CONF)
        assert got == res.rules, f"round {rnd}: complete rule set diverged"
        assert ruler.top_rules(THETA, MIN_CONF, optimal=True) \
            == optimal_rule_set(res.rules), f"round {rnd}: optimal set"
        # per-antecedent verdicts through the cache/batch path: Rule equality
        # covers count, g_count, support AND confidence bit-exactly
        antes = [r.antecedent for r in res.rules]
        assert ruler.rules_for(antes, min_conf=MIN_CONF) == res.rules
        if rnd < rounds:
            batch = _db(rng, 120, 24 + 4 * rnd)   # widens the vocab too
            yb = _labels(rng, batch)
            ruler.append(batch, classes=yb)
            hist += [list(t) for t in batch]
            ys += yb


def test_top_rules_oracle_parity_dense_over_appends():
    _battery(lambda tx, y: CountServer(tx, classes=y))


def test_top_rules_oracle_parity_streaming_store():
    _battery(lambda tx, y: CountServer(tx, classes=y, streaming=True,
                                       chunk_rows=64))


def test_top_rules_oracle_parity_sharded_host_loop():
    _battery(lambda tx, y: CountServer(tx, classes=y, shards=4))


def test_rules_for_verdicts_unknown_empty_and_target_override():
    rng = np.random.default_rng(11)
    tx = _db(rng, 200, 12)
    y = [i % 3 for i in range(len(tx))]          # 3 classes
    ruler = RuleServer(CountServer(tx, classes=y, n_classes=3),
                       target_class=2)
    # empty antecedent = the class prior
    (prior,) = ruler.rules_for([()])
    n2 = sum(1 for c in y if c == 2)
    assert prior == Rule((), 2, n2 / len(tx), n2 / len(tx),
                         n2, len(tx) - n2)
    # unknown item: exact count 0 on both sides -> confidence 0
    (unk,) = ruler.rules_for([(999,)])
    assert unk == Rule((999,), 2, 0.0, 0.0, 0, 0)
    assert ruler.rules_for([(999,)], min_conf=0.1) == [None]
    # per-call target override beats the constructor default
    (r0,) = ruler.rules_for([(0,)], target_class=0)
    (r2,) = ruler.rules_for([(0,)])
    assert r0.consequent == 0 and r2.consequent == 2
    assert r0.count + r0.g_count == r2.count + r2.g_count
    # canonicalization: permuted/duplicated antecedents are one verdict
    a, b = ruler.rules_for([(3, 1, 1), (1, 3)])
    assert a == b and a.antecedent == (1, 3)


def test_rule_server_validation():
    srv = CountServer([[1, 2], [2]], classes=[0, 1])
    with pytest.raises(ValueError, match="target_class"):
        RuleServer(srv, target_class=2)
    with pytest.raises(ValueError, match="prefetch_top"):
        RuleServer(srv, prefetch_top=-1)
    ruler = RuleServer(srv)
    with pytest.raises(ValueError, match="target_class"):
        ruler.rules_for([(1,)], target_class=5)
    with pytest.raises(ValueError, match="min_conf"):
        ruler.rules_for([(1,)], min_conf=1.5)
    with pytest.raises(ValueError, match="class_column"):
        srv.mine(0.5, class_column=3)


def test_class_guided_mine_matches_oracle_and_does_not_arm():
    from repro.core import mine_frequent
    from repro.core.incremental import ceil_count

    rng = np.random.default_rng(23)
    tx = _db(rng, 250, 16)
    y = _labels(rng, tx)
    srv = CountServer(tx, classes=y)
    got = srv.mine(0.05, class_column=1)
    # guided mine == host FP-growth over the target-class rows only
    want = mine_frequent([t for t, c in zip(tx, y) if c == 1],
                         ceil_count(0.05 * len(tx)))
    assert got == want
    with pytest.raises(RuntimeError, match="mine"):
        srv.frequent        # the class-guided query must NOT arm maintenance


def test_class_guided_mine_discards_total_count_checkpoint(tmp_path):
    """A checkpoint saved by a total-count mine must NOT answer a
    class-guided resume at the same version (or vice versa): the mining
    parameters are part of the checkpoint identity."""
    from repro.core import mine_frequent
    from repro.core.incremental import ceil_count
    from repro.mining.distributed import MiningCheckpoint

    rng = np.random.default_rng(47)
    tx = _db(rng, 200, 16)
    y = _labels(rng, tx)
    srv = CountServer(tx, classes=y)
    ruler = RuleServer(srv)
    cp = MiningCheckpoint(str(tmp_path / "mine.json"))
    srv.mine(0.1, checkpoint=cp)                     # total-count state saved
    got = ruler.top_rules(0.1, 0.0, checkpoint=cp)   # must not resume from it
    res = minority_report(tx, y, target_class=1, min_support=0.1,
                          min_confidence=0.0)
    assert got == res.rules
    # reverse direction: the class-guided state must not answer a total mine
    assert srv.mine(0.1, checkpoint=cp) \
        == mine_frequent(tx, ceil_count(0.1 * len(tx)))


def test_threshold_boundary_fp_noise_parity():
    """0.07 * 100 == 7.000000000000001: the epsilon-guarded ceil keeps an
    exactly-at-threshold antecedent on BOTH the host and serving sides."""
    tx = [[0] if i < 7 else [1] for i in range(100)]
    y = [1] * 7 + [0] * 93
    res = minority_report(tx, y, target_class=1, min_support=0.07,
                          min_confidence=0.0)
    assert any(r.antecedent == (0,) and r.count == 7 for r in res.rules)
    ruler = RuleServer(CountServer(tx, classes=y))
    assert ruler.top_rules(0.07, 0.0) == res.rules


# ------------------------------------------------------------ rule cache
def test_rule_cache_stale_version_never_served_after_append():
    rng = np.random.default_rng(31)
    tx = _db(rng, 150, 10)
    y = _labels(rng, tx)
    srv = CountServer(tx, classes=y)
    ruler = RuleServer(srv)
    (before,) = ruler.rules_for([(0,)])
    # append BEHIND the rule server (no purge, no prefetch): the v0 entry is
    # still resident, yet the version key makes it unservable
    batch = [[0, 1]] * 40
    srv.append(batch, classes=[1] * 40)
    assert len(ruler.cache) == 1
    (after,) = ruler.rules_for([(0,)])
    assert after != before
    n = len(tx) + 40
    cnt = sum(1 for t, c in zip(tx, y) if 0 in t and c == 1) + 40
    gcnt = sum(1 for t, c in zip(tx, y) if 0 in t and c == 0)
    assert after == Rule((0,), 1, cnt / n, cnt / (cnt + gcnt), cnt, gcnt)
    # the stale v0 verdict is purgeable and the ledger follows it out
    assert ruler.cache.purge_stale(srv.store.version) == 1
    assert ruler.cache.nbytes == RuleCache.entry_nbytes(after)


def test_rule_cache_prefetch_warms_only_current_version_keys():
    rng = np.random.default_rng(37)
    tx = _db(rng, 200, 12)
    y = _labels(rng, tx)
    srv = CountServer(tx, classes=y)
    ruler = RuleServer(srv, prefetch_top=4)
    hot = [(0,), (1,), (0, 1), (2,)]
    for _ in range(3):                           # build heat on 4 keys
        ruler.rules_for(hot, min_conf=0.1)
    ruler.rules_for([(5,), (6,)], min_conf=0.1)  # colder keys
    batch = _db(rng, 60, 12)
    v = ruler.append(batch, classes=_labels(rng, batch))
    assert ruler.n_prefetches == 1
    # ONLY current-version entries are resident (stale purged, warm rewarmed)
    assert len(ruler.cache) == 4
    assert all(k[1] == v for k in ruler.cache._d)
    # hot keys are answered without any device work
    launches = srv.store.kernel_launches
    hits0 = ruler.cache.hits
    got = ruler.rules_for(hot, min_conf=0.1)
    assert srv.store.kernel_launches == launches
    assert ruler.cache.hits == hits0 + 4
    # and the prefetched verdicts are the CURRENT counts (full history)
    hist = [list(t) for t in tx] + [list(t) for t in batch]
    assert got[0] is not None
    assert got[0].count + got[0].g_count == sum(1 for t in hist if 0 in t)


def test_rule_cache_ledgers_exact_under_mixed_rule_count_traffic():
    rng = np.random.default_rng(41)
    tx = _db(rng, 180, 14)
    y = _labels(rng, tx)
    srv = CountServer(tx, classes=y)
    ruler = RuleServer(srv, cache_size=6, cache_bytes=260, prefetch_top=0)
    pool = [(a,) for a in range(10)] + [(0, 1), (2, 3), (4, 5, 6)]
    purged = 0
    for rnd in range(3):
        ruler.rules_for(pool[rnd:rnd + 8], min_conf=0.2)
        srv.query(pool[rnd:rnd + 4])             # count traffic interleaves
        if rnd == 1:
            # a 12-item antecedent prices at 96+16*12=288 > max_bytes: the
            # oversized-reject path under live traffic
            ruler.rules_for([tuple(range(12))], min_conf=0.0)
            batch = _db(rng, 40, 14)
            srv.append(batch, classes=_labels(rng, batch))
            purged += ruler.cache.purge_stale(srv.store.version)
    cache = ruler.cache
    # the shared BudgetedLRU invariants (exact byte recount, size/capacity,
    # inserts - evictions - purged == size, and the miss-driven identity:
    # every miss becomes exactly one put, each admitted put is resident,
    # evicted, or purged — no slack) live in ONE helper both cache
    # batteries assert through
    st_ = check_cache_ledger(cache, miss_driven=True)
    assert st_["oversized_rejects"] == 1
    assert st_["purged"] == purged
    assert st_["evictions"] > 0                  # budget actually exercised
    # count-cache ledger untouched by rule traffic beyond its own entries
    check_cache_ledger(srv.cache, miss_driven=True)


def test_rule_cache_lru_eviction_oversized_reject_and_none_verdicts():
    cache = RuleCache(capacity=2, max_bytes=300)
    r1 = Rule((1,), 1, 0.1, 0.5, 5, 5)
    r12 = Rule((1, 2), 1, 0.1, 0.5, 5, 5)
    cache.put(((1,), 1, 0.3), 0, r1)
    cache.put(((1, 2), 1, 0.3), 0, None)         # None verdict is cached
    hit, rule = cache.get(((1, 2), 1, 0.3), 0)
    assert hit and rule is None
    assert cache.nbytes == RuleCache.entry_nbytes(r1) + 16
    cache.put(((3,), 1, 0.3), 0, r12)            # capacity 2: LRU evicts
    assert len(cache) == 2 and cache.evictions == 1
    hit, _ = cache.get(((1,), 1, 0.3), 0)        # (1,) was LRU -> gone
    assert not hit
    big = RuleCache(capacity=8, max_bytes=120)
    big.put(((1,), 1, 0.0), 0, r1)               # 112 bytes: fits
    big.put(((1, 2), 1, 0.0), 0, r12)            # 128 bytes: NEVER fits
    assert big.oversized_rejects == 1 and len(big) == 1
    assert big.nbytes == RuleCache.entry_nbytes(r1)
    with pytest.raises(ValueError):
        RuleCache(capacity=0)
    with pytest.raises(ValueError):
        RuleCache(max_bytes=0)


def test_rule_server_append_prefetches_even_on_mining_refresh_error(
        monkeypatch):
    from repro.serve import MiningRefreshError

    rng = np.random.default_rng(43)
    tx = _db(rng, 150, 10)
    y = _labels(rng, tx)
    srv = CountServer(tx, classes=y)
    ruler = RuleServer(srv, prefetch_top=2)
    srv.mine(0.1)
    ruler.rules_for([(0,), (1,)], min_conf=0.1)
    monkeypatch.setattr(srv, "_refresh_frequent",
                        lambda inc: (_ for _ in ()).throw(RuntimeError("x")))
    batch = _db(rng, 30, 10)
    with pytest.raises(MiningRefreshError):
        ruler.append(batch, classes=_labels(rng, batch))
    # the batch IS committed: the rule path purged + re-warmed at the new
    # version anyway — no stale verdict can survive the failed refresh
    v = srv.store.version
    assert v == 1 and ruler.n_prefetches == 1
    assert ruler.cache._d and all(k[1] == v for k in ruler.cache._d)


# ------------------------------------------- optimal_rule_set property test
_EPS = 1e-12
_CONFS = [0.2, 0.5 - 5e-13, 0.5, 0.5 + 5e-13, 0.5 + 4e-12, 0.8, 1.0]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 15 * len(_CONFS) - 1),
                min_size=0, max_size=24))
def test_optimal_rule_set_matches_bruteforce_domination(codes):
    """Subset-enumeration filter == brute-force pairwise domination oracle,
    with confidence ties exercised within/just-outside the eps band."""
    rules, seen = [], set()
    for code in codes:
        mask = code % 15 + 1                      # non-empty subset of 4 items
        conf = _CONFS[code // 15]
        ante = tuple(a for a in range(4) if (mask >> a) & 1)
        if ante in seen:                          # one confidence per ante,
            continue                              # like a real mined rule set
        seen.add(ante)
        rules.append(Rule(ante, 1, 0.1, conf, 10, 5))
    got = optimal_rule_set(rules)
    brute = [r for r in rules
             if not any(set(s.antecedent) < set(r.antecedent)
                        and s.confidence >= r.confidence - _EPS
                        for s in rules)]
    assert got == brute
    # every survivor satisfies the published invariant checker too
    from repro.core import is_optimal_set
    assert is_optimal_set(got, rules)


# --------------------------------------------------- mesh psum path (slow)
MESH_RULES_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core import minority_report, optimal_rule_set
from repro.serve import CountServer, RuleServer

rng = np.random.default_rng(61)
def _db(rows, items, p=0.3):
    return [[int(a) for a in range(items) if rng.random() < p]
            for _ in range(rows)]

tx = _db(300, 24)
y = [int(rng.random() < 0.35) for _ in tx]
mesh = jax.make_mesh((4,), ("data",))
ruler = RuleServer(CountServer(tx, classes=y, shards=4, mesh=mesh))
hist, ys = [list(t) for t in tx], list(y)
for rnd in range(3):                       # initial + 2 append rounds
    res = minority_report(hist, ys, target_class=1, min_support=0.04,
                          min_confidence=0.36)
    assert res.rules, "oracle mined no rules"
    assert ruler.top_rules(0.04, 0.36) == res.rules, rnd
    assert ruler.top_rules(0.04, 0.36, optimal=True) \
        == optimal_rule_set(res.rules), rnd
    antes = [r.antecedent for r in res.rules]
    assert ruler.rules_for(antes, min_conf=0.36) == res.rules, rnd
    if rnd < 2:
        batch = _db(120, 24 + 4 * rnd)
        yb = [int(rng.random() < 0.35) for _ in batch]
        ruler.append(batch, classes=yb)
        hist += [list(t) for t in batch]
        ys += yb
print(json.dumps({"ok": True,
                  "launches": ruler.server.store.kernel_launches}))
"""


@pytest.mark.slow
def test_rule_parity_sharded_mesh_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", MESH_RULES_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["launches"] > 0


def test_serve_counts_launcher_rules_mode():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve_counts", "--rows", "600",
         "--items", "16", "--rounds", "3", "--batch", "8", "--appends", "2",
         "--append-rows", "100", "--pool", "32", "--p-y", "0.35",
         "--theta", "0.03", "--rules", "--min-conf", "0.3", "--verify"],
        env=env, capture_output=True, text=True, timeout=300, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "rules:" in proc.stdout
    assert "== host minority_report" in proc.stdout
