"""Dense (TPU-native) engine vs paper-faithful host engine vs brute force."""
import numpy as np
import pytest
from _pbt import given, settings, strategies as st  # hypothesis or offline shim

from repro.core import (ItemOrder, TISTree, brute_force_counts, mine_frequent,
                        minority_report)
from repro.mining import (DenseDB, ItemVocab, dedup_rows, decode_row,
                          dense_gfp_counts, dense_mine_frequent, encode_bitmap,
                          minority_report_dense, project_columns)

ITEMS = list(range(12))
transactions_st = st.lists(
    st.lists(st.sampled_from(ITEMS), min_size=0, max_size=8),
    min_size=1, max_size=40,
)
targets_st = st.lists(
    st.lists(st.sampled_from(ITEMS), min_size=1, max_size=4),
    min_size=1, max_size=10,
)


def test_encode_decode_roundtrip():
    rng = np.random.default_rng(0)
    db = [[i for i in range(40) if rng.random() < 0.2] for _ in range(50)]
    vocab = ItemVocab.from_transactions(db)
    bits = encode_bitmap(db, vocab)
    for i, t in enumerate(db):
        assert sorted(decode_row(bits[i], vocab), key=repr) == \
            sorted(set(a for a in t if a in vocab), key=repr)


def test_dedup_preserves_totals():
    rng = np.random.default_rng(1)
    # low-entropy data so dedup actually collapses (FP-compression analogue)
    db = [[i for i in range(6) if rng.random() < 0.5] for _ in range(400)]
    vocab = ItemVocab.from_transactions(db)
    bits = encode_bitmap(db, vocab)
    ub, uw = dedup_rows(bits)
    assert ub.shape[0] <= 2 ** 6
    assert ub.shape[0] < bits.shape[0]  # real collapse
    assert uw.sum() == bits.shape[0]


def test_projection_matches_subset_semantics():
    rng = np.random.default_rng(2)
    db = [[i for i in range(20) if rng.random() < 0.3] for _ in range(60)]
    vocab = ItemVocab.from_transactions(db)
    bits = encode_bitmap(db, vocab)
    keep = [a for a in vocab.items][:7]
    proj, sub = project_columns(bits, vocab, keep)
    for i, t in enumerate(db):
        want = sorted((a for a in set(t) if a in sub), key=repr)
        assert sorted(decode_row(proj[i], sub), key=repr) == want


@settings(max_examples=50, deadline=None)
@given(transactions_st, targets_st)
def test_dense_gfp_counts_theorem1(db, targets):
    """Theorem 1 on the dense engine: g-counts exact for arbitrary TIS."""
    counts = {}
    for t in db:
        for a in set(t):
            counts[a] = counts.get(a, 0) + 1
    if not counts:
        return
    order = ItemOrder.from_counts(counts)
    targets = [[a for a in t if a in order] for t in targets]
    targets = [t for t in targets if t]
    if not targets:
        return
    tis = TISTree(order)
    for t in targets:
        tis.insert(t, target=True)
    ddb = DenseDB.encode(db)
    got = dense_gfp_counts(tis, ddb)
    want = brute_force_counts(db, list(got.keys()))
    assert {k: int(v[0]) for k, v in got.items()} == want


@settings(max_examples=30, deadline=None)
@given(transactions_st, st.integers(min_value=1, max_value=6))
def test_dense_mine_frequent_equals_fpgrowth(db, min_count):
    ddb = DenseDB.encode(db)
    got = dense_mine_frequent(ddb, min_count)
    assert got == mine_frequent(db, min_count)


@settings(max_examples=30, deadline=None)
@given(
    transactions_st,
    st.lists(st.integers(min_value=0, max_value=1), min_size=40, max_size=40),
    st.floats(min_value=0.05, max_value=0.5),
    st.floats(min_value=0.0, max_value=0.8),
)
def test_dense_mra_equals_host_mra(db, ybits, min_sup, min_conf):
    y = ybits[: len(db)]
    if 1 not in y:
        return
    host = minority_report(db, y, min_support=min_sup, min_confidence=min_conf)
    dense = minority_report_dense(db, y, min_support=min_sup, min_confidence=min_conf)
    h = {r.antecedent: (r.count, r.g_count) for r in host.rules}
    d = {r.antecedent: (r.count, r.g_count) for r in dense.rules}
    assert h == d


def test_dense_gfp_target_missing_items_counts_zero():
    db = [[0, 1], [1, 2]]
    order = ItemOrder([1, 0, 2, 99])
    tis = TISTree(order)
    tis.insert([99, 1], target=True)
    ddb = DenseDB.encode(db)
    got = dense_gfp_counts(tis, ddb)
    assert int(got[(1, 99)][0]) == 0
