"""Per-kernel validation: shape/dtype sweeps + property tests, Pallas kernel
(interpret mode on CPU) vs the pure-jnp ref.py oracle vs brute force."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _pbt import given, settings, strategies as st  # hypothesis or offline shim

from repro.core import brute_force_counts
from repro.kernels.itemset_count import (itemset_counts, itemset_counts_ref,
                                         itemset_counts_ref_blocked)
from repro.kernels.itemset_count.kernel import itemset_counts_pallas


from _testutil import random_problem


def _random_problem(rng, n, k, w, c, density=0.3):
    tx, tgt, wts = random_problem(rng, n, k, w, c, density)
    return jnp.asarray(tx), jnp.asarray(tgt), jnp.asarray(wts)


SHAPES = [
    # (N, K, W, C, block_k, block_n)
    (1, 1, 1, 1, 8, 128),
    (128, 8, 1, 1, 8, 128),
    (200, 5, 2, 2, 8, 128),          # padding on both axes
    (1024, 256, 4, 2, 256, 1024),    # exact blocks
    (1500, 300, 4, 3, 256, 512),     # multi-tile + ragged
    (4096, 64, 8, 1, 64, 2048),
    (333, 17, 16, 4, 16, 128),
    (777, 130, 33, 2, 128, 256),     # odd word count
]


@pytest.mark.parametrize("n,k,w,c,bk,bn", SHAPES)
def test_kernel_matches_ref_shapes(n, k, w, c, bk, bn):
    rng = np.random.default_rng(n * 7 + k)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    got = itemset_counts(tx, tgt, wts, block_k=bk, block_n=bn)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_blocked_ref_matches_ref():
    rng = np.random.default_rng(0)
    tx, tgt, wts = _random_problem(rng, 1000, 40, 3, 2)
    a = itemset_counts_ref(tx, tgt, wts)
    b = itemset_counts_ref_blocked(tx, tgt, wts, block_n=256)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_raw_layout_exact_blocks():
    """Direct pallas_call path (pre-padded, transposed layouts)."""
    rng = np.random.default_rng(3)
    tx, tgt, wts = _random_problem(rng, 512, 64, 4, 2)
    got = itemset_counts_pallas(tx.T, tgt, wts.T, block_k=32, block_n=128,
                                interpret=True)
    want = itemset_counts_ref(tx, tgt, wts).T
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_weight_vector_promotion():
    rng = np.random.default_rng(4)
    tx, tgt, _ = _random_problem(rng, 64, 4, 2, 1)
    w1 = jnp.ones((64,), jnp.int32)
    out = itemset_counts(tx, tgt, w1)
    assert out.shape == (4, 1)


def test_empty_inputs():
    tx = jnp.zeros((0, 2), jnp.uint32)
    tgt = jnp.zeros((3, 2), jnp.uint32)
    w = jnp.zeros((0, 2), jnp.int32)
    assert itemset_counts(tx, tgt, w).shape == (3, 2)
    assert itemset_counts(jnp.zeros((5, 2), jnp.uint32),
                          jnp.zeros((0, 2), jnp.uint32),
                          jnp.ones((5, 1), jnp.int32)).shape == (0, 1)


def test_huge_word_count_falls_back():
    """W > MAX_KERNEL_WORDS uses the blocked jnp path, still exact."""
    rng = np.random.default_rng(5)
    tx, tgt, wts = _random_problem(rng, 100, 7, 80, 2)
    got = itemset_counts(tx, tgt, wts)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=300),   # n
    st.integers(min_value=1, max_value=40),    # k
    st.integers(min_value=1, max_value=4),     # w
    st.integers(min_value=1, max_value=4),     # c
    st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_kernel_property_random(n, k, w, c, seed):
    rng = np.random.default_rng(seed)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    got = itemset_counts(tx, tgt, wts, block_k=32, block_n=128)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_kernel_equals_bruteforce_semantics(seed):
    """End-to-end semantic check against the set-containment oracle."""
    from repro.mining import ItemVocab, class_weights, encode_bitmap, encode_targets

    rng = np.random.default_rng(seed)
    m, n = 20, 120
    db = [[i for i in range(m) if rng.random() < 0.3] for _ in range(n)]
    y = rng.integers(0, 2, n)
    vocab = ItemVocab.from_transactions(db)
    targets = [sorted(rng.choice(m, size=rng.integers(1, 4), replace=False).tolist())
               for _ in range(10)]
    targets = [[a for a in t if a in vocab] for t in targets]
    targets = [t for t in targets if t]
    if not targets:
        return
    got = np.asarray(itemset_counts(
        jnp.asarray(encode_bitmap(db, vocab)),
        jnp.asarray(encode_targets(targets, vocab)),
        jnp.asarray(class_weights(y, 2)), block_k=16, block_n=128))
    db0 = [t for t, c in zip(db, y) if c == 0]
    db1 = [t for t, c in zip(db, y) if c == 1]
    for i, t in enumerate(targets):
        key = tuple(sorted(set(t), key=repr))
        assert got[i, 0] == brute_force_counts(db0, [t])[key]
        assert got[i, 1] == brute_force_counts(db1, [t])[key]


def test_anti_monotone_counts():
    """count(superset) <= count(subset) must hold for kernel outputs."""
    rng = np.random.default_rng(9)
    from repro.mining import ItemVocab, encode_bitmap, encode_targets
    m, n = 16, 200
    db = [[i for i in range(m) if rng.random() < 0.4] for _ in range(n)]
    vocab = ItemVocab.from_transactions(db)
    subs = [[a] for a in range(m) if a in vocab]
    sups = [s + [(s[0] + 1) % m] for s in subs]
    sups = [[a for a in t if a in vocab] for t in sups]
    tx = jnp.asarray(encode_bitmap(db, vocab))
    w = jnp.ones((n, 1), jnp.int32)
    c_sub = np.asarray(itemset_counts(tx, jnp.asarray(encode_targets(subs, vocab)), w))
    c_sup = np.asarray(itemset_counts(tx, jnp.asarray(encode_targets(sups, vocab)), w))
    assert (c_sup <= c_sub).all()


@pytest.mark.parametrize("accum", ["vpu_int32", "mxu_f32"])
def test_accum_variants_exact(accum):
    """Both reduction paths (VPU int32 / MXU f32 §Perf variant) are exact."""
    rng = np.random.default_rng(11)
    tx, tgt, wts = _random_problem(rng, 1111, 77, 5, 3)
    got = itemset_counts(tx, tgt, wts, accum=accum, block_k=32, block_n=256)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mxu_f32_bound_enforced():
    tx = jnp.zeros((1, 1), jnp.uint32)
    tgt = jnp.zeros((1, 1), jnp.uint32)
    w = jnp.ones((1, 1), jnp.int32)
    # fine under the bound
    itemset_counts(tx, tgt, w, accum="mxu_f32")


@pytest.mark.parametrize("n,k,w,c,bk,bn", [
    (64, 8, 2, 2, 8, 128),
    (1111, 77, 5, 3, 32, 256),       # multi-tile + ragged on both axes
    (2048, 256, 4, 1, 256, 1024),    # exact blocks
])
def test_mxu_f32_differential_parity(n, k, w, c, bk, bn):
    """MXU f32 == VPU int32 == jnp oracle, element for element."""
    rng = np.random.default_rng(n + k)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    got_mxu = itemset_counts(tx, tgt, wts, accum="mxu_f32",
                             block_k=bk, block_n=bn)
    got_vpu = itemset_counts(tx, tgt, wts, accum="vpu_int32",
                             block_k=bk, block_n=bn)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got_mxu), np.asarray(got_vpu))
    np.testing.assert_array_equal(np.asarray(got_mxu), np.asarray(want))


def test_mxu_f32_exact_near_2p24_bound():
    """Counts just below the 2^24 f32-exactness bound stay bit-exact: every
    partial sum is an integer < 2^24, each exactly representable in f32."""
    n = 8
    tx = jnp.asarray(np.full((n, 1), 0xFFFFFFFF, np.uint32))  # contain all
    tgt = np.zeros((3, 1), np.uint32)
    tgt[1, 0] = 1
    tgt[2, 0] = 0b11
    tgt = jnp.asarray(tgt)
    wts = jnp.asarray(np.full((n, 1), (1 << 21) - 1, np.int32))
    got_mxu = itemset_counts(tx, tgt, wts, accum="mxu_f32")
    got_vpu = itemset_counts(tx, tgt, wts, accum="vpu_int32")
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got_mxu), np.asarray(got_vpu))
    np.testing.assert_array_equal(np.asarray(got_mxu), np.asarray(want))
    # the count itself sits 8 below the bound — and is odd-valued, so any
    # f32 rounding above 2^24 would have been visible
    assert int(np.asarray(got_mxu)[0, 0]) == (1 << 24) - 8


def test_mxu_f32_row_bound_raises_value_error():
    """N >= 2^24 rows per launch must be rejected (ops.py exactness guard);
    the streaming engine re-establishes the bound per chunk instead.  A real
    ValueError with the geometry — not a bare assert that ``python -O``
    strips — and raised BEFORE any device work."""
    n = 1 << 24
    tx = jnp.zeros((n, 1), jnp.uint32)
    tgt = jnp.zeros((1, 1), jnp.uint32)
    w = jnp.ones((n, 1), jnp.int32)
    with pytest.raises(ValueError, match=r"N < 2\^24.*N=16777216"):
        itemset_counts(tx, tgt, w, accum="mxu_f32")
