"""Per-kernel validation: shape/dtype sweeps + property tests, Pallas kernel
(interpret mode on CPU) vs the pure-jnp ref.py oracle vs brute force."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import brute_force_counts
from repro.kernels.itemset_count import (itemset_counts, itemset_counts_ref,
                                         itemset_counts_ref_blocked)
from repro.kernels.itemset_count.kernel import itemset_counts_pallas


def _random_problem(rng, n, k, w, c, density=0.3):
    tx = rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)
    tx &= rng.integers(0, 2 ** 32, size=(n, w), dtype=np.uint32)  # sparsify
    # targets: few set bits so containment actually happens
    tgt = np.zeros((k, w), dtype=np.uint32)
    for i in range(k):
        for _ in range(rng.integers(1, 4)):
            b = rng.integers(0, 32 * w)
            tgt[i, b >> 5] |= np.uint32(1) << np.uint32(b & 31)
    wts = rng.integers(0, 7, size=(n, c)).astype(np.int32)
    return jnp.asarray(tx), jnp.asarray(tgt), jnp.asarray(wts)


SHAPES = [
    # (N, K, W, C, block_k, block_n)
    (1, 1, 1, 1, 8, 128),
    (128, 8, 1, 1, 8, 128),
    (200, 5, 2, 2, 8, 128),          # padding on both axes
    (1024, 256, 4, 2, 256, 1024),    # exact blocks
    (1500, 300, 4, 3, 256, 512),     # multi-tile + ragged
    (4096, 64, 8, 1, 64, 2048),
    (333, 17, 16, 4, 16, 128),
    (777, 130, 33, 2, 128, 256),     # odd word count
]


@pytest.mark.parametrize("n,k,w,c,bk,bn", SHAPES)
def test_kernel_matches_ref_shapes(n, k, w, c, bk, bn):
    rng = np.random.default_rng(n * 7 + k)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    got = itemset_counts(tx, tgt, wts, block_k=bk, block_n=bn)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_blocked_ref_matches_ref():
    rng = np.random.default_rng(0)
    tx, tgt, wts = _random_problem(rng, 1000, 40, 3, 2)
    a = itemset_counts_ref(tx, tgt, wts)
    b = itemset_counts_ref_blocked(tx, tgt, wts, block_n=256)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_raw_layout_exact_blocks():
    """Direct pallas_call path (pre-padded, transposed layouts)."""
    rng = np.random.default_rng(3)
    tx, tgt, wts = _random_problem(rng, 512, 64, 4, 2)
    got = itemset_counts_pallas(tx.T, tgt, wts.T, block_k=32, block_n=128,
                                interpret=True)
    want = itemset_counts_ref(tx, tgt, wts).T
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_weight_vector_promotion():
    rng = np.random.default_rng(4)
    tx, tgt, _ = _random_problem(rng, 64, 4, 2, 1)
    w1 = jnp.ones((64,), jnp.int32)
    out = itemset_counts(tx, tgt, w1)
    assert out.shape == (4, 1)


def test_empty_inputs():
    tx = jnp.zeros((0, 2), jnp.uint32)
    tgt = jnp.zeros((3, 2), jnp.uint32)
    w = jnp.zeros((0, 2), jnp.int32)
    assert itemset_counts(tx, tgt, w).shape == (3, 2)
    assert itemset_counts(jnp.zeros((5, 2), jnp.uint32),
                          jnp.zeros((0, 2), jnp.uint32),
                          jnp.ones((5, 1), jnp.int32)).shape == (0, 1)


def test_huge_word_count_falls_back():
    """W > MAX_KERNEL_WORDS uses the blocked jnp path, still exact."""
    rng = np.random.default_rng(5)
    tx, tgt, wts = _random_problem(rng, 100, 7, 80, 2)
    got = itemset_counts(tx, tgt, wts)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=300),   # n
    st.integers(min_value=1, max_value=40),    # k
    st.integers(min_value=1, max_value=4),     # w
    st.integers(min_value=1, max_value=4),     # c
    st.integers(min_value=0, max_value=2 ** 31 - 1),
)
def test_kernel_property_random(n, k, w, c, seed):
    rng = np.random.default_rng(seed)
    tx, tgt, wts = _random_problem(rng, n, k, w, c)
    got = itemset_counts(tx, tgt, wts, block_k=32, block_n=128)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_kernel_equals_bruteforce_semantics(seed):
    """End-to-end semantic check against the set-containment oracle."""
    from repro.mining import ItemVocab, class_weights, encode_bitmap, encode_targets

    rng = np.random.default_rng(seed)
    m, n = 20, 120
    db = [[i for i in range(m) if rng.random() < 0.3] for _ in range(n)]
    y = rng.integers(0, 2, n)
    vocab = ItemVocab.from_transactions(db)
    targets = [sorted(rng.choice(m, size=rng.integers(1, 4), replace=False).tolist())
               for _ in range(10)]
    targets = [[a for a in t if a in vocab] for t in targets]
    targets = [t for t in targets if t]
    if not targets:
        return
    got = np.asarray(itemset_counts(
        jnp.asarray(encode_bitmap(db, vocab)),
        jnp.asarray(encode_targets(targets, vocab)),
        jnp.asarray(class_weights(y, 2)), block_k=16, block_n=128))
    db0 = [t for t, c in zip(db, y) if c == 0]
    db1 = [t for t, c in zip(db, y) if c == 1]
    for i, t in enumerate(targets):
        key = tuple(sorted(set(t), key=repr))
        assert got[i, 0] == brute_force_counts(db0, [t])[key]
        assert got[i, 1] == brute_force_counts(db1, [t])[key]


def test_anti_monotone_counts():
    """count(superset) <= count(subset) must hold for kernel outputs."""
    rng = np.random.default_rng(9)
    from repro.mining import ItemVocab, encode_bitmap, encode_targets
    m, n = 16, 200
    db = [[i for i in range(m) if rng.random() < 0.4] for _ in range(n)]
    vocab = ItemVocab.from_transactions(db)
    subs = [[a] for a in range(m) if a in vocab]
    sups = [s + [(s[0] + 1) % m] for s in subs]
    sups = [[a for a in t if a in vocab] for t in sups]
    tx = jnp.asarray(encode_bitmap(db, vocab))
    w = jnp.ones((n, 1), jnp.int32)
    c_sub = np.asarray(itemset_counts(tx, jnp.asarray(encode_targets(subs, vocab)), w))
    c_sup = np.asarray(itemset_counts(tx, jnp.asarray(encode_targets(sups, vocab)), w))
    assert (c_sup <= c_sub).all()


@pytest.mark.parametrize("accum", ["vpu_int32", "mxu_f32"])
def test_accum_variants_exact(accum):
    """Both reduction paths (VPU int32 / MXU f32 §Perf variant) are exact."""
    rng = np.random.default_rng(11)
    tx, tgt, wts = _random_problem(rng, 1111, 77, 5, 3)
    got = itemset_counts(tx, tgt, wts, accum=accum, block_k=32, block_n=256)
    want = itemset_counts_ref(tx, tgt, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mxu_f32_bound_enforced():
    tx = jnp.zeros((1, 1), jnp.uint32)
    tgt = jnp.zeros((1, 1), jnp.uint32)
    w = jnp.ones((1, 1), jnp.int32)
    # fine under the bound
    itemset_counts(tx, tgt, w, accum="mxu_f32")
