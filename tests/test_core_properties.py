"""Property-based tests (hypothesis) for the paper-faithful core.

Invariants:
  * Theorem 1  — GFP-growth g-counts equal exact brute-force counts for every
    itemset in the TIS-tree, for arbitrary DBs and arbitrary target lists.
  * Theorems 2/3 — MRA emits all-and-only rules matching brute force, with
    exact support/confidence.
  * FP-growth == Apriori == brute force on the frequent-itemset lattice.
  * Anti-monotonicity of counts.
  * GFP data-reduction optimization (#4) does not change results.
"""
import math
from typing import List

from _pbt import given, settings, strategies as st  # hypothesis or offline shim

from repro.core import (
    FPTree, ItemOrder, TISTree, apriori, brute_force_counts, fp_growth,
    full_fpgrowth_rules, gfp_growth, mine_frequent, minority_report,
)

ITEMS = list(range(8))

transactions_st = st.lists(
    st.lists(st.sampled_from(ITEMS), min_size=0, max_size=6),
    min_size=1, max_size=24,
)
targets_st = st.lists(
    st.lists(st.sampled_from(ITEMS), min_size=1, max_size=4),
    min_size=1, max_size=12,
)


def _order_for(db) -> ItemOrder:
    counts = {}
    for t in db:
        for a in set(t):
            counts[a] = counts.get(a, 0) + 1
    return ItemOrder.from_counts(counts)


@settings(max_examples=120, deadline=None)
@given(transactions_st, targets_st)
def test_theorem1_gfp_counts_exact(db, targets):
    order = _order_for(db)
    # TIS-tree may only contain items present in the FP-tree's universe
    targets = [[a for a in t if a in order] for t in targets]
    targets = [t for t in targets if t]
    if not targets:
        return
    tree = FPTree.build(db, order)
    tis = TISTree(order)
    for t in targets:
        tis.insert(t, target=True)
    gfp_growth(tis, tree)
    got = tis.as_dict("g_count")
    want = brute_force_counts(db, list(got.keys()))
    assert got == want


@settings(max_examples=60, deadline=None)
@given(transactions_st, targets_st)
def test_gfp_data_reduction_invariant(db, targets):
    order = _order_for(db)
    targets = [[a for a in t if a in order] for t in targets]
    targets = [t for t in targets if t]
    if not targets:
        return
    tree = FPTree.build(db, order)
    results = []
    for reduce_items in (True, False):
        tis = TISTree(order)
        for t in targets:
            tis.insert(t, target=True)
        gfp_growth(tis, tree, use_data_reduction=reduce_items)
        results.append(tis.as_dict("g_count"))
    assert results[0] == results[1]


@settings(max_examples=60, deadline=None)
@given(transactions_st, st.integers(min_value=1, max_value=5))
def test_fpgrowth_equals_apriori(db, min_count):
    assert mine_frequent(db, min_count) == apriori(db, min_count)


@settings(max_examples=60, deadline=None)
@given(transactions_st, st.integers(min_value=1, max_value=4))
def test_fpgrowth_counts_exact_and_antimonotone(db, min_count):
    freq = mine_frequent(db, min_count)
    oracle = brute_force_counts(db, list(freq.keys()))
    assert freq == oracle
    for itemset, c in freq.items():
        for drop in range(len(itemset)):
            sub = itemset[:drop] + itemset[drop + 1:]
            if sub:
                assert freq[sub] >= c  # subsets frequent + anti-monotone


@settings(max_examples=80, deadline=None)
@given(
    transactions_st,
    st.lists(st.integers(min_value=0, max_value=1), min_size=24, max_size=24),
    st.floats(min_value=0.02, max_value=0.6),
    st.floats(min_value=0.0, max_value=0.9),
)
def test_mra_equals_bruteforce_rules(db, ybits, min_sup, min_conf):
    y = ybits[: len(db)]
    if 1 not in y:
        return
    res = minority_report(db, y, min_support=min_sup, min_confidence=min_conf)
    # Oracle: enumerate all itemsets over kept items via full FP-growth baseline
    base = full_fpgrowth_rules(db, y, min_support=min_sup, min_confidence=min_conf)
    got = {r.antecedent: (r.count, r.g_count, round(r.confidence, 12)) for r in res.rules}
    want = {r.antecedent: (r.count, r.g_count, round(r.confidence, 12)) for r in base}
    assert got == want


@settings(max_examples=40, deadline=None)
@given(transactions_st)
def test_conditional_tree_represents_projection(db):
    """conditional_tree(a) must represent exactly the prefix-projected DB."""
    order = _order_for(db)
    tree = FPTree.build(db, order)
    for item in list(tree.header)[:3]:
        ctree = tree.conditional_tree(item)
        # count of any other item b in ctree == count of {item, b} in DB
        for b in list(ctree.header):
            want = brute_force_counts(db, [(item, b)])
            assert ctree.item_count(b) == list(want.values())[0]
