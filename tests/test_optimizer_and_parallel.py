"""Unit tests: AdamW optimizer, schedules, compression, logical sharding
rules, and the roofline HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel import sharding as shd
from repro.roofline.analysis import CollectiveStats, collective_bytes
from repro.train.optimizer import (AdamWConfig, apply_updates, clip_by_global_norm,
                                   compress_grads, compress_int8, decompress_int8,
                                   init_state, schedule)


# ---------------------------------------------------------------- optimizer
def test_adamw_moves_toward_minimum():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_state(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}   # d/dw ||w||^2
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_weight_decay_shrinks_weights():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                      total_steps=10, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([10.0])}
    state = init_state(params, cfg)
    params2, _, _ = apply_updates(params, {"w": jnp.zeros(1)}, state, cfg)
    assert float(params2["w"][0]) < 10.0


def test_grad_clip_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(clipped))
    assert abs(total - 1.0) < 1e-3
    assert float(norm) == pytest.approx(np.sqrt(800.0), rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == pytest.approx(0.1)
    assert float(schedule(cfg, jnp.asarray(9))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.asarray(99))) == pytest.approx(0.1, abs=1e-2)


def test_int8_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = compress_int8(g)
    back = decompress_int8(q, s, jnp.float32)
    assert float(jnp.abs(back - g).max()) <= float(s) * 0.51 + 1e-7


def test_compress_grads_tree_modes():
    g = {"a": jnp.ones((8,), jnp.float32), "b": jnp.ones((8,), jnp.bfloat16)}
    for mode in (None, "none", "bf16", "int8"):
        out = compress_grads(g, mode)
        assert jax.tree.structure(out) == jax.tree.structure(g)
        for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
            assert x.dtype == y.dtype
    with pytest.raises(ValueError):
        compress_grads(g, "fp4")


# ---------------------------------------------------------------- sharding
def test_pspec_rules_and_divisibility():
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((16, 16), ("data", "model"))
    # divisible dims keep their axes
    spec = shd.pspec(("embed", "ffn"), shape=(64, 128), mesh=mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # non-divisible dims are dropped, not crashed (7 % 16 != 0)
    spec = shd.pspec(("vocab_out",), shape=(7,), mesh=mesh)
    assert spec == jax.sharding.PartitionSpec()
    # heads that don't divide the model axis fall back to replicated
    # ('pod' absent -> act_batch collapses to the canonical bare 'data')
    spec = shd.pspec(("act_batch", None, "act_heads", None),
                     shape=(256, 4096, 56, 128), mesh=mesh)
    assert spec == jax.sharding.PartitionSpec("data")


def test_pspec_missing_mesh_axis_filtered():
    mesh = jax.make_mesh((1,), ("data",))
    with shd.sharding_ctx(mesh):
        spec = shd.pspec(("act_batch", "act_seq", None), shape=(8, 8, 8))
        # 'pod' and 'model' absent; act_batch -> data only, act_seq -> dropped
        assert spec == jax.sharding.PartitionSpec("data")


def test_constrain_noop_outside_ctx():
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "act_batch", None) is x


def test_duplicate_axis_not_reused():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.sharding_ctx(mesh):
        spec = shd.pspec(("embed", "embed"), shape=(16, 16))
        assert spec == jax.sharding.PartitionSpec("data")  # second drops


# ---------------------------------------------------------------- roofline
HLO_SAMPLE = """
  %ar = f32[64,128]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = bf16[256,64]{1,0} all-gather(%x), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %rs = bf16[32,64]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[2,4]<=[8], to_apply=%add
  %cp = u32[16]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
  %no = f32[8]{0} add(%a, %b)
"""


def test_collective_parser_kinds_and_ring_model():
    stats = collective_bytes(HLO_SAMPLE, adjust_bf16_upcast=False)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "collective-permute": 1}
    ar = 64 * 128 * 4
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * ar * 1 / 2)
    ag = 256 * 64 * 2
    assert stats.wire_bytes["all-gather"] == pytest.approx(ag * 3 / 4)
    rs = 32 * 64 * 2
    assert stats.wire_bytes["reduce-scatter"] == pytest.approx(rs * 3)


def test_collective_parser_bf16_upcast_adjustment():
    stats = collective_bytes(HLO_SAMPLE, adjust_bf16_upcast=True)
    ar = 64 * 128 * 2  # f32 counted at bf16 width
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * ar * 1 / 2)


def test_model_flops_sane():
    from repro.configs import get_config
    from repro.models.config import TRAIN_4K, DECODE_32K
    from repro.roofline.analysis import model_flops
    cfg = get_config("qwen3-8b")
    f_train = model_flops(cfg, TRAIN_4K)
    # 6*N*D within 2x of parameter-only estimate (attention adds more)
    n, d = cfg.n_params(), TRAIN_4K.seq_len * TRAIN_4K.global_batch
    assert 6 * n * d <= f_train <= 2 * 6 * n * d
    f_dec = model_flops(cfg, DECODE_32K)
    assert f_dec < f_train / 100


def test_moe_active_params():
    from repro.configs import get_config
    cfg = get_config("arctic-480b")
    assert cfg.n_params() > 400e9
    assert cfg.n_active_params() < 0.1 * cfg.n_params()
