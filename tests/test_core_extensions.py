"""Tests for the paper's §5 extensions: per-level Apriori+GFP counting (§5.1)
and incremental mining with guided recounts (§5.2)."""
import random

from _pbt import given, settings, strategies as st  # hypothesis or offline shim

from repro.core import mine_frequent
from repro.core.apriori_gfp import apriori_gfp
from repro.core.incremental import IncrementalMiner

ITEMS = list(range(10))
transactions_st = st.lists(
    st.lists(st.sampled_from(ITEMS), min_size=0, max_size=6),
    min_size=1, max_size=30,
)


@settings(max_examples=50, deadline=None)
@given(transactions_st, st.integers(min_value=1, max_value=5))
def test_apriori_gfp_equals_fpgrowth(db, min_count):
    got, stats = apriori_gfp(db, min_count)
    want = mine_frequent(db, min_count)
    assert got == want
    assert stats.header_consults >= 0


@settings(max_examples=40, deadline=None)
@given(
    transactions_st, transactions_st,
    st.floats(min_value=0.05, max_value=0.7),
)
def test_incremental_equals_batch(db0, db1, theta):
    miner = IncrementalMiner(theta)
    miner.fit(db0)
    got = miner.update(db1)
    want = mine_frequent(db0 + db1, max(1, _ceil(theta * (len(db0) + len(db1)))))
    assert got == want


@settings(max_examples=20, deadline=None)
@given(transactions_st, transactions_st, transactions_st)
def test_incremental_two_updates(db0, db1, db2):
    theta = 0.25
    miner = IncrementalMiner(theta)
    miner.fit(db0)
    miner.update(db1)
    got = miner.update(db2)
    n = len(db0) + len(db1) + len(db2)
    want = mine_frequent(db0 + db1 + db2, _ceil(theta * n))
    assert got == want


def test_incremental_guided_work_is_smaller():
    """The guided recount should touch far fewer tree nodes than re-mining."""
    rng = random.Random(0)
    db0 = [[i for i in range(30) if rng.random() < 0.2] for _ in range(800)]
    db1 = [[i for i in range(30) if rng.random() < 0.2] for _ in range(80)]
    miner = IncrementalMiner(0.05)
    miner.fit(db0)
    got = miner.update(db1)
    want = mine_frequent(db0 + db1, _ceil(0.05 * 880))
    assert got == want


def _ceil(x):
    import math
    return max(1, math.ceil(x - 1e-9))


@settings(max_examples=40, deadline=None)
@given(
    transactions_st,
    st.lists(st.integers(min_value=0, max_value=1), min_size=30, max_size=30),
    st.floats(min_value=0.03, max_value=0.4),
)
def test_optimal_rule_set_invariants(db, ybits, min_sup):
    """Li/Shen/Topor optimal set (paper §5.1 ref [26]): every kept rule's
    proper sub-antecedents all have strictly lower confidence; every dropped
    rule is dominated by a kept subset chain."""
    from repro.core import minority_report
    from repro.core.optimal_rules import is_optimal_set, optimal_rule_set

    y = ybits[: len(db)]
    if 1 not in y:
        return
    res = minority_report(db, y, min_support=min_sup, min_confidence=0.0)
    opt = optimal_rule_set(res.rules)
    assert is_optimal_set(opt, res.rules)
    assert set(r.antecedent for r in opt) <= set(r.antecedent for r in res.rules)
    # every single-item rule is trivially optimal (no proper subsets)
    singles = [r for r in res.rules if len(r.antecedent) == 1]
    assert set(r.antecedent for r in singles) <= set(r.antecedent for r in opt)
