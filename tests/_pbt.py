"""Offline-safe property-based-testing shim.

The suite's property tests are written against the small hypothesis subset
``given`` / ``settings`` / ``strategies.{integers,floats,lists,sampled_from}``.
This module re-exports the real hypothesis when it is installed; otherwise it
provides a deterministic random-sampling fallback (fixed per-test seed derived
from the test name) so the suite collects and runs in offline containers.

The fallback is NOT a shrinking property-based engine — it is plain seeded
random sampling.  ``PBT_MAX_EXAMPLES`` caps the per-test example count in
fallback mode (default 20) to keep the fast tier fast.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies  # type: ignore # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import os
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 20
    _CAP = int(os.environ.get("PBT_MAX_EXAMPLES", "20"))

    class _Strategy:
        """A draw function wrapped so tests can compose strategies."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            pool = list(elements)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng: random.Random):
                size = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(size)]

            return _Strategy(draw)

    strategies = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
                 **_ignored):
        def deco(fn):
            fn._pbt_max_examples = max_examples
            return fn

        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = min(getattr(wrapper, "_pbt_max_examples",
                                _DEFAULT_EXAMPLES), _CAP)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    vals = [s.example(rng) for s in strats]
                    try:
                        fn(*vals)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i} of {fn.__name__}: "
                            f"{vals!r}") from e

            # hide the wrapped signature: the drawn parameters must not look
            # like pytest fixtures (hypothesis does the same)
            wrapper.__dict__.pop("__wrapped__", None)
            wrapper.__signature__ = inspect.Signature()
            wrapper._pbt_given = True
            return wrapper

        return deco
