"""Clean twin of ``conc_bad.py``: one global lock order (A before B,
everywhere), every mutation of the thread-shared attribute under the lock.
"""
import threading


class GoodOrdering:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        self.shared = 0
        threading.Thread(target=self._run, daemon=True).start()

    def lock_ab(self):
        with self._a_lock:
            with self._b_lock:
                return self.shared

    def lock_ab_again(self):
        with self._a_lock:
            with self._b_lock:
                self.shared += 2

    def _run(self):
        with self._a_lock:
            self.shared += 1

    def safe_bump(self):
        with self._a_lock:
            self.shared += 1
