"""Clean twin of ``jit_bad.py``: statics stay concrete (keyword-only +
``static_argnames``), shapes are trace-time constants, branching happens
in jnp, and the error path raises a typed exception on concrete values.
"""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("scale",))
def well_behaved(x, y, *, scale, block: int = 8):
    n, = x.shape
    if n % block:
        raise ValueError(f"rows {n} not a multiple of block {block}")
    gated = jnp.where(x > 0, x * scale, x)
    return gated + y
