"""Violation fixture for the tuner-seam checker (PARSED, never imported).

TUNE001 three ways: a literal ``block_k``, a literal ``accum``, and a
local constant threaded through a name.
"""


def launch_hardcoded(tx, tgt, w, itemset_counts):
    return itemset_counts(tx, tgt, w, block_k=256, accum="mxu_f32")


def launch_via_local(tx, tgt, w, acc, itemset_counts_into):
    bk = 128
    return itemset_counts_into(acc, tx, tgt, w, block_k=bk)
