"""Violation fixture for the metric-hygiene checker (PARSED, never
imported).

MET001: an f-string label, a ``str()`` label, and an f-string threaded
through a local; MET002: one histogram name registered under two different
bucket grids.
"""


def record(REGISTRY, n_rows, key):
    REGISTRY.counter("serve_rows_total", rows=f"{n_rows}").inc()
    REGISTRY.counter("serve_keys_total", key=str(key)).inc()
    label = f"shape_{n_rows}"
    REGISTRY.gauge("serve_shape", shape=label)


def grids(REGISTRY):
    REGISTRY.histogram("lat_ms", buckets=(1, 5, 10)).observe(2.0)
    REGISTRY.histogram("lat_ms", buckets=(2, 4, 8)).observe(3.0)
