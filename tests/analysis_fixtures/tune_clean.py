"""Clean twin of ``tune_bad.py``: configs forwarded from parameters, taken
from ``resolve_launch_config``, or left to resolve inside the entry point.
"""


def launch_resolved(tx, tgt, w, itemset_counts, resolve_launch_config):
    cfg = resolve_launch_config(tx.shape[0], tgt.shape[0], tx.shape[1],
                                w.shape[1])
    return itemset_counts(tx, tgt, w, block_k=cfg.block_k, accum=cfg.accum)


def launch_forwarded(tx, tgt, w, itemset_counts, block_k=None, accum=None):
    return itemset_counts(tx, tgt, w, block_k=block_k, accum=accum)


def launch_default(tx, tgt, w, itemset_counts):
    return itemset_counts(tx, tgt, w)
