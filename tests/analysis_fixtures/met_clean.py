"""Clean twin of ``met_bad.py``: constant labels, bucketized geometry
labels, a forwarded bounded-vocabulary name, one histogram grid per name.
"""


def record(REGISTRY, n, k, w, c, trigger, geometry_bucket):
    geom = geometry_bucket(n, k, w, c)
    REGISTRY.counter("kernel_launches_total", geometry=geom).inc()
    REGISTRY.counter("serve_flushes_total", trigger=trigger).inc()
    REGISTRY.counter("serve_appends_total", path="delta").inc()


def grids(REGISTRY):
    REGISTRY.histogram("lat_ms", buckets=(1, 5, 10)).observe(2.0)
    REGISTRY.histogram("lat_ms", buckets=(1, 5, 10)).observe(3.0)
