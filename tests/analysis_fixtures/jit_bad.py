"""Violation fixture for the jit-safety checker (PARSED, never imported).

JIT001: ``float()`` and ``.item()`` on traced values; JIT002: Python ``if``
on a traced value; JIT003: bare assert (with the checker scoped to cover
this file); JIT004: ``np.asarray`` host transfer inside the jit scope.
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def leaky(x, y):
    assert x.ndim == 1
    if x[0] > 0:
        return float(y)
    host = np.asarray(x)
    return x.item() + host[0]
