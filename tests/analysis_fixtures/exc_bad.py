"""Violation fixture for the exception-hygiene checker (PARSED, never
imported).

EXC001 three ways: swallow without binding, bind without using, and
preserve context without accounting.
"""


def swallow(fn):
    try:
        fn()
    except Exception:
        pass


def bind_unused(fn, log):
    try:
        fn()
    except Exception as e:
        log.append("something went wrong")


def no_accounting(fn, state):
    try:
        fn()
    except Exception as e:
        state["last"] = str(e)
