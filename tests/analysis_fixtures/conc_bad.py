"""Violation fixture for the concurrency checker (PARSED, never imported).

CONC001: ``lock_ab`` and ``lock_ba`` acquire the two locks in opposite
orders.  CONC002: ``racy_bump`` mutates ``shared``, which the thread target
``_run`` also assigns, without holding any lock.
"""
import threading


class BadOrdering:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        self.shared = 0
        threading.Thread(target=self._run, daemon=True).start()

    def lock_ab(self):
        with self._a_lock:
            with self._b_lock:
                return self.shared

    def lock_ba(self):
        with self._b_lock:
            with self._a_lock:
                return self.shared

    def _run(self):
        with self._a_lock:
            self.shared += 1

    def racy_bump(self):
        self.shared += 1
