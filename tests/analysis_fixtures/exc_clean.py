"""Clean twin of ``exc_bad.py``: broad handlers re-raise, or preserve the
exception AND account for it; typed handlers are out of scope entirely.
"""


def wrap_and_reraise(fn):
    try:
        fn()
    except Exception as e:
        raise RuntimeError("wrapped") from e


def account_and_continue(fn, counter, state):
    try:
        fn()
    except Exception as e:
        state["last_error"] = f"{type(e).__name__}: {e}"
        counter.inc()


def typed_is_fine(fn):
    try:
        fn()
    except (ValueError, OSError):
        return None
