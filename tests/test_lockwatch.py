"""Dynamic lock-order watcher battery: the runtime twin of CONC001.

Covers the watcher mechanics (edge recording, re-entrancy, cycle
detection on a synthetic ABBA inversion) and the real cross-check the
ISSUE asks for: threaded async serving traffic under instrumentation must
show no order cycles, and every edge observed live must already be in the
STATIC lock graph — if the dynamic run ever surfaces an edge the AST
checker missed, this test fails and the checker needs teaching.

The inversion test runs its two threads SEQUENTIALLY (thread 1 fully
releases before thread 2 starts): the watcher flags the ordering hazard
without the test ever risking an actual deadlock.
"""
import threading
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.analysis import ConcurrencyChecker, analyze_paths
from repro.obs import (LockOrderError, LockOrderWatcher, WatchedLock,
                       instrument_server)
from repro.serve import CountServer

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"


def _db(rng, rows, items, p=0.3):
    return [[int(a) for a in range(items) if rng.random() < p]
            for _ in range(rows)]


# -- watcher mechanics --------------------------------------------------------

def test_nested_acquire_records_edge():
    w = LockOrderWatcher()
    a = w.wrap(threading.Lock(), "A")
    b = w.wrap(threading.Lock(), "B")
    for _ in range(2):
        with a:
            with b:
                pass
    assert w.edges() == {("A", "B"): 2}
    assert w.cycles() == []
    w.check()   # must not raise


def test_reentrant_rlock_adds_no_self_edge():
    w = LockOrderWatcher()
    r = w.wrap(threading.RLock(), "R")
    with r:
        with r:
            with r:
                pass
    assert w.edges() == {}


def test_wrapped_lock_proxies_the_real_lock():
    w = LockOrderWatcher()
    lock = threading.Lock()
    wrapped = w.wrap(lock, "L")
    assert isinstance(wrapped, WatchedLock)
    assert wrapped.acquire(blocking=False)
    assert lock.locked()          # __getattr__ passthrough + real acquire
    wrapped.release()
    assert not lock.locked()


def test_synthetic_abba_inversion_detected():
    w = LockOrderWatcher()
    a = w.wrap(threading.Lock(), "A")
    b = w.wrap(threading.Lock(), "B")

    def forward():
        with a:
            with b:
                pass

    def backward():
        with b:
            with a:
                pass

    # sequential threads: the ORDER hazard is recorded, no deadlock risk
    for target in (forward, backward):
        t = threading.Thread(target=target)
        t.start()
        t.join(timeout=10)
        assert not t.is_alive()

    assert w.edges() == {("A", "B"): 1, ("B", "A"): 1}
    cycles = w.cycles()
    assert len(cycles) == 1
    assert set(cycles[0]) == {"A", "B"}
    with pytest.raises(LockOrderError, match="cycle"):
        w.check()
    report = w.report()
    assert report["locks"] == ["A", "B"]
    assert set(report["edges"]) == {"A -> B", "B -> A"}
    w.reset()
    assert w.edges() == {} and w.cycles() == []


# -- the real cross-check: live serving traffic vs the static graph ----------

def test_threaded_serving_traffic_has_no_lock_cycles(rng):
    """Instrumented async CountServer under concurrent submit/stats
    traffic: no order cycles, and observed edges ⊆ static lock graph."""
    checker = ConcurrencyChecker()
    analyze_paths([str(SRC)], [checker], root=str(SRC))
    static_edges = set(checker.lock_edges)

    srv = CountServer(_db(rng, 96, 12), async_flush=True,
                      max_delay_ms=20, min_batch=4)
    watcher = instrument_server(srv, registry=obs.REGISTRY)
    try:
        def client(i):
            futs = [srv.submit_async(f"c{i}", [(0, 1), (2,)])
                    for _ in range(4)]
            for fut in futs:
                fut.result(timeout=15)
            srv.stats()

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive()
        srv.flush()
    finally:
        srv.close()
        # unwrap the PROCESS-GLOBAL registry lock so later tests see the
        # plain lock again (the server locks die with the server)
        obs.REGISTRY._lock = obs.REGISTRY._lock._lock

    observed = set(watcher.edges())
    assert watcher.cycles() == [], watcher.report()
    # the flush path must actually have exercised the known nesting
    assert ("CountServer._lock", "AsyncFlusher._lat_lock") in observed
    # every live edge must be known to the static analysis
    assert observed <= static_edges, (
        f"dynamic run observed lock edges the static checker missed: "
        f"{sorted(observed - static_edges)}")


def test_disk_tier_traffic_edges_subset_of_static_graph(rng, tmp_path):
    """The disk-tier concurrency surface under live traffic: an async server
    over a SPILLED store with the background compactor on, instrumented on
    all four serving locks, racing queries against appends.  No order
    cycles, every observed edge already in the static CONC001 graph, and the
    new store-lock -> compactor-queue nesting actually exercised."""
    checker = ConcurrencyChecker()
    analyze_paths([str(SRC)], [checker], root=str(SRC))
    static_edges = set(checker.lock_edges)

    srv = CountServer(_db(rng, 120, 10), async_flush=True, max_delay_ms=20,
                      min_batch=4, chunk_rows=32, spill_dir=str(tmp_path),
                      spill_threshold_bytes=64, merge_ratio=0.05,
                      min_compact_rows=0, background_compaction=True)
    assert srv.store.resident == "spilled"
    watcher = instrument_server(srv, registry=obs.REGISTRY)
    try:
        def client(i):
            futs = [srv.submit_async(f"c{i}", [(0, 1), (2,)])
                    for _ in range(4)]
            for fut in futs:
                fut.result(timeout=15)
            srv.stats()

        def appender():
            arng = np.random.default_rng(7)
            for _ in range(4):
                srv.append(_db(arng, 30, 10))   # trips the bg compactor

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(3)] + [threading.Thread(target=appender)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive()
        srv.flush()
        srv.store._compactor.drain()
        assert srv.store.last_compaction_error is None
    finally:
        srv.close()
        obs.REGISTRY._lock = obs.REGISTRY._lock._lock

    observed = set(watcher.edges())
    assert watcher.cycles() == [], watcher.report()
    # the append trigger must have nested the compactor handoff under the
    # store lock (the edge the disk tier added to the graph)
    assert ("VersionedDB._store_lock", "AsyncCompactor._mu") in observed
    assert observed <= static_edges, (
        f"dynamic run observed lock edges the static checker missed: "
        f"{sorted(observed - static_edges)}")
