from .ops import (itemset_counts, itemset_counts_into, itemset_counts_ref,
                  itemset_counts_ref_blocked)
