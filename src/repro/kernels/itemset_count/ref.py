"""Pure-jnp oracle for the multitude-targeted itemset-counting kernel.

Semantics (the GFP-growth counting step, dense form):

    counts[k, c] = sum_n weights[n, c] * [ tx_bits[n] contains tgt_bits[k] ]

where "contains" is bitwise: for every word w, (tx[n,w] & tgt[k,w]) == tgt[k,w].
This is a matmul over the (AND, ==, ALL) containment semiring followed by an
ordinary weighted reduction — exactly C(α) per target per class (paper Thm 1 /
§4.1 two-class counters), computed for a *multitude* of targets in one pass.
"""
from __future__ import annotations

import jax.numpy as jnp


def itemset_counts_ref(tx_bits: jnp.ndarray, tgt_bits: jnp.ndarray,
                       weights: jnp.ndarray) -> jnp.ndarray:
    """tx_bits (N, W) uint32; tgt_bits (K, W) uint32; weights (N, C) int32
    -> counts (K, C) int32."""
    if tx_bits.dtype != jnp.uint32 or tgt_bits.dtype != jnp.uint32:
        raise TypeError(
            f"itemset_counts_ref: bitmap dtypes must be uint32, got "
            f"tx={tx_bits.dtype} tgt={tgt_bits.dtype}")
    if tx_bits.ndim != 2 or tgt_bits.ndim != 2 or weights.ndim != 2:
        raise ValueError(
            f"itemset_counts_ref: expected 2-D (N,W)/(K,W)/(N,C) inputs, "
            f"got ndim tx={tx_bits.ndim} tgt={tgt_bits.ndim} "
            f"w={weights.ndim}")
    if tx_bits.shape[1] != tgt_bits.shape[1]:
        raise ValueError(
            f"itemset_counts_ref: word-width mismatch: tx W="
            f"{tx_bits.shape[1]} vs tgt W={tgt_bits.shape[1]}")
    if tx_bits.shape[0] != weights.shape[0]:
        raise ValueError(
            f"itemset_counts_ref: row mismatch: tx N={tx_bits.shape[0]} "
            f"vs weights N={weights.shape[0]}")
    # (K, N, W): does transaction n contain target k's bits of word w?
    hit = (tx_bits[None, :, :] & tgt_bits[:, None, :]) == tgt_bits[:, None, :]
    contained = jnp.all(hit, axis=-1)  # (K, N)
    return contained.astype(jnp.int32) @ weights.astype(jnp.int32)


def itemset_counts_ref_blocked(tx_bits: jnp.ndarray, tgt_bits: jnp.ndarray,
                               weights: jnp.ndarray, block_n: int = 4096) -> jnp.ndarray:
    """Memory-bounded oracle for larger N (scan over N blocks)."""
    import jax

    n = tx_bits.shape[0]
    pad = (-n) % block_n
    if pad:
        tx_bits = jnp.pad(tx_bits, ((0, pad), (0, 0)))
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    nb = tx_bits.shape[0] // block_n
    txb = tx_bits.reshape(nb, block_n, tx_bits.shape[1])
    wb = weights.reshape(nb, block_n, weights.shape[1])

    def step(acc, blk):
        tb, w = blk
        return acc + itemset_counts_ref(tb, tgt_bits, w), None

    init = jnp.zeros((tgt_bits.shape[0], weights.shape[1]), dtype=jnp.int32)
    out, _ = jax.lax.scan(step, init, (txb, wb))
    return out
