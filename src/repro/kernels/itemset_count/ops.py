"""Public jit'd wrapper around the itemset-counting Pallas kernel.

Handles padding, layout transposition, backend selection (interpret mode on
CPU — the kernel body executes in Python for correctness validation; compiled
Mosaic on TPU), and a pure-jnp fallback for degenerate shapes.
"""
from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...roofline import autotune
from ...roofline.kernel_model import record_launch
from .kernel import itemset_counts_pallas
from .ref import itemset_counts_ref, itemset_counts_ref_blocked

__all__ = ["itemset_counts", "itemset_counts_into", "itemset_counts_ref",
           "itemset_counts_ref_blocked"]

# Unrolling the word loop beyond this is counter-productive; fall back to the
# blocked jnp reference (still jit-compiled) for enormous item universes.
MAX_KERNEL_WORDS = 64


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def itemset_counts(
    tx_bits: jnp.ndarray,     # (N, W) uint32
    tgt_bits: jnp.ndarray,    # (K, W) uint32
    weights: jnp.ndarray,     # (N, C) int32  (or (N,) -> C=1)
    *,
    block_k: Optional[int] = None,
    block_n: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    accum: Optional[str] = None,
) -> jnp.ndarray:             # (K, C) int32
    """Exact counts of every target itemset, per weight column (class).

    ``block_k`` / ``block_n`` / ``accum`` left as None resolve through the
    active per-device tuning table (``roofline.autotune``), falling back to
    the compiled-in defaults — callers pin explicit values to bypass it.

    ``accum='mxu_f32'`` routes the weighted reduction through the MXU in f32
    (exact while each count < 2^24; enforced below) — the counting-kernel
    §Perf variant."""
    if weights.ndim == 1:
        weights = weights[:, None]
    n, w = tx_bits.shape
    k = tgt_bits.shape[0]
    c = weights.shape[1]
    if k == 0:
        return jnp.zeros((0, c), jnp.int32)
    if n == 0:
        return jnp.zeros((k, c), jnp.int32)
    if not use_kernel or w > MAX_KERNEL_WORDS:
        return itemset_counts_ref_blocked(tx_bits, tgt_bits, weights)

    if block_k is None or block_n is None or accum is None:
        # Eager host-side resolution (n/k/w/c are concrete Python ints even
        # under a jit trace) so any jit cache downstream keys on the CONCRETE
        # tuned values — never on a None that could alias across table swaps.
        cfg = autotune.resolve_launch_config(n, k, w, c)
        block_k = cfg.block_k if block_k is None else block_k
        block_n = cfg.block_n if block_n is None else block_n
        accum = cfg.accum if accum is None else accum

    if interpret is None:
        interpret = _on_cpu()
    if accum == "mxu_f32" and n >= (1 << 24):
        # exactness bound: every partial sum is <= sum(|weights|) per column,
        # and f32 holds integers exactly only below 2^24.  A real error, not
        # an assert — `python -O` must not silently admit inexact counts.
        raise ValueError(
            "mxu_f32 accumulation is exact only for N < 2^24 rows per "
            f"launch; got geometry (N={n}, K={k}, W={w}, C={c}) — chunk "
            "the sweep (mining/stream.py) or use accum='vpu_int32'")

    # Shrink blocks for small problems, keeping TPU-friendly minima.
    block_n = min(block_n, _round_up(n, 128))
    block_k = min(block_k, _round_up(k, 8))

    n_pad = _round_up(n, block_n) - n
    k_pad = _round_up(k, block_k) - k
    tx_p = jnp.pad(tx_bits, ((0, n_pad), (0, 0)))        # pad rows: weight 0
    wt_p = jnp.pad(weights, ((0, n_pad), (0, 0)))
    tgt_p = jnp.pad(tgt_bits, ((0, k_pad), (0, 0)))       # pad targets: sliced

    # Per-launch telemetry: wall time vs the roofline model's prediction for
    # this geometry (repro.obs / roofline.kernel_model).  Only measurable at
    # the eager boundary — under a jit trace (e.g. the streaming
    # itemset_counts_into step) the operands are Tracers and host timing
    # would clock trace time, not the launch, so recording is skipped there.
    eager = (not isinstance(tx_bits, jax.core.Tracer)
             and not isinstance(tgt_bits, jax.core.Tracer))
    timed = obs.kernel_timing_enabled() and eager
    span = (obs.TRACER.span("kernel.count",
                            {"n": n, "k": k, "w": w, "c": c})
            if eager else obs.tracing.NOOP_SPAN)
    with span:
        t0 = time.perf_counter() if timed else 0.0
        out_t = itemset_counts_pallas(
            tx_p.T, tgt_p, wt_p.T.astype(jnp.int32),
            block_k=block_k, block_n=block_n, interpret=interpret,
            accum=accum,
        )                                                 # (C, K_pad)
        if timed:
            # blocking gives a TRUE wall time; free on CPU (callers
            # materialize the counts immediately) but serializes a pipelined
            # TPU launch stream — obs.configure(kernel_timing=False) when
            # overlap matters
            out_t.block_until_ready()
            record_launch(n, k, w, c, time.perf_counter() - t0)
    return out_t.T[:k, :]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Streaming accumulation step.  The out-of-core sweep (mining/stream.py) keeps
# the small (K, C) count block device-resident and adds one chunk's counts per
# call; donating the accumulator lets the compiler update it in place, so a
# sweep allocates O(chunk) device memory regardless of total N.  Note the
# mxu_f32 exactness bound (N < 2^24) then applies PER CHUNK — chunking makes
# the MXU variant exact for unbounded N.
# ---------------------------------------------------------------------------

def _counts_into(acc, tx_bits, tgt_bits, weights, *, block_k, block_n,
                 interpret, use_kernel, accum):
    return acc + itemset_counts(
        tx_bits, tgt_bits, weights, block_k=block_k, block_n=block_n,
        interpret=interpret, use_kernel=use_kernel, accum=accum)


@functools.lru_cache(maxsize=None)
def _counts_into_jit(donate: bool):
    kwargs = dict(static_argnames=("block_k", "block_n", "interpret",
                                   "use_kernel", "accum"))
    if donate:
        kwargs["donate_argnums"] = (0,)
    return jax.jit(_counts_into, **kwargs)


def itemset_counts_into(
    acc: jnp.ndarray,             # (K, C) int32 running counts (donated)
    tx_bits: jnp.ndarray,         # (N_chunk, W) uint32
    tgt_bits: jnp.ndarray,        # (K, W) uint32
    weights: jnp.ndarray,         # (N_chunk, C) int32
    *,
    block_k: Optional[int] = None,
    block_n: Optional[int] = None,
    interpret: Optional[bool] = None,
    use_kernel: bool = True,
    accum: Optional[str] = None,
) -> jnp.ndarray:                 # (K, C) int32 = acc + chunk counts
    """``acc + itemset_counts(chunk)`` fused in one jit; acc stays on device.

    Launch config resolves EAGERLY here (not inside the trace): the jit
    cache is keyed on the static block/accum values, so a table swap between
    calls must surface as different statics, not a stale cached trace."""
    if block_k is None or block_n is None or accum is None:
        wts = weights if weights.ndim == 2 else weights[:, None]
        cfg = autotune.resolve_launch_config(
            tx_bits.shape[0], tgt_bits.shape[0], tx_bits.shape[1],
            wts.shape[1])
        block_k = cfg.block_k if block_k is None else block_k
        block_n = cfg.block_n if block_n is None else block_n
        accum = cfg.accum if accum is None else accum
    donate = jax.default_backend() != "cpu"  # CPU donation warns, no-op
    return _counts_into_jit(donate)(
        acc, tx_bits, tgt_bits, weights, block_k=block_k, block_n=block_n,
        interpret=interpret, use_kernel=use_kernel, accum=accum)
