"""Pallas TPU kernel: multitude-targeted itemset counting.

TPU mapping of the GFP-growth counting step (see ref.py for semantics).

Layout rationale (TPU memory hierarchy):
  * transactions arrive TRANSPOSED as (W, N): the huge N axis is the 128-lane
    dimension, W (a handful of packed uint32 words) is the sublane axis;
  * targets stay (K, W): K is the sublane axis of the (K_b, N_b) containment
    tile that feeds the reduction;
  * weights arrive (C, N) and the output is (C, K) — class axis on sublanes,
    keeping the lane axis 128-aligned on both operands of the final reduce;
  * grid = (K_tiles, N_tiles), N fastest-varying; the (C, K_b) output block is
    revisited across the N sweep and accumulated in place (initialised when
    n_idx == 0) — VMEM-resident accumulator, one HBM writeback per K tile;
  * the containment test is an unrolled loop over the W words (W is static and
    small — 32·W items), all in VREG-friendly elementwise uint32 ops (VPU);
    the weighted reduction is a small int32 dot_general.

VMEM budget per grid step (defaults W<=64, N_b=1024, K_b=256, C<=8):
  tx (64,1024)·4B = 256KiB ; tgt (256,64)·4B = 64KiB ; w (8,1024)·4B = 32KiB ;
  containment tile (256,1024)·4B = 1MiB ; out (8,256)·4B = 8KiB  << 16MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _itemset_count_kernel(tx_ref, tgt_ref, w_ref, out_ref, *, n_words: int,
                          accum: str = "vpu_int32"):
    """Grid step (k_idx, n_idx): accumulate counts for one (K_b, N_b) tile.

    ``accum``:
      * 'vpu_int32' — int32 dot on the VPU (always exact);
      * 'mxu_f32'   — f32 dot on the MXU (§Perf variant): counts stay exact
        while every partial sum < 2^24 (enforced in ops.py); on TPU this moves
        the reduction from ~4 TOP/s VPU lanes to the systolic array.
    """
    n_idx = pl.program_id(1)

    # Containment: AND over the W packed words, unrolled (W static, small).
    tgt = tgt_ref[...]  # (K_b, W) uint32
    acc = None
    for w in range(n_words):
        t_row = tx_ref[w, :]          # (N_b,) uint32
        g_col = tgt[:, w][:, None]    # (K_b, 1) uint32
        hit = (t_row[None, :] & g_col) == g_col  # (K_b, N_b) bool
        acc = hit if acc is None else (acc & hit)

    if accum == "mxu_f32":
        contained = acc.astype(jnp.float32)       # (K_b, N_b)
        weights = w_ref[...].astype(jnp.float32)  # (C, N_b)
        part = jax.lax.dot_general(
            weights, contained,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(jnp.int32)
    else:
        contained = acc.astype(jnp.int32)             # (K_b, N_b)
        weights = w_ref[...].astype(jnp.int32)        # (C, N_b)
        # (C, N_b) x (K_b, N_b) -> (C, K_b), contracting the lane axis.
        part = jax.lax.dot_general(
            weights, contained,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(n_idx == 0)
    def _init():
        out_ref[...] = part

    @pl.when(n_idx != 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_k", "block_n", "interpret",
                                              "accum"))
def itemset_counts_pallas(
    tx_bits_t: jnp.ndarray,   # (W, N) uint32, N % block_n == 0
    tgt_bits: jnp.ndarray,    # (K, W) uint32, K % block_k == 0
    weights_t: jnp.ndarray,   # (C, N) int32
    *,
    block_k: int = 256,
    block_n: int = 1024,
    interpret: bool = False,
    accum: str = "vpu_int32",
) -> jnp.ndarray:             # (C, K) int32
    n_words, n = tx_bits_t.shape
    k = tgt_bits.shape[0]
    c = weights_t.shape[0]
    if n % block_n or k % block_k:
        raise ValueError(f"N({n}) % block_n({block_n}) and K({k}) % "
                         f"block_k({block_k}) must be 0 (pad in ops.py)")

    grid = (k // block_k, n // block_n)
    kernel = functools.partial(_itemset_count_kernel, n_words=n_words,
                               accum=accum)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_words, block_n), lambda ki, ni: (0, ni)),
            pl.BlockSpec((block_k, n_words), lambda ki, ni: (ki, 0)),
            pl.BlockSpec((c, block_n), lambda ki, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((c, block_k), lambda ki, ni: (0, ki)),
        out_shape=jax.ShapeDtypeStruct((c, k), jnp.int32),
        interpret=interpret,
    )(tx_bits_t, tgt_bits, weights_t)
