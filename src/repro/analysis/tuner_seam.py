"""Tuner-seam checker: launch configs must come from the tuning table.

PR 8 threaded ``roofline.autotune.resolve_launch_config`` through every
count seam so one committed table governs every kernel launch.  A literal
``block_k=256`` / ``accum="mxu_f32"`` at a call site silently severs that
seam: the launch ignores the table, the sweep can no longer improve it, and
the exactness guard (MXU row bound re-checked at resolve time) is bypassed.

**TUNE001** flags calls into the counting entry points
(``itemset_counts``, ``itemset_counts_into``, ``streaming_counts``,
``distributed_counts``) that pass a LITERAL launch-config argument
(``block_k`` / ``block_n`` / ``accum`` / ``chunk_rows``) — directly, or
through a local name whose only assignment in the enclosing function is a
constant.  Forwarded parameters, ``None`` (resolve-inside), and values
derived from ``resolve_launch_config``/``resolve_serve_block_k`` are fine.

``roofline/`` itself is exempt: the sweep exists to measure explicit
configs, and benchmarks under ``benchmarks/`` are outside ``src/repro``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence

from .engine import Checker, Finding, Module, call_name

_COUNT_ENTRYPOINTS = {"itemset_counts", "itemset_counts_into",
                      "streaming_counts", "distributed_counts"}
_CONFIG_KWARGS = {"block_k", "block_n", "accum", "chunk_rows"}


def _literal_value(node: ast.AST) -> Optional[object]:
    """The constant behind an expression, if it is one (ignoring None)."""
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, ast.UnaryOp) and \
            isinstance(node.operand, ast.Constant):
        return node.operand.value
    return None


class TunerSeamChecker(Checker):
    name = "tuner_seam"
    codes = {
        "TUNE001": "literal launch-config argument at a count entry point "
                   "(bypasses resolve_launch_config / the tuning table)",
    }

    def __init__(self, exempt_prefixes: Sequence[str] = ("roofline/",)):
        self.exempt_prefixes = tuple(exempt_prefixes)

    def check_module(self, mod: Module) -> List[Finding]:
        if self.exempt_prefixes and mod.rel.startswith(self.exempt_prefixes):
            return []
        findings: List[Finding] = []

        def visit(node: ast.AST, consts: Dict[str, object]) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = dict(consts)
                inner.update(self._local_constants(node))
                for stmt in node.body:
                    visit(stmt, inner)
                return
            if isinstance(node, ast.Call) and \
                    call_name(node) in _COUNT_ENTRYPOINTS:
                findings.extend(self._check_call(mod, node, consts))
            for child in ast.iter_child_nodes(node):
                visit(child, consts)

        visit(mod.tree, self._local_constants(mod.tree))
        return findings

    def _local_constants(self, scope: ast.AST) -> Dict[str, object]:
        consts: Dict[str, object] = {}
        assigned: Dict[str, int] = {}
        # shallow walk: nested function/class scopes resolve for themselves
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        assigned[tgt.id] = assigned.get(tgt.id, 0) + 1
                        val = _literal_value(node.value)
                        if val is not None:
                            consts[tgt.id] = val
            stack.extend(ast.iter_child_nodes(node))
        # only names assigned exactly once, to a constant, count as literal
        return {k: v for k, v in consts.items() if assigned.get(k) == 1}

    def _check_call(self, mod: Module, call: ast.Call,
                    local_consts: Dict[str, object]) -> List[Finding]:
        findings: List[Finding] = []
        for kw in call.keywords:
            if kw.arg not in _CONFIG_KWARGS:
                continue
            val = _literal_value(kw.value)
            origin = "literal"
            if val is None and isinstance(kw.value, ast.Name) and \
                    kw.value.id in local_consts:
                val = local_consts[kw.value.id]
                origin = f"local constant {kw.value.id!r}"
            if val is not None:
                findings.append(mod.finding(
                    call.lineno, "TUNE001",
                    f"{call_name(call)}(..., {kw.arg}={val!r}) passes a "
                    f"{origin} instead of threading "
                    f"resolve_launch_config", self.name))
        return findings
