"""Metric hygiene checker: bounded label sets + consistent histogram grids.

PR 8's ``geometry_bucket`` exists because telemetry labels derived from
request data (one distinct N per append, one distinct K per query shape)
grow the registry without bound.  This checker pins that discipline:

* **MET001** — a label keyword at a registry instrument call
  (``counter`` / ``gauge`` / ``set_gauge`` / ``histogram``) built from an
  obviously unbounded construction: an f-string, ``str()``/``repr()``/
  ``format()``, ``%``-/``+``-composed strings — directly or through a
  local name assigned from one.  Values routed through a bucketizer
  (any callee whose name contains ``bucket``) are exempt, as are plain
  constants and forwarded names (boundedness of a forwarded name is the
  caller's contract — e.g. the flusher's fixed trigger vocabulary).

* **MET002** — the same histogram name registered with two DIFFERENT
  explicit bucket grids anywhere in the tree.  The runtime
  ``MetricsRegistry.histogram`` raises on this at call time; the checker
  moves the failure to lint time, before one process ever hits both paths.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .engine import Checker, Finding, Module, call_name

_INSTRUMENT_METHODS = {"counter", "gauge", "set_gauge", "histogram"}
_NON_LABEL_KWARGS = {"buckets"}
_STRINGIFY_CALLS = {"str", "repr", "format"}


def _is_unbounded_expr(node: ast.AST) -> Optional[str]:
    """Why this label expression is unbounded, or None if it looks fine."""
    if isinstance(node, ast.JoinedStr):
        return "f-string label"
    if isinstance(node, ast.Call):
        cname = call_name(node)
        if cname is None:
            return None
        if "bucket" in cname.lower():
            return None   # routed through a bucketizer: bounded by design
        if cname in _STRINGIFY_CALLS:
            return f"{cname}() label"
        return None
    if isinstance(node, ast.BinOp) and \
            isinstance(node.op, (ast.Mod, ast.Add)):
        for side in (node.left, node.right):
            if isinstance(side, ast.Constant) and \
                    isinstance(side.value, str):
                return "string-composition label"
    return None


def _grid_literal(node: ast.AST) -> Optional[Tuple]:
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if not isinstance(e, ast.Constant):
                return None
            vals.append(e.value)
        return tuple(vals)
    return None


class MetricHygieneChecker(Checker):
    name = "metric_hygiene"
    codes = {
        "MET001": "unbounded metric label construction (not routed "
                  "through a bucketizer)",
        "MET002": "histogram name registered with conflicting bucket "
                  "grids",
    }

    def __init__(self):
        # name -> grid -> (rel, line) first witness
        self._grids: Dict[str, Dict[Tuple, Tuple[str, int]]] = {}
        self._mods: Dict[str, Module] = {}

    def check_module(self, mod: Module) -> List[Finding]:
        self._mods[mod.rel] = mod
        findings: List[Finding] = []

        def visit(node: ast.AST, consts: Dict[str, Optional[str]]) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                inner = dict(consts)
                inner.update(self._local_origins(node))
                for stmt in node.body:
                    visit(stmt, inner)
                return
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _INSTRUMENT_METHODS:
                findings.extend(self._check_instrument(mod, node, consts))
            for child in ast.iter_child_nodes(node):
                visit(child, consts)

        visit(mod.tree, self._local_origins(mod.tree))
        return findings

    def _local_origins(self, scope: ast.AST) -> Dict[str, Optional[str]]:
        """name -> unboundedness reason for single-assignment locals
        (None value = assigned but from a bounded/unknown source)."""
        origins: Dict[str, Optional[str]] = {}
        counts: Dict[str, int] = {}
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        counts[tgt.id] = counts.get(tgt.id, 0) + 1
                        origins[tgt.id] = _is_unbounded_expr(node.value)
            stack.extend(ast.iter_child_nodes(node))
        return {k: v for k, v in origins.items() if counts.get(k) == 1}

    def _check_instrument(self, mod: Module, call: ast.Call,
                          consts: Dict[str, Optional[str]]) -> List[Finding]:
        findings: List[Finding] = []
        metric_name = None
        if call.args and isinstance(call.args[0], ast.Constant) and \
                isinstance(call.args[0].value, str):
            metric_name = call.args[0].value
        for kw in call.keywords:
            if kw.arg is None or kw.arg in _NON_LABEL_KWARGS:
                if kw.arg == "buckets" and metric_name is not None and \
                        call.func.attr == "histogram":
                    grid = _grid_literal(kw.value)
                    if grid is not None:
                        self._grids.setdefault(metric_name, {}) \
                            .setdefault(grid, (mod.rel, call.lineno))
                continue
            reason = _is_unbounded_expr(kw.value)
            if reason is None and isinstance(kw.value, ast.Name):
                reason = consts.get(kw.value.id)
            if reason is not None:
                findings.append(mod.finding(
                    call.lineno, "MET001",
                    f"label {kw.arg}=... of metric "
                    f"{metric_name or '<dynamic>'} is a {reason}: the "
                    f"label set is unbounded — route it through the "
                    f"geometry bucketizer or a fixed vocabulary",
                    self.name))
        return findings

    def finalize(self) -> List[Finding]:
        findings: List[Finding] = []
        for name, grids in sorted(self._grids.items()):
            if len(grids) <= 1:
                continue
            sites = sorted(grids.values())
            rel, line = sites[-1]
            mod = self._mods.get(rel)
            msg = (f"histogram {name!r} registered with "
                   f"{len(grids)} different bucket grids "
                   f"(first at {sites[0][0]}:{sites[0][1]}) — "
                   f"MetricsRegistry will raise at runtime")
            if mod is not None:
                findings.append(mod.finding(line, "MET002", msg, self.name))
            else:
                findings.append(Finding(rel, line, "MET002", msg, self.name))
        return findings
