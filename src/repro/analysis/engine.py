"""repro-lint engine: AST analysis over this repo's own invariants.

Generic linters can't see that every count seam must thread
``resolve_launch_config``, that metric label sets must stay bounded through
the geometry bucketizer, or which attributes the ``AsyncFlusher`` thread
shares with its server — so this package encodes those rules directly.
The engine is deliberately small:

  * :class:`Module` — one parsed source file (AST + raw lines + the
    suppression comments found in it);
  * :class:`Checker` — the protocol every rule module implements:
    ``check_module(mod)`` per file, then ``finalize()`` for cross-file
    facts (lock graphs, histogram grids);
  * :class:`Finding` — one violation, with a LINE-NUMBER-FREE fingerprint
    (path + code + stripped source line) so committed baselines survive
    unrelated edits above the finding;
  * baseline load/diff/write helpers for ``tools/analyze.py``.

Suppression syntax (same line as the finding)::

    something_flagged()   # repro-lint: disable=CONC002  -- why it is safe

or, anywhere in a file, ``# repro-lint: disable-file=JIT003`` (code list,
or ``all``).  Suppressions are for invariants the checker cannot see
statically (e.g. "caller holds the lock"); the comment should say why.

Stdlib-only, like ``repro.obs``: the analyzer must run in CI before any
heavyweight import succeeds.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_SUPPRESS_LINE_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Za-z0-9_*,\s]+)")
_SUPPRESS_FILE_RE = re.compile(
    r"#\s*repro-lint:\s*disable-file=([A-Za-z0-9_*,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    path: str       # repo-relative posix path (fingerprint component)
    line: int       # 1-based; NOT part of the fingerprint
    code: str       # e.g. "CONC001"
    message: str
    checker: str    # checker name that produced it
    context: str = ""   # stripped source line (fingerprint component)

    @property
    def fingerprint(self) -> str:
        return f"{self.path}::{self.code}::{self.context}"

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} [{self.checker}] " \
               f"{self.message}"


class Module:
    """One parsed source file plus its suppression directives."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.line_suppressions: Dict[int, Set[str]] = {}
        self.file_suppressions: Set[str] = set()
        for i, ln in enumerate(self.lines, 1):
            m = _SUPPRESS_FILE_RE.search(ln)
            if m:
                self.file_suppressions |= _parse_codes(m.group(1))
                continue
            m = _SUPPRESS_LINE_RE.search(ln)
            if m:
                codes = _parse_codes(m.group(1))
                self.line_suppressions.setdefault(i, set()).update(codes)
                if ln.strip().startswith("#"):
                    # own-line directive: applies to the next line too
                    self.line_suppressions.setdefault(i + 1,
                                                      set()).update(codes)

    def context_line(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, line: int, code: str, message: str,
                checker: str) -> Finding:
        return Finding(self.rel, line, code, message, checker,
                       self.context_line(line))

    def suppressed(self, f: Finding) -> bool:
        if _matches(self.file_suppressions, f.code):
            return True
        return _matches(self.line_suppressions.get(f.line, set()), f.code)


def _parse_codes(raw: str) -> Set[str]:
    return {c.strip() for c in raw.split(",") if c.strip()}


def _matches(codes: Set[str], code: str) -> bool:
    return bool(codes) and (code in codes or "all" in codes or "*" in codes)


class Checker:
    """Base checker: subclass, set ``name``/``codes``, override hooks.

    Checkers are STATEFUL across one run (``finalize`` sees facts collected
    from every module), so callers must construct fresh instances per run
    (see :func:`repro.analysis.default_checkers`).
    """

    name = "checker"
    codes: Dict[str, str] = {}

    def check_module(self, mod: Module) -> List[Finding]:
        return []

    def finalize(self) -> List[Finding]:
        return []


def iter_py_files(root: str) -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames
                             if not d.startswith(".") and d != "__pycache__")
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


def analyze_paths(paths: Sequence[str], checkers: Sequence[Checker],
                  root: Optional[str] = None) -> Tuple[List[Finding], int]:
    """Run ``checkers`` over every ``.py`` under ``paths``.

    Returns ``(findings, n_files)`` with suppressions already applied and
    findings sorted by location.  ``root`` anchors the repo-relative paths
    used in fingerprints (defaults to each path's own directory root).
    """
    files: List[Tuple[str, str]] = []   # (abspath, rel)
    for p in paths:
        if os.path.isdir(p):
            base = root or p
            for f in iter_py_files(p):
                files.append((f, os.path.relpath(f, base)))
        else:
            base = root or os.path.dirname(p) or "."
            files.append((p, os.path.relpath(p, base)))

    modules: List[Module] = []
    findings: List[Finding] = []
    for path, rel in files:
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        try:
            modules.append(Module(path, rel, source))
        except SyntaxError as e:
            findings.append(Finding(rel.replace(os.sep, "/"),
                                    e.lineno or 0, "ENG001",
                                    f"syntax error: {e.msg}", "engine"))

    by_rel = {m.rel: m for m in modules}
    for checker in checkers:
        raw: List[Finding] = []
        for mod in modules:
            raw.extend(checker.check_module(mod))
        raw.extend(checker.finalize())
        for f in raw:
            mod = by_rel.get(f.path)
            if mod is not None and mod.suppressed(f):
                continue
            findings.append(f)

    findings.sort(key=lambda f: (f.path, f.line, f.code, f.message))
    return findings, len(modules)


# -- baseline ----------------------------------------------------------------

BASELINE_SCHEMA = 1


def load_baseline(path: str) -> Set[str]:
    """Fingerprint set from a committed baseline file (empty if absent)."""
    if not os.path.isfile(path):
        return set()
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"unsupported baseline schema in {path}: "
                         f"{doc.get('schema')!r}")
    return set(doc.get("fingerprints", []))


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    fps = sorted({f.fingerprint for f in findings})
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"schema": BASELINE_SCHEMA, "fingerprints": fps}, fh,
                  indent=1)
        fh.write("\n")
    return len(fps)


def new_findings(findings: Sequence[Finding],
                 baseline: Set[str]) -> List[Finding]:
    return [f for f in findings if f.fingerprint not in baseline]


# -- shared AST helpers (used by several checkers) ---------------------------

def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``self._server._lock`` -> ("self", "_server", "_lock"); None if the
    expression is not a pure Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Bare callee name of a call: ``f(...)`` -> "f", ``a.b.f(...)`` -> "f"."""
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def find_cycle(edges: Dict[str, Set[str]]) -> Optional[List[str]]:
    """First cycle in a directed graph as a node list (closed: first ==
    last), or None.  Iterative DFS with the standard three-color marking."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {u: WHITE for u in edges}
    for vs in edges.values():
        for v in vs:
            color.setdefault(v, WHITE)
    for start in sorted(color):
        if color[start] != WHITE:
            continue
        stack: List[Tuple[str, Iterable[str]]] = \
            [(start, iter(sorted(edges.get(start, ()))))]
        color[start] = GRAY
        path = [start]
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color[nxt] == GRAY:
                    return path[path.index(nxt):] + [nxt]
                if color[nxt] == WHITE:
                    color[nxt] = GRAY
                    path.append(nxt)
                    stack.append((nxt, iter(sorted(edges.get(nxt, ())))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                path.pop()
                stack.pop()
    return None
