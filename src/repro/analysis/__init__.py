"""repro-lint: repo-specific static analysis for this codebase's invariants.

Five checkers over the serving/mining/kernel stack (see each module's
docstring for the rule catalogue), a small AST engine with suppression
comments and a committed baseline, and an advisory dead-module import
report.  Driven by ``tools/analyze.py``; gated in ``tools/ci.sh``; the
dynamic twin of the concurrency rules lives in ``repro.obs.lockwatch``.

Stdlib-only by design — the analyzer must be runnable before the heavy
imports it polices.
"""
from __future__ import annotations

from typing import List

from .engine import (Checker, Finding, Module, analyze_paths, find_cycle,
                     load_baseline, new_findings, write_baseline)
from .concurrency import ConcurrencyChecker
from .exception_hygiene import ExceptionHygieneChecker
from .jit_safety import JitSafetyChecker
from .metric_hygiene import MetricHygieneChecker
from .tuner_seam import TunerSeamChecker
from .deadmods import dead_module_report

__all__ = [
    "Checker", "Finding", "Module", "analyze_paths", "find_cycle",
    "load_baseline", "new_findings", "write_baseline",
    "ConcurrencyChecker", "ExceptionHygieneChecker", "JitSafetyChecker",
    "MetricHygieneChecker", "TunerSeamChecker", "default_checkers",
    "dead_module_report",
]


def default_checkers() -> List[Checker]:
    """Fresh instances of the five repo checkers (checkers are stateful
    across one run — never share instances between runs)."""
    return [
        ConcurrencyChecker(),
        JitSafetyChecker(),
        TunerSeamChecker(),
        MetricHygieneChecker(),
        ExceptionHygieneChecker(),
    ]
