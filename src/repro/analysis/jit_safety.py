"""jit/Pallas safety checker: tracer leaks, bare asserts, host syncs.

The PR 8 autotuner's contract is that launch configs resolve EAGERLY so jit
statics stay concrete; the flip side is that anything reaching a jit-traced
function body is (potentially) a tracer, and Python-level control flow or
scalar conversion on a tracer fails at trace time — or worse, silently
specializes.  This checker finds jit-visible functions and flags:

* **JIT001** — ``float()``/``int()``/``bool()``/``.item()``/``.tolist()``
  on a traced argument (or a value derived from one) inside a jit scope;
* **JIT002** — Python branching (``if``/``while``/``assert``) whose test
  mentions a traced value;
* **JIT003** — a bare ``assert`` in a hot-path module (``kernels/``,
  ``mining/``, ``serve/``): it vanishes under ``python -O``, so invariants
  on user-reachable paths must be typed exceptions (the PR 8 ``ops.py``
  precedent);
* **JIT004** — host syncs (``block_until_ready``, ``jax.device_get``,
  ``np.asarray``/``np.array`` on traced values) inside a jit scope.

Jit-visible functions are those decorated with ``jax.jit`` (directly or
through ``functools.partial(jax.jit, ...)``), passed by name to a
``jax.jit(...)`` call, or used as a Pallas kernel body (first argument of
``pl.pallas_call``, possibly through ``functools.partial``).  Statics are
exempt from tainting: names listed in a literal ``static_argnames``,
keyword-only parameters (this repo's convention for statics — every kernel
entry point takes arrays positionally and config keyword-only), and
parameters annotated with Python scalar types.  ``.shape``/``.ndim``/
``.dtype``/``len()`` of a traced array are concrete and break the taint.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from .engine import Checker, Finding, Module, attr_chain, call_name, names_in

_SCALAR_ANNOTATIONS = {"int", "float", "bool", "str"}
_CAST_CALLS = {"float", "int", "bool"}
_HOST_SYNC_ATTRS = {"block_until_ready", "item", "tolist"}


def _decorator_marks_jit(dec: ast.AST) -> Optional[Set[str]]:
    """If this decorator applies jax.jit, return the literal
    ``static_argnames`` (empty set if none given), else None."""
    chain = attr_chain(dec)
    if chain is not None and chain[-1] == "jit":
        return set()
    if isinstance(dec, ast.Call):
        fn_chain = attr_chain(dec.func)
        if fn_chain is not None and fn_chain[-1] == "jit":
            return _literal_statics(dec.keywords)
        # functools.partial(jax.jit, static_argnames=...)
        if fn_chain is not None and fn_chain[-1] == "partial" and dec.args:
            inner = attr_chain(dec.args[0])
            if inner is not None and inner[-1] == "jit":
                return _literal_statics(dec.keywords)
    return None


def _literal_statics(keywords: Sequence[ast.keyword]) -> Set[str]:
    out: Set[str] = set()
    for kw in keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, str):
                    out.add(sub.value)
    return out


class JitSafetyChecker(Checker):
    name = "jit_safety"
    codes = {
        "JIT001": "Python scalar conversion of a traced value in a jit "
                  "scope (trace-time failure or silent specialization)",
        "JIT002": "Python branching on a traced value in a jit scope",
        "JIT003": "bare assert in a hot-path module (vanishes under "
                  "python -O; use a typed exception with context)",
        "JIT004": "host sync inside a jit scope",
    }

    def __init__(self,
                 hot_prefixes: Sequence[str] = ("kernels/", "mining/",
                                                "serve/")):
        self.hot_prefixes = tuple(hot_prefixes)

    def check_module(self, mod: Module) -> List[Finding]:
        findings: List[Finding] = []
        jit_funcs = self._find_jit_functions(mod)
        for func, statics, why in jit_funcs:
            findings.extend(self._check_jit_body(mod, func, statics, why))
        if mod.rel.startswith(self.hot_prefixes) or \
                self.hot_prefixes == ("",):
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.Assert):
                    findings.append(mod.finding(
                        node.lineno, "JIT003",
                        "bare assert on a hot path: disabled under "
                        "python -O — raise a typed exception with "
                        "geometry/context instead", self.name))
        return findings

    # -- jit-visible function discovery --------------------------------------

    def _find_jit_functions(self, mod: Module):
        by_name: Dict[str, ast.AST] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                by_name.setdefault(node.name, node)

        out = []
        seen: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    statics = _decorator_marks_jit(dec)
                    if statics is not None and node.name not in seen:
                        seen.add(node.name)
                        out.append((node, statics, "decorated with jax.jit"))
                        break
            elif isinstance(node, ast.Call):
                fn_chain = attr_chain(node.func)
                if fn_chain is None:
                    continue
                tail = fn_chain[-1]
                if tail == "jit" and node.args and \
                        isinstance(node.args[0], ast.Name):
                    target = by_name.get(node.args[0].id)
                    if target is not None and target.name not in seen:
                        seen.add(target.name)
                        out.append((target, _literal_statics(node.keywords),
                                    "passed to jax.jit(...)"))
                elif tail == "pallas_call" and node.args:
                    kernel_arg = node.args[0]
                    kname = None
                    if isinstance(kernel_arg, ast.Name):
                        kname = kernel_arg.id
                    elif isinstance(kernel_arg, ast.Call) and \
                            call_name(kernel_arg) == "partial" and \
                            kernel_arg.args and \
                            isinstance(kernel_arg.args[0], ast.Name):
                        kname = kernel_arg.args[0].id
                    target = by_name.get(kname) if kname else None
                    if target is not None and target.name not in seen:
                        seen.add(target.name)
                        out.append((target, set(), "Pallas kernel body"))
        return out

    # -- body analysis --------------------------------------------------------

    def _check_jit_body(self, mod: Module, func: ast.AST, statics: Set[str],
                        why: str) -> List[Finding]:
        tainted: Set[str] = set()
        args = func.args
        for a in args.args + args.posonlyargs:
            ann = getattr(a.annotation, "id", None)
            if a.arg in statics or a.arg == "self" or \
                    ann in _SCALAR_ANNOTATIONS:
                continue
            tainted.add(a.arg)
        # keyword-only params are this repo's static-config convention
        # (block_k / accum / n_words are bound concrete before tracing)

        findings: List[Finding] = []

        def is_tainted(expr: ast.AST) -> bool:
            return bool(names_in(expr) & tainted)

        def breaks_taint(expr: ast.AST) -> bool:
            """Concrete-at-trace-time projections of a traced array."""
            if isinstance(expr, ast.Attribute) and \
                    expr.attr in ("shape", "ndim", "dtype", "size"):
                return True
            if isinstance(expr, ast.Subscript):
                return breaks_taint(expr.value)
            if isinstance(expr, ast.Call) and call_name(expr) == "len":
                return True
            if isinstance(expr, ast.Tuple):
                return all(breaks_taint(e) for e in expr.elts)
            return False

        class V(ast.NodeVisitor):
            def visit_FunctionDef(self, node):
                if node is func:
                    self.generic_visit(node)
                # nested defs: still traced (closures inside jit) — recurse
                else:
                    self.generic_visit(node)

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Assign(self, node):
                if is_tainted(node.value) and not breaks_taint(node.value):
                    for tgt in node.targets:
                        tainted.update(names_in(tgt))
                self.generic_visit(node)

            def visit_Call(self, node):
                cname = call_name(node)
                if cname in _CAST_CALLS and node.args and \
                        is_tainted(node.args[0]):
                    findings.append(mod.finding(
                        node.lineno, "JIT001",
                        f"{cname}() on traced value in {func.name} "
                        f"({why})", JitSafetyChecker.name))
                elif cname in ("asarray", "array", "device_get") and \
                        node.args and is_tainted(node.args[0]):
                    chain = attr_chain(node.func) or ()
                    if chain[:1] in (("np",), ("numpy",), ("jax",)):
                        findings.append(mod.finding(
                            node.lineno, "JIT004",
                            f"host transfer {'.'.join(chain)}() on traced "
                            f"value in {func.name} ({why})",
                            JitSafetyChecker.name))
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _HOST_SYNC_ATTRS and \
                        is_tainted(node.func.value):
                    code = "JIT001" if node.func.attr in ("item", "tolist") \
                        else "JIT004"
                    findings.append(mod.finding(
                        node.lineno, code,
                        f".{node.func.attr}() on traced value in "
                        f"{func.name} ({why})", JitSafetyChecker.name))
                self.generic_visit(node)

            def visit_If(self, node):
                if is_tainted(node.test):
                    findings.append(mod.finding(
                        node.lineno, "JIT002",
                        f"Python `if` on traced value in {func.name} "
                        f"({why}) — use jnp.where / lax.cond / pl.when",
                        JitSafetyChecker.name))
                self.generic_visit(node)

            def visit_While(self, node):
                if is_tainted(node.test):
                    findings.append(mod.finding(
                        node.lineno, "JIT002",
                        f"Python `while` on traced value in {func.name} "
                        f"({why})", JitSafetyChecker.name))
                self.generic_visit(node)

            def visit_Assert(self, node):
                if is_tainted(node.test):
                    findings.append(mod.finding(
                        node.lineno, "JIT002",
                        f"assert on traced value in {func.name} ({why})",
                        JitSafetyChecker.name))
                self.generic_visit(node)

        for stmt in func.body:
            V().visit(stmt)
        return findings
