"""Dead-module report: src/repro files unreachable from any entry point.

Advisory output (never a CI gate): builds the static import graph of
``src/repro`` and marks every module reachable from the roots — the
``repro.launch`` entry points plus anything imported by ``tests/``,
``benchmarks/``, ``tools/`` or ``examples/``.  What's left is seed-era
code nothing references (the historic ``models/`` / ``train/`` /
``configs/`` scaffolding), listed so a future PR can delete or revive it
deliberately rather than letting it rot silently.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Set

from .engine import iter_py_files

_PKG = "repro"


def module_map(src_root: str) -> Dict[str, str]:
    """Dotted module name -> path for every module under ``src_root``
    (which is the directory CONTAINING the ``repro`` package)."""
    out: Dict[str, str] = {}
    pkg_root = os.path.join(src_root, _PKG)
    for path in iter_py_files(pkg_root):
        rel = os.path.relpath(path, src_root)
        parts = rel[:-3].split(os.sep)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        out[".".join(parts)] = path
    return out


def _module_package(modname: str, path: str) -> str:
    """The package a module's relative imports resolve against."""
    if path.endswith("__init__.py"):
        return modname
    return modname.rsplit(".", 1)[0] if "." in modname else ""


def imports_of(path: str, modname: str, known: Set[str]) -> Set[str]:
    """Known-module names imported by one file (absolute + relative)."""
    with open(path, "r", encoding="utf-8") as fh:
        try:
            tree = ast.parse(fh.read(), filename=path)
        except SyntaxError:
            return set()
    pkg = _module_package(modname, path) if modname else ""
    found: Set[str] = set()

    def note(dotted: str) -> None:
        # credit the module and every enclosing package __init__
        parts = dotted.split(".")
        for i in range(1, len(parts) + 1):
            cand = ".".join(parts[:i])
            if cand in known:
                found.add(cand)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                note(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0:
                base = node.module or ""
            else:
                up = pkg.split(".") if pkg else []
                if node.level - 1 > 0:
                    up = up[:-(node.level - 1)] if node.level - 1 <= len(up) \
                        else []
                base = ".".join(up + ([node.module] if node.module else []))
            if base:
                note(base)
                for alias in node.names:
                    note(f"{base}.{alias.name}")
    return found


def dead_module_report(repo_root: str) -> dict:
    """``{"roots": [...], "reachable": [...], "dead": [...]}`` over
    ``src/repro``."""
    src_root = os.path.join(repo_root, "src")
    known = module_map(src_root)
    names = set(known)

    edges: Dict[str, Set[str]] = {
        name: imports_of(path, name, names) for name, path in known.items()
    }

    roots: Set[str] = {n for n in names if n == f"{_PKG}.launch"
                       or n.startswith(f"{_PKG}.launch.")}
    for sub in ("tests", "benchmarks", "tools", "examples"):
        d = os.path.join(repo_root, sub)
        if not os.path.isdir(d):
            continue
        for path in iter_py_files(d):
            roots |= imports_of(path, "", names)

    reachable: Set[str] = set()
    frontier = sorted(roots)
    while frontier:
        mod = frontier.pop()
        if mod in reachable:
            continue
        reachable.add(mod)
        frontier.extend(sorted(edges.get(mod, ()) - reachable))

    dead = sorted(names - reachable)
    return {
        "roots": sorted(roots),
        "reachable": sorted(reachable),
        "dead": dead,
        "dead_paths": [os.path.relpath(known[m], repo_root) for m in dead],
    }
