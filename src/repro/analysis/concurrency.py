"""Concurrency checker: lock-acquisition graph + thread-shared attributes.

Two rules over the threaded serving surface (``serve/`` + ``obs/``):

* **CONC001 — inconsistent lock ordering.**  Every ``with <lock>:`` scope
  contributes edges held-lock -> newly-acquired-lock; calls made while
  holding a lock contribute edges to every lock the (transitively resolved)
  callee may acquire.  A cycle in the resulting graph means two code paths
  acquire the same locks in opposite orders — the classic ABBA deadlock.
  The dynamic twin of this rule is ``repro.obs.lockwatch``, which records
  the orders an actual threaded run exercised.

* **CONC002 — shared attribute mutated outside a held lock.**  For every
  class that starts a ``threading.Thread(target=self.<m>)``, any attribute
  ASSIGNED inside the thread-target method (or a same-class method it
  calls) is thread-shared; assigning it anywhere in the class outside a
  ``with <lock>:`` scope is a data race.  ``__init__`` is exempt — the
  thread cannot observe construction.  Methods documented as "called under
  the caller's lock" carry an explicit suppression naming that contract.

Static call resolution is deliberately conservative: ``self.m()`` resolves
inside the class; bare ``f()`` resolves to module-level functions of any
analyzed module; ``obj.m()`` resolves by method name across analyzed
classes UNLESS the name collides with a builtin container method
(``append``, ``get``, ...) — a ``list.append`` must not inherit
``CountServer.append``'s lock footprint.  Cross-object attribute locks that
static analysis cannot type (the flusher touching its server's lock) are
resolved through an explicit alias table.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .engine import Checker, Finding, Module, attr_chain, find_cycle

# Attribute chains (joined with ".") whose lock identity crosses objects in
# a way the AST cannot see.  Keyed on the source text of the with-item.
DEFAULT_LOCK_ALIASES = {
    "self._server._lock": "CountServer._lock",   # AsyncFlusher -> its server
    "self.server._lock": "CountServer._lock",    # RuleServer -> its server
    # the composed backend / background compactor acquire their store's lock
    "self.store._store_lock": "VersionedDB._store_lock",
    "self._store._store_lock": "VersionedDB._store_lock",
    "store._store_lock": "VersionedDB._store_lock",   # store = self.store
}

# Method names that collide with builtin container/primitive methods: calls
# through an arbitrary receiver must NOT resolve to same-named methods of
# analyzed classes (e.g. list.append vs CountServer.append).
_BUILTIN_METHODS = frozenset({
    "append", "appendleft", "add", "get", "pop", "popleft", "clear",
    "update", "extend", "remove", "insert", "discard", "sort", "reverse",
    "copy", "count", "index", "items", "keys", "values", "setdefault",
    "join", "split", "strip", "format", "encode", "decode", "read",
    "write", "flush", "acquire", "release", "wait", "notify", "notify_all",
    "set", "is_set", "put", "get_nowait", "start",
})

_LOCKISH_RE = ("lock", "mutex", "_mu")


def _is_lock_factory(node: ast.AST) -> bool:
    """Does this expression construct a threading lock anywhere inside?
    (Covers ``threading.RLock() if async_flush else nullcontext()``.)"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fn = sub.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else None
            if name in ("Lock", "RLock"):
                return True
    return False


def _looks_lockish(name: str) -> bool:
    low = name.lower()
    return any(tok in low for tok in _LOCKISH_RE)


class _FuncFacts:
    """Per-function facts: direct acquisitions, lock-order events, call
    sites made while holding locks, and attribute assignments."""

    def __init__(self, key: str):
        self.key = key
        self.acquires: Set[str] = set()
        # (held_tuple, acquired, line)
        self.events: List[Tuple[Tuple[str, ...], str, int]] = []
        # (held_tuple, kind, callee, line); kind in {"self", "free", "method"}
        self.calls: List[Tuple[Tuple[str, ...], str, str, int]] = []
        # (attr, under_lock, line) for ``self.X = ...`` / ``self.X += ...``
        self.self_assigns: List[Tuple[str, bool, int]] = []


class ConcurrencyChecker(Checker):
    name = "concurrency"
    codes = {
        "CONC001": "inconsistent lock acquisition order (cycle in the "
                   "lock-order graph)",
        "CONC002": "thread-shared attribute mutated outside a held lock",
    }

    def __init__(self,
                 path_prefixes: Sequence[str] = ("serve/", "obs/",
                                                 "mining/spill.py"),
                 aliases: Optional[Dict[str, str]] = None):
        self.path_prefixes = tuple(path_prefixes)
        self.aliases = dict(DEFAULT_LOCK_ALIASES if aliases is None
                            else aliases)
        self._mods: Dict[str, Module] = {}
        # facts keyed by (class_or_None, func_name) -> list (same name may
        # repeat across modules; merged conservatively)
        self._class_funcs: Dict[Tuple[str, str], List[_FuncFacts]] = {}
        self._free_funcs: Dict[str, List[_FuncFacts]] = {}
        self._findings: List[Finding] = []
        self.lock_edges: Dict[Tuple[str, str], Tuple[str, int]] = {}

    # -- per-module collection ------------------------------------------------

    def check_module(self, mod: Module) -> List[Finding]:
        if self.path_prefixes != ("",) and \
                not mod.rel.startswith(self.path_prefixes):
            return []
        self._mods[mod.rel] = mod
        module_locks = self._module_level_locks(mod)
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                self._collect_class(mod, node, module_locks)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                facts = self._collect_function(mod, node, cls=None,
                                               lock_attrs={},
                                               module_locks=module_locks)
                self._free_funcs.setdefault(node.name, []).append(facts)
        return []

    def _module_level_locks(self, mod: Module) -> Dict[str, str]:
        base = mod.rel.rsplit("/", 1)[-1].rsplit(".", 1)[0]
        locks: Dict[str, str] = {}
        for node in mod.tree.body:
            if isinstance(node, ast.Assign) and _is_lock_factory(node.value):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        locks[tgt.id] = f"{base}.{tgt.id}"
        return locks

    def _collect_class(self, mod: Module, cls: ast.ClassDef,
                       module_locks: Dict[str, str]) -> None:
        lock_attrs: Dict[str, str] = {}
        for sub in ast.walk(cls):
            if isinstance(sub, ast.Assign) and _is_lock_factory(sub.value):
                for tgt in sub.targets:
                    chain = attr_chain(tgt)
                    if chain and len(chain) == 2 and chain[0] == "self":
                        lock_attrs[chain[1]] = f"{cls.name}.{chain[1]}"

        thread_targets: Set[str] = set()
        for sub in ast.walk(cls):
            if isinstance(sub, ast.Call):
                fn = sub.func
                name = fn.attr if isinstance(fn, ast.Attribute) else \
                    fn.id if isinstance(fn, ast.Name) else None
                if name != "Thread":
                    continue
                for kw in sub.keywords:
                    if kw.arg == "target":
                        chain = attr_chain(kw.value)
                        if chain and len(chain) == 2 and chain[0] == "self":
                            thread_targets.add(chain[1])

        methods: Dict[str, _FuncFacts] = {}
        for node in cls.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                facts = self._collect_function(mod, node, cls=cls.name,
                                               lock_attrs=lock_attrs,
                                               module_locks=module_locks)
                methods[node.name] = facts
                self._class_funcs.setdefault((cls.name, node.name),
                                             []).append(facts)

        if thread_targets:
            self._check_shared_attrs(mod, cls.name, methods, thread_targets)

    def _check_shared_attrs(self, mod: Module, cls_name: str,
                            methods: Dict[str, _FuncFacts],
                            thread_targets: Set[str]) -> None:
        # thread-owned methods: closure of the targets under self-calls
        owned = set(thread_targets)
        frontier = list(thread_targets)
        while frontier:
            m = frontier.pop()
            facts = methods.get(m)
            if facts is None:
                continue
            for _, kind, callee, _ in facts.calls:
                if kind == "self" and callee in methods and \
                        callee not in owned:
                    owned.add(callee)
                    frontier.append(callee)
        # calls list only records lock-held call sites; also walk unheld
        # self-calls for ownership (a thread method may call helpers while
        # holding nothing)
        changed = True
        while changed:
            changed = False
            for m in list(owned):
                facts = methods.get(m)
                if facts is None:
                    continue
                for _, kind, callee, _ in facts.all_calls:
                    if kind == "self" and callee in methods and \
                            callee not in owned:
                        owned.add(callee)
                        changed = True

        shared: Set[str] = set()
        for m in owned:
            facts = methods.get(m)
            if facts is None:
                continue
            shared |= {attr for attr, _, _ in facts.self_assigns}
        if not shared:
            return
        for mname, facts in methods.items():
            if mname == "__init__":
                continue   # pre-start construction: thread can't observe it
            for attr, under_lock, line in facts.self_assigns:
                if attr in shared and not under_lock:
                    self._findings.append(mod.finding(
                        line, "CONC002",
                        f"{cls_name}.{attr} is assigned by the "
                        f"thread target (Thread(target=self."
                        f"{'/'.join(sorted(thread_targets))})) but mutated "
                        f"here outside any held lock", self.name))

    def _collect_function(self, mod: Module, func: ast.AST, cls: Optional[str],
                          lock_attrs: Dict[str, str],
                          module_locks: Dict[str, str]) -> _FuncFacts:
        key = f"{mod.rel}:{cls + '.' if cls else ''}{func.name}"
        facts = _FuncFacts(key)
        facts.all_calls = []   # (held, kind, callee, line) incl. unheld
        checker = self

        def resolve_lock(expr: ast.AST) -> Optional[str]:
            chain = attr_chain(expr)
            if chain is None:
                return None
            text = ".".join(chain)
            if text in checker.aliases:
                return checker.aliases[text]
            if len(chain) == 2 and chain[0] == "self":
                if chain[1] in lock_attrs:
                    return lock_attrs[chain[1]]
                if _looks_lockish(chain[1]):
                    return f"{cls or mod.rel}.{chain[1]}"
                return None
            if len(chain) == 1:
                if chain[0] in module_locks:
                    return module_locks[chain[0]]
                if _looks_lockish(chain[0]):
                    return f"{mod.rel}:{chain[0]}"
                return None
            # deeper chain (other object's lock): only lockish tails count
            if _looks_lockish(chain[-1]):
                return f"?{text}"
            return None

        held: List[str] = []

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not func:
                return   # nested defs: separate execution context
            if isinstance(node, (ast.With, ast.AsyncWith)):
                acquired: List[str] = []
                for item in node.items:
                    lock = resolve_lock(item.context_expr)
                    if lock is not None:
                        facts.acquires.add(lock)
                        for h in held:
                            if h != lock:
                                facts.events.append(
                                    (tuple(held), lock, node.lineno))
                                break
                        held.append(lock)
                        acquired.append(lock)
                    else:
                        visit(item.context_expr)
                for stmt in node.body:
                    visit(stmt)
                for _ in acquired:
                    held.pop()
                return
            if isinstance(node, ast.Call):
                kind = None
                callee = None
                if isinstance(node.func, ast.Name):
                    kind, callee = "free", node.func.id
                elif isinstance(node.func, ast.Attribute):
                    recv = node.func.value
                    if isinstance(recv, ast.Name) and recv.id == "self":
                        kind, callee = "self", node.func.attr
                    else:
                        kind, callee = "method", node.func.attr
                if callee is not None:
                    rec = (tuple(held), kind, callee, node.lineno)
                    facts.all_calls.append(rec)
                    if held:
                        facts.calls.append(rec)
                for child in ast.iter_child_nodes(node):
                    visit(child)
                return
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    chain = attr_chain(tgt)
                    if chain and len(chain) == 2 and chain[0] == "self":
                        facts.self_assigns.append(
                            (chain[1], bool(held), node.lineno))
                for child in ast.iter_child_nodes(node):
                    visit(child)
                return
            for child in ast.iter_child_nodes(node):
                visit(child)

        for stmt in func.body:
            visit(stmt)
        facts._mod_rel = mod.rel
        return facts

    # -- cross-module graph ---------------------------------------------------

    def finalize(self) -> List[Finding]:
        # fixpoint: lock-acquire closure per callable name bucket
        all_facts: List[_FuncFacts] = []
        for lst in self._class_funcs.values():
            all_facts.extend(lst)
        for lst in self._free_funcs.values():
            all_facts.extend(lst)

        closures: Dict[str, Set[str]] = {f.key: set(f.acquires)
                                         for f in all_facts}

        def callee_keys(facts: _FuncFacts, kind: str,
                        callee: str) -> List[str]:
            out: List[str] = []
            if kind == "self":
                cls = facts.key.split(":")[-1].split(".")[0] \
                    if "." in facts.key.split(":")[-1] else None
                if cls is not None:
                    out += [f.key for f in
                            self._class_funcs.get((cls, callee), [])]
            elif kind == "free":
                out += [f.key for f in self._free_funcs.get(callee, [])]
            elif kind == "method" and callee not in _BUILTIN_METHODS:
                for (c, m), lst in self._class_funcs.items():
                    if m == callee:
                        out += [f.key for f in lst]
            return out

        changed = True
        while changed:
            changed = False
            for facts in all_facts:
                acc = closures[facts.key]
                before = len(acc)
                for _, kind, callee, _ in facts.all_calls:
                    for k in callee_keys(facts, kind, callee):
                        acc |= closures.get(k, set())
                if len(acc) != before:
                    changed = True

        # edges: direct nesting events + lock-held call sites
        edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        for facts in all_facts:
            rel = facts._mod_rel
            for held, acquired, line in facts.events:
                for h in held:
                    if h != acquired:
                        edges.setdefault((h, acquired), (rel, line))
            for held, kind, callee, line in facts.calls:
                for k in callee_keys(facts, kind, callee):
                    for lock in closures.get(k, set()):
                        for h in held:
                            if h != lock:
                                edges.setdefault((h, lock), (rel, line))
        self.lock_edges = edges

        adj: Dict[str, Set[str]] = {}
        for (a, b) in edges:
            adj.setdefault(a, set()).add(b)
        findings = list(self._findings)
        seen_cycles: Set[frozenset] = set()
        while True:
            cycle = find_cycle(adj)
            if cycle is None:
                break
            key = frozenset(cycle)
            if key not in seen_cycles:
                seen_cycles.add(key)
                a, b = cycle[0], cycle[1]
                rel, line = edges.get((a, b), ("<unknown>", 0))
                mod = self._mods.get(rel)
                msg = ("lock-order cycle: " + " -> ".join(cycle)
                       + " (witness edge at this line; some other path "
                         "acquires these locks in the reverse order)")
                if mod is not None:
                    findings.append(mod.finding(line, "CONC001", msg,
                                                self.name))
                else:
                    findings.append(Finding(rel, line, "CONC001", msg,
                                            self.name))
            # break ONE edge of the reported cycle and look again, so
            # distinct cycles each get a finding without looping forever
            adj[cycle[0]].discard(cycle[1])
        return findings
