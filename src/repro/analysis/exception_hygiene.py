"""Exception hygiene checker: no silently swallowed broad handlers.

A broad ``except Exception`` on a serving path is sometimes right — a
failed compaction or a failed background flush must not take down serving.
But "swallow and move on" has a minimum bar, or the failure is invisible
until a user asks why throughput halved:

* the handler must **re-raise** (possibly wrapped), OR
* it must **bind the exception and use it** (preserve context — into a
  ``last_*_error`` attribute, a log record, a telemetry payload) AND
  **account for it** (bump an error counter, record a span, or update an
  error/failure-named field).

**EXC001** flags ``except:``, ``except Exception:`` and
``except BaseException:`` handlers (including tuples containing them) that
miss the bar.  Typed handlers (``except (TableError, OSError)``) are the
caller's business and are not flagged.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .engine import Checker, Finding, Module, attr_chain, call_name

_BROAD = {"Exception", "BaseException"}
_ACCOUNT_CALL_ATTRS = {"inc", "observe", "instant", "span", "record"}
_ACCOUNT_NAME_TOKENS = ("error", "errors", "fail", "failure", "fallback",
                        "warn")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    types = t.elts if isinstance(t, ast.Tuple) else [t]
    for node in types:
        chain = attr_chain(node)
        if chain is not None and chain[-1] in _BROAD:
            return True
    return False


def _has_name_token(name: str) -> bool:
    low = name.lower()
    return any(tok in low for tok in _ACCOUNT_NAME_TOKENS)


class ExceptionHygieneChecker(Checker):
    name = "exception_hygiene"
    codes = {
        "EXC001": "broad except handler that neither re-raises nor "
                  "preserves+accounts the error (silent swallow)",
    }

    def check_module(self, mod: Module) -> List[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ExceptHandler) and _is_broad(node):
                problem = self._handler_problem(node)
                if problem is not None:
                    findings.append(mod.finding(
                        node.lineno, "EXC001",
                        f"broad except handler {problem} — re-raise, or "
                        f"bind the exception, preserve its context, and "
                        f"bump an error counter / span", self.name))
        return findings

    def _handler_problem(self, handler: ast.ExceptHandler) -> Optional[str]:
        reraises = False
        uses_exc = False
        accounts = False
        bound = handler.name
        for node in ast.walk(ast.Module(body=handler.body,
                                        type_ignores=[])):
            if isinstance(node, ast.Raise):
                reraises = True
            if bound is not None and isinstance(node, ast.Name) and \
                    node.id == bound and isinstance(node.ctx, ast.Load):
                uses_exc = True
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr in _ACCOUNT_CALL_ATTRS:
                    accounts = True
                cname = call_name(node)
                if cname is not None and _has_name_token(cname):
                    accounts = True
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    tname = None
                    if isinstance(tgt, ast.Name):
                        tname = tgt.id
                    elif isinstance(tgt, ast.Attribute):
                        tname = tgt.attr
                    elif isinstance(tgt, ast.Subscript) and \
                            isinstance(tgt.slice, ast.Constant) and \
                            isinstance(tgt.slice.value, str):
                        tname = tgt.slice.value
                    if tname is not None and _has_name_token(tname):
                        accounts = True
        if reraises:
            return None
        if bound is None:
            return "swallows without binding the exception"
        if not uses_exc:
            return f"binds `{bound}` but never uses it (context lost)"
        if not accounts:
            return "preserves context but never accounts the error " \
                   "(no counter/span/error field)"
        return None
