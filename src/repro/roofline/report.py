"""Render §Dry-run and §Roofline markdown tables from sweep JSONL records.

  PYTHONPATH=src python -m repro.roofline.report results_single_pod.jsonl \
      [results_multi_pod.jsonl]
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional


def load(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(json.loads(line))
    return out


def _gib(x: float) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | mesh | kind | compile | args GiB/dev | temp GiB/dev | "
        "HLO GFLOP/dev | wire GB/dev | collectives (ar/ag/rs/a2a/cp) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"SKIP | — | — | — | — | {r['reason']} |")
            continue
        if r["status"] == "fail":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                         f"**FAIL** | — | — | — | — | {r['error'][:60]} |")
            continue
        m, roof = r["memory"], r["roofline"]
        c = roof["collectives"]["counts"]
        counts = "/".join(str(c.get(k, 0)) for k in
                          ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
            f"{r['compile_s']}s | {_gib(m['argument_bytes'])} | "
            f"{_gib(m['temp_bytes'])} | {roof['flops_per_device']/1e9:,.0f} | "
            f"{roof['wire_bytes_per_device']/1e9:.1f} | {counts} |")
    return "\n".join(lines)


def roofline_table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "MODEL/HLO | roofline frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        roof = r["roofline"]
        hint = _hint(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['t_compute_s']*1e3:,.1f}ms | "
            f"{roof['t_memory_s']*1e3:,.1f}ms | {roof['t_collective_s']*1e3:,.1f}ms | "
            f"**{roof['bottleneck']}** | {roof['useful_ratio']:.2f} | "
            f"{roof['roofline_fraction']:.3f} | {hint} |")
    return "\n".join(lines)


def _hint(r: dict) -> str:
    roof = r["roofline"]
    b = roof["bottleneck"]
    kind = r["kind"]
    wire = roof["collectives"]["wire_bytes"]
    if b == "collective":
        top = max(wire, key=wire.get) if wire else "?"
        return (f"biggest wire item is {top}: fewer/narrower activation "
                f"reshards (SP gather-once, RS instead of AR, int8 grads)")
    if b == "memory":
        if kind == "decode":
            return "KV/weight reads dominate: quantize KV cache, fuse decode attention"
        return "remat recompute + activation traffic: looser remat policy, fused norms"
    return "MXU-bound: raise arithmetic intensity (larger tiles, bf16 dots)"


def main() -> None:
    recs = load(sys.argv[1])
    print("### Dry-run (single pod 16x16)\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single pod 16x16)\n")
    print(roofline_table(recs))
    if len(sys.argv) > 2:
        mrecs = load(sys.argv[2])
        print("\n### Dry-run (multi-pod 2x16x16)\n")
        print(dryrun_table(mrecs))


if __name__ == "__main__":
    main()
