"""Roofline model of the ``itemset_count`` Pallas kernel, per launch geometry.

The counting kernel is a (N, W)-bitmap x (K, W)-target containment sweep
with a per-class weighted reduction: for every (row, target) pair it ANDs
and compares W packed words, then accumulates C weight columns for the
contained pairs.  Per launch of geometry (N, K, W, C):

  bytes  = 4 * (N*W + N*C + K*W + K*C)      one pass over bitmap + weights,
                                            targets + the (K, C) result
  "FLOPs"= N*K * (2*W + C)                  W ANDs + W compares per pair,
                                            plus the C-column accumulate
                                            (integer ops priced as FLOPs at
                                            the VPU's int32 lane rate)

Predicted launch time on the TARGET hardware is the perfect-overlap roofline
bound ``max(bytes/HBM_BW, flops/PEAK_FLOPS)`` with the same TPU v5e-class
constants as ``roofline.analysis``.  ``record_launch`` publishes measured
wall time against that prediction into the telemetry registry
(``repro.obs``) so ``CountServer.stats()`` / the Prometheus export report a
measured-vs-predicted **efficiency ratio** per geometry.

Container caveat: this repo's CI box runs the kernel in Pallas interpret
mode on CPU, so absolute efficiency there is tiny and only the TREND across
commits is meaningful; on a real TPU the ratio is the MFU-style signal the
autotuning ROADMAP item keys on.
"""
from __future__ import annotations

from .analysis import HBM_BW, PEAK_FLOPS

_WORD_BYTES = 4


def kernel_flops(n: int, k: int, w: int, c: int) -> float:
    """Integer-op count of one containment sweep, priced as FLOPs."""
    return float(n) * float(k) * (2.0 * w + c)


def kernel_bytes(n: int, k: int, w: int, c: int) -> float:
    """HBM traffic of one sweep: bitmap + weights + targets + result."""
    return _WORD_BYTES * (float(n) * w + float(n) * c
                          + float(k) * w + float(k) * c)


def predicted_seconds(n: int, k: int, w: int, c: int,
                      peak_flops: float = PEAK_FLOPS,
                      hbm_bw: float = HBM_BW) -> float:
    """Perfect-overlap roofline bound for one launch on target hardware."""
    return max(kernel_flops(n, k, w, c) / peak_flops,
               kernel_bytes(n, k, w, c) / hbm_bw)


def geometry_label(n: int, k: int, w: int, c: int) -> str:
    """Stable per-geometry metric label.  Serving launches are block-padded,
    so the label set stays small (one per distinct padded shape)."""
    return f"n{n}_k{k}_w{w}_c{c}"


def record_launch(n: int, k: int, w: int, c: int, seconds: float) -> None:
    """Publish one measured launch against the model: three counters per
    geometry (launch count, measured seconds, predicted seconds) — the
    efficiency ratio is derived at snapshot time by
    ``repro.obs.kernel_efficiency``."""
    from ..obs import REGISTRY

    geom = geometry_label(n, k, w, c)
    REGISTRY.counter("kernel_launches_total", geometry=geom).inc()
    REGISTRY.counter("kernel_measured_s_total", geometry=geom).inc(seconds)
    REGISTRY.counter("kernel_predicted_s_total", geometry=geom).inc(
        predicted_seconds(n, k, w, c))
