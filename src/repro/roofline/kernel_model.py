"""Roofline model of the ``itemset_count`` Pallas kernel, per launch geometry.

The counting kernel is a (N, W)-bitmap x (K, W)-target containment sweep
with a per-class weighted reduction: for every (row, target) pair it ANDs
and compares W packed words, then accumulates C weight columns for the
contained pairs.  Per launch of geometry (N, K, W, C):

  bytes  = 4 * (N*W + N*C + K*W + K*C)      one pass over bitmap + weights,
                                            targets + the (K, C) result
  "FLOPs"= N*K * (2*W + C)                  W ANDs + W compares per pair,
                                            plus the C-column accumulate
                                            (integer ops priced as FLOPs at
                                            the VPU's int32 lane rate)

Predicted launch time on the TARGET hardware is the perfect-overlap roofline
bound ``max(bytes/HBM_BW, flops/PEAK_FLOPS)`` with the same TPU v5e-class
constants as ``roofline.analysis``.  ``record_launch`` publishes measured
wall time against that prediction into the telemetry registry
(``repro.obs``) so ``CountServer.stats()`` / the Prometheus export report a
measured-vs-predicted **efficiency ratio** per geometry.

Container caveat: this repo's CI box runs the kernel in Pallas interpret
mode on CPU, so absolute efficiency there is tiny and only the TREND across
commits is meaningful; on a real TPU the ratio is the MFU-style signal the
autotuning ROADMAP item keys on.
"""
from __future__ import annotations

import re
from typing import Tuple

from .analysis import HBM_BW, PEAK_FLOPS

_WORD_BYTES = 4


def kernel_flops(n: int, k: int, w: int, c: int) -> float:
    """Integer-op count of one containment sweep, priced as FLOPs."""
    return float(n) * float(k) * (2.0 * w + c)


def kernel_bytes(n: int, k: int, w: int, c: int) -> float:
    """HBM traffic of one sweep: bitmap + weights + targets + result."""
    return _WORD_BYTES * (float(n) * w + float(n) * c
                          + float(k) * w + float(k) * c)


def predicted_seconds(n: int, k: int, w: int, c: int,
                      peak_flops: float = PEAK_FLOPS,
                      hbm_bw: float = HBM_BW) -> float:
    """Perfect-overlap roofline bound for one launch on target hardware."""
    return max(kernel_flops(n, k, w, c) / peak_flops,
               kernel_bytes(n, k, w, c) / hbm_bw)


def geometry_label(n: int, k: int, w: int, c: int) -> str:
    """EXACT per-geometry label (debug/report use).  Telemetry records under
    :func:`geometry_bucket` instead — see below."""
    return f"n{n}_k{k}_w{w}_c{c}"


# -- geometry bucketing ------------------------------------------------------
#
# Telemetry labels and tuning-table keys are BUCKETIZED geometries: each
# dimension rounds UP to a power of two inside a clamped range, so however
# adversarial the query mix (one distinct N per append, one distinct K per
# query shape) the label set stays bounded and the metrics registry cannot
# grow without limit.  The roofline PREDICTION still uses the exact geometry
# — only the label under which it is aggregated is rounded.  A hard cap
# backstops the clamp: once ``MAX_GEOMETRY_BUCKETS`` distinct buckets exist,
# any new bucket collapses into the single ``GEOMETRY_OVERFLOW`` label.

_BUCKET_RANGES = ((128, 1 << 26),   # n: kernel pads rows to 128 anyway
                  (8, 1 << 20),     # k: kernel pads targets to 8
                  (1, 64),          # w: MAX_KERNEL_WORDS
                  (1, 16))          # c: class columns
MAX_GEOMETRY_BUCKETS = 256
GEOMETRY_OVERFLOW = "overflow"
_BUCKET_RE = re.compile(r"n(\d+)_k(\d+)_w(\d+)_c(\d+)")
_SEEN_BUCKETS: set = set()


def _bucket_dim(x: int, lo: int, hi: int) -> int:
    x = max(int(x), 1)
    p2 = 1 << (x - 1).bit_length()     # round up to a power of two
    return min(max(p2, lo), hi)


def geometry_bucket(n: int, k: int, w: int, c: int) -> str:
    """Bucketized geometry label: pow2-rounded, range-clamped dimensions."""
    bn, bk, bw, bc = (_bucket_dim(x, lo, hi)
                      for x, (lo, hi) in zip((n, k, w, c), _BUCKET_RANGES))
    return f"n{bn}_k{bk}_w{bw}_c{bc}"


def bucket_shape(bucket: str) -> Tuple[int, int, int, int]:
    """Parse ``"nN_kK_wW_cC"`` back to ``(n, k, w, c)`` (ValueError if not
    a geometry bucket — e.g. the overflow label)."""
    m = _BUCKET_RE.fullmatch(bucket)
    if m is None:
        raise ValueError(f"not a geometry bucket label: {bucket!r}")
    return tuple(int(g) for g in m.groups())  # type: ignore[return-value]


def _bucket_label(n: int, k: int, w: int, c: int) -> str:
    """Bucket label with the hard cardinality cap applied."""
    b = geometry_bucket(n, k, w, c)
    if b in _SEEN_BUCKETS:
        return b
    if len(_SEEN_BUCKETS) >= MAX_GEOMETRY_BUCKETS:
        return GEOMETRY_OVERFLOW
    _SEEN_BUCKETS.add(b)
    return b


def _reset_geometry_buckets() -> None:
    """Drop the seen-bucket cap state (tests only)."""
    _SEEN_BUCKETS.clear()


def record_launch(n: int, k: int, w: int, c: int, seconds: float) -> None:
    """Publish one measured launch against the model: three counters per
    geometry BUCKET (launch count, measured seconds, predicted seconds) —
    the efficiency ratio is derived at snapshot time by
    ``repro.obs.kernel_efficiency``.  The prediction uses the exact
    geometry; only the aggregation label is bucketized (bounded label set,
    and the same keys the tuning table uses — closing the feedback loop in
    ``roofline.autotune.staleness_report``)."""
    from ..obs import REGISTRY

    geom = _bucket_label(n, k, w, c)
    REGISTRY.counter("kernel_launches_total", geometry=geom).inc()
    REGISTRY.counter("kernel_measured_s_total", geometry=geom).inc(seconds)
    REGISTRY.counter("kernel_predicted_s_total", geometry=geom).inc(
        predicted_seconds(n, k, w, c))
