"""Roofline-driven kernel autotuner: per-device tuned launch configs.

PR 7 gave every eager ``itemset_count`` launch a measured-vs-predicted
ledger per geometry bucket (``kernel_model.record_launch`` →
``obs.kernel_efficiency``).  This module CONSUMES it — the offline+online
loop the ROADMAP autotuning item asks for:

  * **offline sweep** (:func:`sweep`, driven by ``tools/autotune.py``):
    micro-benchmark the candidate lattice — ``block_k ∈ {64,128,256,512}``,
    ``accum ∈ {vpu_int32, mxu_f32}`` (the N < 2^24 exactness guard is
    respected: oversized geometries never get an MXU candidate), and a
    ``chunk_rows`` grid for the streaming sweep — over bucketized launch
    geometries, and persist the winner per (device-kind, geometry-bucket)
    in a versioned JSON :class:`TuningTable`.
  * **resolution seam** (:func:`resolve_launch_config`): every call site
    that used to hard-code ``block_k=256`` / ``accum="vpu_int32"`` /
    ``chunk_rows`` heuristics now passes ``None`` and lets this function
    look the geometry's bucket up in the active table — falling back to
    the original defaults when there is no table, no matching entry, or an
    entry whose ``mxu_f32`` pick would violate the exactness bound for the
    actual row count.  Resolution happens EAGERLY (host-side, concrete
    shapes) so jit caches always see concrete static arguments.
  * **online staleness** (:func:`staleness_report`): the live per-bucket
    efficiency ledger is compared against the sweep-time efficiency of the
    recorded runner-up candidate; a tuned entry whose measured ratio
    drifts below that alternative (x ``STALE_MARGIN``) is flagged stale —
    the signal to re-run the sweep.

Config choice NEVER changes counts: every candidate is bit-exact (the PBT
battery in ``tests/test_autotune.py`` pins dense, streaming, and GFP paths
across the whole lattice), so a bad table can only cost speed.

Table discovery precedence: ``$REPRO_TUNE_TABLE`` (explicit path) → the
user cache (``~/.cache/repro/autotune/<device-kind>.json``, override root
with ``$REPRO_CACHE_DIR``) → the in-repo committed table for the CI box
(``roofline/tables/<device-kind>.json``).  ``$REPRO_AUTOTUNE=0`` disables
discovery entirely.  Schema-checked on load; anything invalid falls back
to the defaults (and bumps ``autotune_table_errors_total``).

CPU-interpret caveat: on this container the kernel runs in Pallas
interpret mode, so sweep timings measure the Python interpreter, not a
TPU — the committed CPU table keeps CI honest about the MECHANISM (tuned
must never lose to default; ``BENCH_tune.json`` gates it) while absolute
win margins only mean something on real hardware.
"""
from __future__ import annotations

import json
import math
import os
import re
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

from .. import obs
from .kernel_model import (GEOMETRY_OVERFLOW, bucket_shape, geometry_bucket,
                           predicted_seconds)

__all__ = [
    "LaunchConfig", "TuningTable", "TableEntry", "TableError",
    "DEFAULT_BLOCK_K", "DEFAULT_BLOCK_N", "DEFAULT_ACCUM", "DEFAULT_CONFIG",
    "BLOCK_K_LATTICE", "ACCUM_LATTICE", "CHUNK_ROWS_GRID", "MXU_MAX_ROWS",
    "SCHEMA_VERSION", "STALE_MARGIN",
    "resolve_launch_config", "resolve_serve_block_k", "candidate_configs",
    "sweep", "save_table", "load_table", "table_to_dict", "table_from_dict",
    "set_active_table", "clear_active_table", "active_table",
    "describe_active", "device_kind", "repo_table_path", "cache_table_path",
    "default_table_paths", "staleness_report", "derived_chooser_thresholds",
]

# Today's hard-coded constants, now the documented fallback.
DEFAULT_BLOCK_K = 256
DEFAULT_BLOCK_N = 1024
DEFAULT_ACCUM = "vpu_int32"

# The candidate lattice the sweep measures.
BLOCK_K_LATTICE = (64, 128, 256, 512)
ACCUM_LATTICE = ("vpu_int32", "mxu_f32")
CHUNK_ROWS_GRID = (0, 4096, 16384)      # 0 = the staging-budget heuristic

# mxu_f32 is exact only while every launch sees < 2^24 rows (ops.py guard).
MXU_MAX_ROWS = 1 << 24

# The serve seam's reference micro-batch: the batcher pads each flush's K up
# to a block_k multiple, so the padded launch costs us(k=block_k) for any
# flush of <= block_k queries — an effect a fixed-K sweep cannot see.  The
# serve view times each candidate at its OWN padded geometry (k = block_k)
# and picks the cheapest flush for a batch of this size.
SERVE_REF_BATCH = 64

SCHEMA_VERSION = 1

# A non-default winner must beat the default by >3% to displace it — sweeps
# share a noisy box; a coin-flip "win" must not churn the table.
KEEP_DEFAULT_WITHIN = 0.97

# Staleness: flag when live efficiency < alternative's sweep efficiency x this.
STALE_MARGIN = 0.9

# The launch-overhead assumption (us) the hand-tuned chooser crossovers
# encode: DEFAULT_MIN_DEPTH=4 / DEFAULT_TINY_ROWS were picked for a dispatch
# cost of about this much.  Measured overhead scales the derived thresholds
# relative to it (docs/autotuning.md).
REF_LAUNCH_OVERHEAD_US = 100.0


@dataclass(frozen=True)
class LaunchConfig:
    """One launch configuration.  ``chunk_rows`` is None for the planner's
    staging-budget heuristic; ``source`` says where the config came from."""
    block_k: int = DEFAULT_BLOCK_K
    block_n: int = DEFAULT_BLOCK_N
    accum: str = DEFAULT_ACCUM
    chunk_rows: Optional[int] = None
    source: str = "default"


DEFAULT_CONFIG = LaunchConfig()


class TableError(ValueError):
    """A tuning table failed schema validation (load falls back to defaults)."""


@dataclass
class TableEntry:
    """Winner + evidence for one geometry bucket.  ``serve_block_k`` is the
    serve-seam winner (batcher padding view, timed at k = block_k per
    candidate); None means no serve view was swept — the serve path then
    keeps its default block."""
    config: LaunchConfig
    us: float                                  # winner, best-of-repeats
    efficiency: float                          # predicted_s / measured_s
    candidates: Dict[str, float] = field(default_factory=dict)
    chunk_candidates: Dict[str, float] = field(default_factory=dict)
    serve_block_k: Optional[int] = None
    serve_candidates: Dict[str, float] = field(default_factory=dict)


@dataclass
class TuningTable:
    device_kind: str
    entries: Dict[str, TableEntry]
    created: str = ""
    schema: int = SCHEMA_VERSION
    source: str = "<memory>"


# -- hot-path counters (bound once; registry resets keep them valid) ---------
_M_RESOLVE_DEFAULT = obs.REGISTRY.counter("autotune_resolutions_total",
                                          source="default")
_M_RESOLVE_TABLE = obs.REGISTRY.counter("autotune_resolutions_total",
                                        source="table")
_M_TABLE_ERRORS = obs.REGISTRY.counter("autotune_table_errors_total")

# last swallowed error per fallback site (device probe, serve-block probe):
# surfaced through the telemetry section so a chronically failing probe is
# visible in stats() instead of silently pinning the defaults
LAST_FALLBACKS: Dict[str, str] = {}


def _note_fallback(site: str, exc: BaseException) -> None:
    """Account one swallowed fallback: bounded-label counter + context."""
    LAST_FALLBACKS[site] = f"{type(exc).__name__}: {exc}"
    obs.REGISTRY.counter("autotune_fallbacks_total", site=site).inc()


# -- active-table state ------------------------------------------------------
# pinned: an explicit set_active_table() call (tests pin None = defaults).
# resolved: lazy discovery already ran (clear_active_table() re-arms it).
_STATE = {"pinned": False, "resolved": False, "table": None}


def device_kind() -> str:
    """Normalized device-kind token for table file names ('cpu', 'tpu_v5e'…)."""
    try:
        import jax
        kind = jax.devices()[0].device_kind
    except Exception as e:
        _note_fallback("device_kind", e)
        return "cpu"
    return re.sub(r"[^a-z0-9_.-]+", "_", str(kind).lower()).strip("_") or "cpu"


def repo_table_path(kind: Optional[str] = None) -> str:
    return os.path.join(os.path.dirname(__file__), "tables",
                        f"{kind or device_kind()}.json")


def cache_table_path(kind: Optional[str] = None) -> str:
    root = os.environ.get("REPRO_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(root, "repro", "autotune",
                        f"{kind or device_kind()}.json")


def default_table_paths() -> Tuple[str, ...]:
    """Discovery precedence: env override, user cache, committed repo table."""
    env = os.environ.get("REPRO_TUNE_TABLE")
    paths = [env] if env else []
    paths += [cache_table_path(), repo_table_path()]
    return tuple(paths)


def set_active_table(table: Optional[TuningTable]) -> None:
    """Pin the active table (None = pin to the defaults, discovery off)."""
    _STATE.update(pinned=True, resolved=True, table=table)


def clear_active_table() -> None:
    """Unpin and re-arm lazy discovery (the process-start state)."""
    _STATE.update(pinned=False, resolved=False, table=None)


def active_table() -> Optional[TuningTable]:
    """The table ``resolve_launch_config`` consults (lazy discovery)."""
    if not _STATE["resolved"]:
        _STATE["table"] = _discover_table()
        _STATE["resolved"] = True
    return _STATE["table"]


def _discover_table() -> Optional[TuningTable]:
    if os.environ.get("REPRO_AUTOTUNE", "1").lower() in ("0", "off", "false"):
        return None
    for path in default_table_paths():
        if not os.path.isfile(path):
            continue
        try:
            return load_table(path)
        except (TableError, OSError):
            _M_TABLE_ERRORS.inc()
    return None


def describe_active() -> str:
    """One-line banner text for the launchers: which table (if any) is live."""
    t = active_table()
    if t is None:
        return "default launch configs (no tuning table)"
    return (f"tuning table [{t.device_kind}] {len(t.entries)} entries "
            f"from {t.source}")


# -- the seam ----------------------------------------------------------------

def resolve_launch_config(n: int, k: int, w: int, c: int) -> LaunchConfig:
    """Launch config for one (N, K, W, C) geometry: the active table's entry
    for its bucket, or :data:`DEFAULT_CONFIG`.

    Exactness guard re-checked at resolve time: a table entry tuned to
    ``mxu_f32`` on a bucket whose ACTUAL row count reaches 2^24 falls back
    to the VPU accumulator (buckets round up, so a tuned bucket can be hit
    by a larger real N than the sweep measured)."""
    t = active_table()
    if t is None:
        _M_RESOLVE_DEFAULT.inc()
        return DEFAULT_CONFIG
    entry = t.entries.get(geometry_bucket(n, k, w, c))
    if entry is None:
        _M_RESOLVE_DEFAULT.inc()
        return DEFAULT_CONFIG
    cfg = entry.config
    if cfg.accum == "mxu_f32" and n >= MXU_MAX_ROWS:
        cfg = replace(cfg, accum=DEFAULT_ACCUM)
    _M_RESOLVE_TABLE.inc()
    return cfg


def resolve_serve_block_k(store) -> int:
    """Serve-path block_k for a count store (CountServer/MicroBatcher init).

    Serve launches pad K up to block_k multiples, so the nominal K for the
    bucket lookup is the default block itself; N/W/C come from the store's
    resident geometry.  Only the entry's ``serve_block_k`` (the padding-
    aware serve view) is honored — the fixed-K winner optimizes a different
    objective and must not shrink or grow the batcher's padding untested.
    Anything unmeasurable falls back to the default."""
    try:
        n = int(getattr(store, "base_rows", 0) or getattr(store, "n_rows", 0))
        w = int(store.vocab.n_words)
        c = int(store.n_classes)
    except Exception as e:
        _note_fallback("serve_block_k", e)
        return DEFAULT_BLOCK_K
    t = active_table()
    if t is None:
        return DEFAULT_BLOCK_K
    entry = t.entries.get(geometry_bucket(max(n, 1), DEFAULT_BLOCK_K,
                                          max(w, 1), max(c, 1)))
    if entry is None or not entry.serve_block_k:
        return DEFAULT_BLOCK_K
    return int(entry.serve_block_k)


# -- persistence -------------------------------------------------------------

def table_to_dict(table: TuningTable) -> dict:
    return {
        "schema": table.schema,
        "device_kind": table.device_kind,
        "created": table.created,
        "entries": {
            bucket: {
                "block_k": e.config.block_k,
                "block_n": e.config.block_n,
                "accum": e.config.accum,
                "chunk_rows": int(e.config.chunk_rows or 0),
                "us": e.us,
                "efficiency": e.efficiency,
                "candidates": e.candidates,
                "chunk_candidates": e.chunk_candidates,
                "serve_block_k": int(e.serve_block_k or 0),
                "serve_candidates": e.serve_candidates,
            }
            for bucket, e in table.entries.items()
        },
    }


def table_from_dict(doc: dict, source: str = "<memory>") -> TuningTable:
    """Schema-checked deserialization; raises :class:`TableError` on any
    violation (the loaders then fall back to the defaults)."""
    if not isinstance(doc, dict):
        raise TableError("tuning table must be a JSON object")
    if doc.get("schema") != SCHEMA_VERSION:
        raise TableError(f"unsupported tuning-table schema "
                         f"{doc.get('schema')!r} (want {SCHEMA_VERSION})")
    kind = doc.get("device_kind")
    if not isinstance(kind, str) or not kind:
        raise TableError("device_kind must be a non-empty string")
    raw = doc.get("entries")
    if not isinstance(raw, dict):
        raise TableError("entries must be an object")
    entries: Dict[str, TableEntry] = {}
    for bucket, e in raw.items():
        try:
            bucket_shape(bucket)
        except ValueError as exc:
            raise TableError(str(exc)) from exc
        if not isinstance(e, dict):
            raise TableError(f"{bucket}: entry must be an object")
        bk, bn = e.get("block_k"), e.get("block_n", DEFAULT_BLOCK_N)
        accum = e.get("accum")
        cr = e.get("chunk_rows", 0)
        us = e.get("us")
        if bk not in BLOCK_K_LATTICE:
            raise TableError(f"{bucket}: block_k {bk!r} outside the lattice "
                             f"{BLOCK_K_LATTICE}")
        if not isinstance(bn, int) or bn <= 0:
            raise TableError(f"{bucket}: block_n must be a positive int")
        if accum not in ACCUM_LATTICE:
            raise TableError(f"{bucket}: accum {accum!r} outside "
                             f"{ACCUM_LATTICE}")
        if not isinstance(cr, int) or cr < 0:
            raise TableError(f"{bucket}: chunk_rows must be an int >= 0")
        if not isinstance(us, (int, float)) or us <= 0:
            raise TableError(f"{bucket}: us must be a positive number")
        sbk = e.get("serve_block_k", 0)
        if sbk not in (0, None) and sbk not in BLOCK_K_LATTICE:
            raise TableError(f"{bucket}: serve_block_k {sbk!r} outside the "
                             f"lattice {BLOCK_K_LATTICE}")
        entries[bucket] = TableEntry(
            config=LaunchConfig(block_k=bk, block_n=bn, accum=accum,
                                chunk_rows=cr or None, source="table"),
            us=float(us),
            efficiency=float(e.get("efficiency", 0.0)),
            candidates={str(kk): float(v)
                        for kk, v in (e.get("candidates") or {}).items()},
            chunk_candidates={str(kk): float(v)
                              for kk, v in
                              (e.get("chunk_candidates") or {}).items()},
            serve_block_k=sbk or None,
            serve_candidates={str(kk): float(v)
                              for kk, v in
                              (e.get("serve_candidates") or {}).items()},
        )
    return TuningTable(device_kind=kind, entries=entries,
                       created=str(doc.get("created", "")),
                       schema=SCHEMA_VERSION, source=source)


def save_table(table: TuningTable, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(table_to_dict(table), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_table(path: str) -> TuningTable:
    try:
        with open(path) as f:
            doc = json.load(f)
    except json.JSONDecodeError as exc:
        raise TableError(f"{path}: not valid JSON ({exc})") from exc
    return table_from_dict(doc, source=path)


# -- the offline sweep -------------------------------------------------------

def candidate_configs(n: int) -> Tuple[Tuple[int, str], ...]:
    """(block_k, accum) lattice for a bucket, MXU guard applied."""
    return tuple((bk, acc) for bk in BLOCK_K_LATTICE for acc in ACCUM_LATTICE
                 if not (acc == "mxu_f32" and n >= MXU_MAX_ROWS))


def _cand_key(block_k: int, accum: str) -> str:
    return f"bk{block_k}/{accum}"


def _time_best_of(fn: Callable[[], object], repeats: int) -> float:
    """Best-of-N wall time in microseconds (first call warms the jit cache)."""
    fn()
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _synthetic_problem(n: int, k: int, w: int, c: int):
    """Deterministic synthetic workload for one bucket: random bitmap rows,
    targets derived from row pairs (plausible containment density), unit
    weights."""
    import numpy as np

    rng = np.random.default_rng([0x7A11, n, k, w, c])
    tx = rng.integers(0, 1 << 32, size=(n, w), dtype=np.uint64) \
        .astype(np.uint32)
    picks = rng.integers(0, n, size=(2, k))
    tgt = (tx[picks[0]] & tx[picks[1]]).astype(np.uint32)
    wts = np.ones((n, c), np.int32)
    return tx, tgt, wts


def sweep(geometries: Iterable[Tuple[int, int, int, int]], *,
          repeats: int = 3,
          block_ks: Sequence[int] = BLOCK_K_LATTICE,
          accums: Sequence[str] = ACCUM_LATTICE,
          chunk_grid: Sequence[int] = CHUNK_ROWS_GRID,
          kind: Optional[str] = None,
          created: str = "",
          log: Optional[Callable[[str], None]] = None) -> TuningTable:
    """Micro-benchmark the candidate lattice over each geometry's BUCKET and
    return the winning :class:`TuningTable` (not yet active or persisted).

    Kernel wall-time telemetry is suspended for the duration: losing
    candidates must not pollute the live efficiency ledger the staleness
    rule reads."""
    import jax.numpy as jnp
    import numpy as np

    from ..kernels.itemset_count import itemset_counts
    from ..mining.plan import choose_chunk_rows
    from ..mining.stream import streaming_counts

    buckets = []
    for g in geometries:
        b = geometry_bucket(*g)
        if b not in buckets:
            buckets.append(b)

    entries: Dict[str, TableEntry] = {}
    prev_timing = obs.KERNEL_TIMING
    obs.configure(kernel_timing=False)
    try:
        for bucket in buckets:
            n, k, w, c = bucket_shape(bucket)
            tx, tgt, wts = _synthetic_problem(n, k, w, c)
            txd, tgtd, wtsd = jnp.asarray(tx), jnp.asarray(tgt), \
                jnp.asarray(wts)

            cands: Dict[str, float] = {}
            for bk in block_ks:
                for acc in accums:
                    if acc == "mxu_f32" and n >= MXU_MAX_ROWS:
                        continue
                    cands[_cand_key(bk, acc)] = _time_best_of(
                        lambda bk=bk, acc=acc: np.asarray(itemset_counts(
                            txd, tgtd, wtsd, block_k=bk,
                            block_n=DEFAULT_BLOCK_N, accum=acc)),
                        repeats)
            default_key = _cand_key(DEFAULT_BLOCK_K, DEFAULT_ACCUM)
            best_key = min(cands, key=cands.get)  # type: ignore[arg-type]
            if (default_key in cands and best_key != default_key
                    and cands[best_key]
                    > cands[default_key] * KEEP_DEFAULT_WITHIN):
                best_key = default_key            # not a decisive win
            win_bk, win_acc = best_key.split("/")
            win_bk = int(win_bk[2:])

            # chunk_rows grid with the winning block config (0 = heuristic)
            chunk_cands: Dict[str, float] = {}
            heuristic = choose_chunk_rows(w, c)
            if n > 1024:
                for cr in chunk_grid:
                    eff = int(cr) or heuristic
                    if cr and (eff >= n and heuristic >= n):
                        continue    # indistinguishable from the heuristic
                    chunk_cands[str(int(cr))] = _time_best_of(
                        lambda eff=eff: np.asarray(streaming_counts(
                            tx, tgt, wts, chunk_rows=eff, block_k=win_bk,
                            block_n=DEFAULT_BLOCK_N, accum=win_acc)),
                        max(1, repeats - 1))
            win_cr = 0
            if chunk_cands:
                best_cr = min(chunk_cands, key=chunk_cands.get)  # type: ignore[arg-type]
                if ("0" in chunk_cands and best_cr != "0"
                        and chunk_cands[best_cr]
                        > chunk_cands["0"] * KEEP_DEFAULT_WITHIN):
                    best_cr = "0"
                win_cr = int(best_cr)

            # serve view: the batcher pads a flush's K up to block_k, so a
            # <= block_k-query flush costs a k=block_k launch — time each
            # candidate at its OWN padded geometry.  Structural (smaller
            # block = strictly less padded work), unlike the fixed-K tie.
            serve_cands: Dict[str, float] = {}
            serve_bk = 0
            if k > min(block_ks):
                for bk in block_ks:
                    stx, stgt, swts = _synthetic_problem(n, int(bk), w, c)
                    stxd, stgtd, swtsd = (jnp.asarray(stx), jnp.asarray(stgt),
                                          jnp.asarray(swts))
                    flushes = max(1, -(-SERVE_REF_BATCH // int(bk)))
                    serve_cands[str(int(bk))] = flushes * _time_best_of(
                        lambda: np.asarray(itemset_counts(
                            stxd, stgtd, swtsd, block_k=int(bk),
                            block_n=DEFAULT_BLOCK_N, accum=win_acc)),
                        max(1, repeats - 1))
                best_sbk = min(serve_cands, key=serve_cands.get)  # type: ignore[arg-type]
                default_sbk = str(DEFAULT_BLOCK_K)
                if (default_sbk in serve_cands and best_sbk != default_sbk
                        and serve_cands[best_sbk]
                        > serve_cands[default_sbk] * KEEP_DEFAULT_WITHIN):
                    best_sbk = default_sbk
                serve_bk = int(best_sbk)

            us = cands[best_key]
            entries[bucket] = TableEntry(
                config=LaunchConfig(block_k=win_bk, block_n=DEFAULT_BLOCK_N,
                                    accum=win_acc, chunk_rows=win_cr or None,
                                    source="table"),
                us=us,
                efficiency=predicted_seconds(n, k, w, c) / (us * 1e-6),
                candidates=cands,
                chunk_candidates=chunk_cands,
                serve_block_k=serve_bk or None,
                serve_candidates=serve_cands,
            )
            if log is not None:
                log(f"autotune: {bucket}: {best_key} "
                    f"({us:.0f}us, chunk_rows={win_cr or 'auto'}, "
                    f"serve_block_k={serve_bk or 'default'}, "
                    f"{len(cands)} candidates)")
    finally:
        obs.configure(kernel_timing=prev_timing)
    return TuningTable(device_kind=kind or device_kind(), entries=entries,
                       created=created)


# -- the online feedback loop ------------------------------------------------

def staleness_report(table: Optional[TuningTable] = None,
                     snap: Optional[dict] = None) -> Dict[str, dict]:
    """Per-bucket staleness verdicts from the live efficiency ledger.

    An entry is STALE when its live measured-vs-predicted efficiency has
    drifted below the sweep-time efficiency of the recorded runner-up
    candidate (x :data:`STALE_MARGIN`): the config that won the sweep is now
    delivering less than the alternative did back then, so the sweep should
    be re-run.  Buckets with no live launches report ``stale: False`` with
    a reason."""
    t = table if table is not None else active_table()
    if t is None:
        return {}
    live = obs.kernel_efficiency(snap)
    out: Dict[str, dict] = {}
    for bucket, entry in t.entries.items():
        win_key = _cand_key(entry.config.block_k, entry.config.accum)
        alts = {kk: us for kk, us in entry.candidates.items()
                if kk != win_key and us > 0}
        row = {"stale": False, "config": win_key,
               "sweep_efficiency": entry.efficiency,
               "live_efficiency": None, "launches": 0,
               "alternative": None, "alternative_efficiency": None}
        if alts:
            alt_key = min(alts, key=alts.get)  # type: ignore[arg-type]
            row["alternative"] = alt_key
            # sweep-time efficiency of the runner-up, from its measured us
            row["alternative_efficiency"] = (entry.efficiency * entry.us
                                             / alts[alt_key])
        ledger = live.get(bucket)
        if ledger and ledger.get("efficiency") is not None:
            row["live_efficiency"] = ledger["efficiency"]
            row["launches"] = ledger["launches"]
            if row["alternative_efficiency"] is not None:
                row["stale"] = bool(
                    ledger["efficiency"]
                    < row["alternative_efficiency"] * STALE_MARGIN)
        else:
            row["reason"] = "no live launches recorded for this bucket"
        out[bucket] = row
    return out


def _telemetry_section() -> dict:
    """The ``stats()["telemetry"]["autotune"]`` block (registered below)."""
    t = active_table()
    if t is None:
        return {"active": False, "source": "default", "entries": {},
                "stale": {}, "fallbacks": dict(LAST_FALLBACKS)}
    return {
        "active": True,
        "source": t.source,
        "fallbacks": dict(LAST_FALLBACKS),
        "device_kind": t.device_kind,
        "entries": {
            bucket: {"block_k": e.config.block_k, "block_n": e.config.block_n,
                     "accum": e.config.accum,
                     "chunk_rows": e.config.chunk_rows,
                     "serve_block_k": e.serve_block_k, "us": e.us}
            for bucket, e in t.entries.items()
        },
        "stale": staleness_report(t),
    }


obs.register_section("autotune", _telemetry_section)


# -- measured chooser crossovers ---------------------------------------------

def _launch_cost_fit(table: TuningTable) -> Optional[Tuple[float, float]]:
    """Least-squares fit ``us ≈ overhead + per_row * n`` over the table's
    winner timings (needs >= 2 distinct row buckets).  Returns
    ``(overhead_us, per_row_us)`` with sane floors, or None."""
    pts = []
    for bucket, e in table.entries.items():
        try:
            n, _, _, _ = bucket_shape(bucket)
        except ValueError:
            continue
        pts.append((float(n), e.us))
    if len({p[0] for p in pts}) < 2:
        return None
    mx = sum(p[0] for p in pts) / len(pts)
    my = sum(p[1] for p in pts) / len(pts)
    var = sum((p[0] - mx) ** 2 for p in pts)
    cov = sum((p[0] - mx) * (p[1] - my) for p in pts)
    per_row = max(cov / var, 1e-6) if var > 0 else 1e-6
    overhead = max(my - per_row * mx, 1.0)
    return overhead, per_row


def _stream_ratio(table: TuningTable) -> Optional[float]:
    """Median measured single-pass/chunked throughput ratio (<= ~1 when
    chunking costs something; None without chunk evidence)."""
    ratios = []
    for e in table.entries.values():
        chunked = [us for cr, us in e.chunk_candidates.items()
                   if cr != "0" and us > 0]
        if chunked and e.us > 0:
            ratios.append(e.us / min(chunked))
    if not ratios:
        return None
    ratios.sort()
    return ratios[len(ratios) // 2]


def derived_chooser_thresholds(
        table: Optional[TuningTable] = None) -> Dict[str, int]:
    """Chooser crossovers derived from the table's MEASURED throughput
    (empty dict without a table or enough evidence → the chooser keeps its
    hand-tuned constants).  All values are clamped to sane ranges: sweep
    timings on the CPU-interpret container are wild, and a mistuned
    threshold must only ever cost speed, never sanity.

      * ``tiny_rows``      — rows where launch overhead ≈ sweep cost
                             (``overhead / per_row``): below it, dense
                             always wins.
      * ``min_depth``      — gfp crossover shifted by how much pricier a
                             launch is than the :data:`REF_LAUNCH_OVERHEAD_US`
                             assumption behind the default depth 4
                             (``4 - log2(overhead/ref)``): pricier launches
                             → guided counting pays off shallower.
      * ``stream_threshold_bytes`` — dense-vs-streaming residency crossover
                             scaled inversely with the measured chunking
                             penalty: near-free chunking lowers the
                             threshold (stream earlier, buy headroom),
                             expensive chunking raises it (cling to
                             residency).
      * ``gfp_host_rows``  — the GFP hybrid's host-vs-kernel block
                             crossover, same overhead/per-row quantity as
                             ``tiny_rows`` on its own clamp.
    """
    t = table if table is not None else active_table()
    if t is None:
        return {}
    out: Dict[str, int] = {}
    fit = _launch_cost_fit(t)
    if fit is not None:
        overhead_us, per_row_us = fit
        crossover = int(round(overhead_us / per_row_us))
        out["tiny_rows"] = min(65536, max(512, crossover))
        # the sweep measures only the KERNEL side of the hybrid, so measured
        # evidence can raise the host crossover (launches proved expensive)
        # but never push blocks onto the kernel below the hand-tuned default
        # (4096 = gfp_backend.DEFAULT_HOST_BLOCK_ROWS; no host cost was swept
        # to justify that direction)
        out["gfp_host_rows"] = min(16384, max(4096, crossover))
        shift = math.log2(max(overhead_us, 1.0) / REF_LAUNCH_OVERHEAD_US)
        out["min_depth"] = min(8, max(2, round(4 - shift)))
    rho = _stream_ratio(t)
    if rho is not None:
        from ..mining.stream import DEFAULT_STREAM_THRESHOLD_BYTES
        scaled = int(DEFAULT_STREAM_THRESHOLD_BYTES / (2 * max(rho, 0.25)))
        out["stream_threshold_bytes"] = min(
            2 * DEFAULT_STREAM_THRESHOLD_BYTES,
            max(DEFAULT_STREAM_THRESHOLD_BYTES // 2, scaled))
    return out
