from . import autotune
from .analysis import Roofline, analyze, collective_bytes, model_flops
from .autotune import (LaunchConfig, TuningTable, derived_chooser_thresholds,
                       resolve_launch_config, staleness_report)
from .kernel_model import (geometry_bucket, geometry_label, kernel_bytes,
                           kernel_flops, predicted_seconds, record_launch)
