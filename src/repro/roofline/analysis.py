"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch × shape × mesh) cell, all in seconds-per-step on the
TARGET hardware (TPU v5e-class constants; this container only compiles):

  compute    = HLO_FLOPs_per_device            / PEAK_FLOPS
  memory     = HLO_bytes_accessed_per_device   / HBM_BW
  collective = Σ_ops ring_bytes_on_wire(op)    / LINK_BW

``cost_analysis()`` of the SPMD-partitioned module is already per-device
(verified empirically).  Collective bytes are NOT in cost_analysis, so we
parse the post-partitioning HLO text: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute line carries the per-device
result shape and an iota ``replica_groups=[G,S]<=[N]`` (group size S); the
ring model converts result bytes to bytes-on-the-wire per device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# --- target hardware constants (TPU v5e-class, per chip) --------------------
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(segment: str, adjust_bf16_upcast: bool = False) -> int:
    """Sum byte sizes of all typed shapes in an HLO text segment.

    ``adjust_bf16_upcast``: XLA:CPU's float-normalization pass upcasts bf16
    compute (and therefore the collectives this container compiles) to f32;
    on the TPU target they stay bf16.  The jaxpr-level values are verified
    bf16, so f32 payloads are counted at 2 bytes/element under this flag.
    """
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        width = _DTYPE_BYTES[dt]
        if adjust_bf16_upcast and dt == "f32":
            width = 2
        total += n * width
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    result_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    wire_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def as_dict(self) -> dict:
        return {"counts": dict(self.counts),
                "result_bytes": dict(self.result_bytes),
                "wire_bytes": {k: float(v) for k, v in self.wire_bytes.items()},
                "total_wire_bytes": float(self.total_wire_bytes)}


def collective_bytes(hlo_text: str,
                     adjust_bf16_upcast: bool = True) -> CollectiveStats:
    """Per-device bytes-on-wire per collective kind (ring cost model)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-producing collective op lines look like:  %x = TYPE[...] all-reduce(...)
        m = re.match(r"%?[\w.\-]+ = (.+?) (" + "|".join(_COLLECTIVES) + r")\(",
                     stripped)
        if not m:
            continue
        result_seg, kind = m.group(1), m.group(2)
        # `-start` variants duplicate with `-done`; count starts only
        if stripped.startswith("%" ) and ("-done" in stripped.split("=")[0]):
            continue
        rbytes = _shape_bytes(result_seg, adjust_bf16_upcast=adjust_bf16_upcast)
        n = _group_size(stripped)
        if kind == "collective-permute":
            # pairwise op: identified by source_target_pairs, no replica_groups
            n = 2 if "source_target_pairs" in stripped else n
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * rbytes * frac
        elif kind == "all-gather":
            wire = rbytes * frac                  # result is the gathered (big) shape
        elif kind == "reduce-scatter":
            wire = rbytes * (n - 1)               # result is the scattered shard
        elif kind == "all-to-all":
            wire = rbytes * frac
        else:  # collective-permute
            wire = rbytes
        stats.counts[kind] += 1
        stats.result_bytes[kind] += rbytes
        stats.wire_bytes[kind] += wire
    return stats


@dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    wire_bytes: float            # per device
    collectives: CollectiveStats
    model_flops: float = 0.0     # analytic useful FLOPs per device
    n_devices: int = 1

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful FLOPs / (step_time * peak) — the MFU-at-roofline score."""
        t = self.step_time
        return self.model_flops / (t * PEAK_FLOPS) if t else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "wire_bytes_per_device": self.wire_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_per_device": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives.as_dict(),
        }


# XLA:CPU float-normalization upcasts bf16 HBM traffic to f32; the TPU target
# keeps bf16, so 'bytes accessed' from this container over-counts ~2x on
# bf16-dominant models.  Collectives are corrected per-op by dtype (above);
# the aggregate memory term uses this documented scalar.
MEM_BF16_UPCAST_ADJUST = 0.5


def analyze(compiled, model_flops_total: float, n_devices: int,
            mem_adjust: float = MEM_BF16_UPCAST_ADJUST) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    stats = collective_bytes(compiled.as_text())
    return Roofline(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)) * mem_adjust,
        wire_bytes=stats.total_wire_bytes,
        collectives=stats,
        model_flops=model_flops_total / n_devices,
        n_devices=n_devices,
    )


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6·N_active·D for training,
    2·N_active·D for prefill, 2·N_active·B per decoded token (+attention reads
    are bytes, not FLOPs — attention matmul FLOPs added explicitly)."""
    n_active = cfg.n_active_params()
    tokens = shape.seq_len * shape.global_batch
    # attention score+value matmul FLOPs (causal => /2)
    attn = 0.0
    n_attn_layers = sum(1 for i in range(cfg.n_layers)
                        if cfg.layer_kind(i) == "attn")
    if cfg.n_heads:
        h, dh = cfg.n_heads, cfg.d_head
        if shape.kind in ("train", "prefill"):
            attn = (2.0 * tokens * shape.seq_len * h * dh * 2 / 2) * n_attn_layers
        else:  # decode: 1 new token vs seq_len cache
            attn = (2.0 * shape.global_batch * shape.seq_len * h * dh * 2) * n_attn_layers
    if shape.kind == "train":
        return 6.0 * n_active * tokens + 3.0 * attn
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens + attn
    return 2.0 * n_active * shape.global_batch + attn
