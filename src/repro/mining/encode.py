"""Bitmap encoding of transaction databases — the TPU-native data layout.

The FP-tree's two benefits are (a) prefix compression (shared work across
transactions sharing prefixes) and (b) frequency-ordered arrangement.  On TPU
we realize the same benefits in a dense layout:

  * each transaction -> a packed row of ``W = ceil(M/32)`` uint32 words, items
    mapped to bit positions in support-DESCENDING order (same discipline as the
    FP-tree arrangement; makes equal-prefix rows byte-identical early, so the
    dedup below collapses exactly the paths an FP-tree would merge);
  * duplicate rows are collapsed into a single row with an integer weight
    (per class: an (U, C) weight matrix) — the FP-tree compression analogue;
  * column projection drops items absent from the target set before any device
    work — the GFP-growth conditional-tree data reduction (#4) analogue.

All functions are host-side numpy (data-pipeline stage); the arrays they
produce are the device inputs of the counting kernel.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

import numpy as np

Item = Hashable


@dataclass(frozen=True)
class ItemVocab:
    """item -> bit column, support-descending (column 0 = most frequent)."""

    items: Tuple[Item, ...]

    @property
    def size(self) -> int:
        return len(self.items)

    @property
    def n_words(self) -> int:
        return max(1, (len(self.items) + 31) // 32)

    def col(self, item: Item) -> int:
        return self._index()[item]

    def _index(self) -> Dict[Item, int]:
        idx = getattr(self, "_idx", None)
        if idx is None:
            idx = {a: i for i, a in enumerate(self.items)}
            object.__setattr__(self, "_idx", idx)
        return idx

    def __contains__(self, item: Item) -> bool:
        return item in self._index()

    @staticmethod
    def from_transactions(
        transactions: Iterable[Sequence[Item]],
        min_count: int = 1,
        counts: Optional[Dict[Item, int]] = None,
    ) -> "ItemVocab":
        if counts is None:
            counts = {}
            for t in transactions:
                for a in set(t):
                    counts[a] = counts.get(a, 0) + 1
        items = [a for a, c in counts.items() if c >= min_count]
        items.sort(key=lambda a: (-counts[a], repr(a)))
        return ItemVocab(tuple(items))


def encode_bitmap(
    transactions: Sequence[Sequence[Item]],
    vocab: ItemVocab,
) -> np.ndarray:
    """-> (N, W) uint32 packed bitmap (items outside vocab are dropped)."""
    n = len(transactions)
    w = vocab.n_words
    out = np.zeros((n, w), dtype=np.uint32)
    idx = vocab._index()
    for i, t in enumerate(transactions):
        for a in set(t):
            c = idx.get(a)
            if c is not None:
                out[i, c >> 5] |= np.uint32(1) << np.uint32(c & 31)
    return out


def encode_targets(
    itemsets: Sequence[Sequence[Item]],
    vocab: ItemVocab,
) -> np.ndarray:
    """-> (K, W) uint32 target masks.  Raises if a target item is outside the
    vocab (the TIS-tree 'does not need to include itemsets ... containing items
    which do not appear in the FP-tree'; callers filter first)."""
    k = len(itemsets)
    w = vocab.n_words
    out = np.zeros((k, w), dtype=np.uint32)
    idx = vocab._index()
    for i, s in enumerate(itemsets):
        for a in set(s):
            c = idx[a]
            out[i, c >> 5] |= np.uint32(1) << np.uint32(c & 31)
    return out


def dedup_rows(
    bits: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """FP-compression analogue: collapse identical rows, summing weights.

    bits: (N, W) uint32;  weights: (N, C) int — defaults to ones (C=1).
    -> (unique_bits (U, W), weights (U, C) int32)
    """
    n = bits.shape[0]
    if weights is None:
        weights = np.ones((n, 1), dtype=np.int32)
    if weights.ndim == 1:
        weights = weights[:, None]
    uniq, inv = np.unique(bits, axis=0, return_inverse=True)
    agg = np.zeros((uniq.shape[0], weights.shape[1]), dtype=np.int64)
    np.add.at(agg, inv.reshape(-1), weights)
    if np.any(agg > np.iinfo(np.int32).max):
        raise OverflowError("per-row class weights exceed int32")
    return uniq.astype(np.uint32), agg.astype(np.int32)


def class_weights(classes: Sequence[int], n_classes: int = 2) -> np.ndarray:
    """One-hot (N, C) int32 class indicator — the multi-class counter columns
    (paper §4.1: 'per class counters on each node of a single tree')."""
    y = np.asarray(classes, dtype=np.int64)
    if y.min() < 0 or y.max() >= n_classes:
        raise ValueError("class id out of range")
    out = np.zeros((y.shape[0], n_classes), dtype=np.int32)
    out[np.arange(y.shape[0]), y] = 1
    return out


def project_columns(
    bits: np.ndarray,
    vocab: ItemVocab,
    keep_items: Sequence[Item],
) -> Tuple[np.ndarray, ItemVocab]:
    """GFP data-reduction (#4) analogue: repack keeping only ``keep_items``.

    Preserves the relative (support-descending) order of the kept items.
    -> (projected (N, W') uint32, sub-vocab)
    """
    keep = [a for a in vocab.items if a in set(keep_items)]
    sub = ItemVocab(tuple(keep))
    cols = np.array([vocab.col(a) for a in keep], dtype=np.int64)
    n = bits.shape[0]
    out = np.zeros((n, sub.n_words), dtype=np.uint32)
    for new_c, old_c in enumerate(cols):
        bit = (bits[:, old_c >> 5] >> np.uint32(old_c & 31)) & np.uint32(1)
        out[:, new_c >> 5] |= bit.astype(np.uint32) << np.uint32(new_c & 31)
    return out, sub


def pad_words(bits: np.ndarray, n_words: int) -> np.ndarray:
    """Zero-extend packed rows (N, W) -> (N, n_words).

    A tail-extended vocab (``extend_vocab``) only APPENDS bit columns, so rows
    encoded under the old vocab stay valid at the new width with zero bits in
    the new columns — this is the re-encode-free append path of the serving
    store."""
    w = bits.shape[1]
    if w == n_words:
        return bits
    if w > n_words:
        raise ValueError(f"cannot shrink packed rows from {w} to {n_words} words")
    out = np.zeros((bits.shape[0], n_words), dtype=np.uint32)
    out[:, :w] = bits
    return out


def extend_vocab(
    transactions: Sequence[Sequence[Item]],
    vocab: ItemVocab,
) -> ItemVocab:
    """Tail-extend ``vocab`` with items unseen so far (incremental appends).

    Existing items keep their bit columns (already-encoded rows stay valid —
    see ``pad_words``); new items are appended batch-frequency-descending,
    mirroring the ``IncrementalMiner`` tail extension of its ``ItemOrder``.
    Returns ``vocab`` itself when the batch introduces nothing new.
    """
    counts: Dict[Item, int] = {}
    for t in transactions:
        for a in set(t):
            if a not in vocab:
                counts[a] = counts.get(a, 0) + 1
    if not counts:
        return vocab
    new = sorted(counts, key=lambda a: (-counts[a], repr(a)))
    return ItemVocab(vocab.items + tuple(new))


def decode_row(row: np.ndarray, vocab: ItemVocab) -> List[Item]:
    """Inverse of encode for tests/debug."""
    out: List[Item] = []
    for c, a in enumerate(vocab.items):
        if (int(row[c >> 5]) >> (c & 31)) & 1:
            out.append(a)
    return out
