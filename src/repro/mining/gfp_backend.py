"""Device-hybrid GFP-growth counting backend — conditional-pattern-base
counting over the encoded bitmap, batched per tree item.

The level-wise engines pay one kernel launch per candidate level: every
level's (K, W) target block sweeps ALL rows of the DB.  The paper's
GFP-growth (Algorithm 3.1) instead walks a guided FP-tree: each target
itemset is counted against the (much smaller) conditional pattern base of its
deepest item.  This module realizes that walk on the bitmap layout:

  * the support-descending bitmap IS the FP-tree analogue (``encode.py``):
    dedup = prefix compression, column rank = arrangement order.  The
    conditional pattern base of item ``a`` is derived directly — rows with
    bit ``a`` set, masked to the prefix columns ``0..rank(a)`` (items at or
    above ``a`` in the arrangement order), re-deduped.  Counting any itemset
    whose deepest-rank ("tail") item is ``a`` against that block yields its
    exact full-DB count: bits deeper than the tail can never occur in the
    mask, so the projection drops nothing the containment test reads.
  * ``counts(masks)`` groups the target block by tail item and flushes each
    group as ONE conditional block — all of one tree item's conditional
    counting in a single launch, instead of the whole DB once per level.
    Guided data reduction (paper optimization #4) additionally projects the
    block to the union of the group's masks and re-dedups before counting.
  * each flushed block is counted on the HOST (vectorized containment over
    the deduped block) when it has at most ``host_rows`` rows, and through
    the Pallas ``itemset_counts`` kernel otherwise — the hybrid: small
    conditional bases never pay launch overhead, large ones keep the device.

Exactness: every path is integer arithmetic over the same per-class weights
the dense kernel sums — dedup aggregation, prefix projection, and host/device
containment all commute with the int32 count, so ``GFPBackend.counts`` is
bit-identical to ``DenseBackend.counts`` and to the host ``core/gfp.py``
g-counts (the differential battery in ``tests/test_gfp_backend.py`` pins all
three against each other).

Driver integration: flush groups are the backend's count CHUNKS — one chunk
per distinct tail item (the empty mask, if present, is its own leading
chunk), in deterministic ascending-rank order.  ``chunk_signature`` /
``mine_signature`` are wired so the unified driver's ``MiningCheckpoint``
kill/resume (``mining/driver.py``) works unchanged: a killed mine resumes
mid-FLUSH, skipping every conditional block already counted, and a
``from_store`` backend pins the store version so a resume across an append
discards the stale state wholesale.
"""
from __future__ import annotations

from typing import Dict, Hashable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels.itemset_count import itemset_counts
from ..obs import REGISTRY, TRACER
from .backend import CountBackend
from .encode import ItemVocab, dedup_rows, encode_targets, pad_words

Item = Hashable

# hybrid dispatch ledger: which path counted each flushed conditional block
# (obs.summary_line reads the host label), and CPB cache effectiveness
_M_BLOCKS_HOST = REGISTRY.counter("gfp_blocks_total", path="host")
_M_BLOCKS_KERNEL = REGISTRY.counter("gfp_blocks_total", path="kernel")
_M_BLOCKS_EMPTY = REGISTRY.counter("gfp_blocks_total", path="empty")
_M_CPB_BUILDS = REGISTRY.counter("gfp_cpb_builds_total")
_M_CPB_REUSES = REGISTRY.counter("gfp_cpb_reuses_total")

# Conditional blocks at or under this many deduped rows are counted on the
# host (vectorized containment); larger blocks go through the kernel.  The
# crossover favors the host generously: a kernel launch over a few thousand
# rows costs more in dispatch than the numpy sweep does in arithmetic.
# ``host_rows=None`` derives the crossover from the active tuning table's
# measured launch cost (``roofline.autotune.derived_chooser_thresholds``).
DEFAULT_HOST_BLOCK_ROWS = 4096


def _resolve_host_rows(host_rows):
    if host_rows is not None:
        return int(host_rows)
    from ..roofline import autotune
    derived = autotune.derived_chooser_thresholds()
    return int(derived.get("gfp_host_rows", DEFAULT_HOST_BLOCK_ROWS))

# Host containment slab budget (bytes of the (slab, P, W) uint32 broadcast).
_HOST_SLAB_BYTES = 8 << 20


def _prefix_mask(col: int, n_words: int) -> np.ndarray:
    """(W,) uint32 mask selecting bit columns ``0..col`` inclusive."""
    out = np.zeros(n_words, np.uint32)
    full, rem = divmod(col + 1, 32)
    out[:full] = np.uint32(0xFFFFFFFF)
    if rem:
        out[full] = np.uint32((1 << rem) - 1)
    return out


def _tail_columns(masks: np.ndarray) -> np.ndarray:
    """Per-mask index of the highest set bit column (-1 for the empty mask).

    The highest set column is the target's deepest-rank (least-frequent)
    item — the FP-tree item whose conditional pattern base decides the
    target's count."""
    k, w = masks.shape
    tails = np.full(k, -1, np.int64)
    for wi in range(w):
        v = masks[:, wi]
        nz = v != 0
        if not nz.any():
            continue
        # frexp is exact on uint32 values: v in [2**(e-1), 2**e)
        e = np.frexp(v.astype(np.float64))[1].astype(np.int64)
        tails[nz] = 32 * wi + e[nz] - 1
    return tails


class GFPBackend(CountBackend):
    """Guided FP-growth hybrid :class:`CountBackend` (see module docstring).

    Counters: ``kernel_launches`` (device flushes), ``host_blocks`` (host-
    counted flushes), ``blocks_counted`` (total flush groups processed) —
    the kill/resume tests and ``benchmarks/gfp_hybrid.py`` read these.
    """

    def __init__(self, db, *, use_kernel: bool = True,
                 host_rows: Optional[int] = None,
                 guide: bool = True):
        self._setup(db.vocab, np.asarray(db.bits), np.asarray(db.weights),
                    int(db.n_rows), int(db.n_classes),
                    use_kernel=use_kernel, host_rows=host_rows, guide=guide)

    @classmethod
    def from_arrays(cls, vocab: ItemVocab, bits, weights, n_rows: int,
                    n_classes: int, **kw) -> "GFPBackend":
        self = cls.__new__(cls)
        self._setup(vocab, np.asarray(bits), np.asarray(weights),
                    int(n_rows), int(n_classes), **kw)
        return self

    @classmethod
    def from_store(cls, store, **kw) -> "GFPBackend":
        """Materialize the hybrid backend from a serving ``VersionedDB``:
        base + delta rows at the current vocab width, re-deduped — the same
        composed history the store's own sweep counts.  The
        ``mine_signature`` pins the store ``version``, so a checkpoint
        resumed after an ``append`` is discarded wholesale."""
        w_now = store.vocab.n_words
        bits = pad_words(np.asarray(store.base.bits), w_now)
        wts = np.asarray(store.base.weights)
        if store._delta_bits is not None:
            bits = np.concatenate([bits, pad_words(store._delta_bits, w_now)])
            wts = np.concatenate([wts, store._delta_weights])
        if bits.shape[0]:
            bits, wts = dedup_rows(bits, wts)
        return cls.from_arrays(
            store.vocab, bits, wts, store.n_rows, store.n_classes,
            mine_sig={"engine": "gfp", "version": store.version}, **kw)

    def _setup(self, vocab, bits, weights, n_rows, n_classes, *,
               use_kernel=True, host_rows=None, guide=True, mine_sig=None):
        self.vocab = vocab
        self.bits = np.ascontiguousarray(bits, np.uint32)
        self.weights = np.ascontiguousarray(weights, np.int32)
        self.n_rows = n_rows
        self.n_classes = n_classes
        self.use_kernel = use_kernel
        self.host_rows = _resolve_host_rows(host_rows)
        self.guide = bool(guide)
        self._mine_sig = dict(mine_sig or {})
        totals = (self.weights.sum(axis=0, dtype=np.int64)
                  if self.bits.shape[0] else np.zeros(n_classes, np.int64))
        # the empty-mask chunk answers with these totals, and every count is
        # bounded by them: int32 must hold them (same guard as streaming)
        if np.any(totals > np.iinfo(np.int32).max):
            raise OverflowError(
                "per-class weight totals exceed int32; counts could wrap — "
                "split the DB")
        self._class_totals = totals.astype(np.int32)
        self._cpb: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self.kernel_launches = 0
        self.host_blocks = 0
        self.blocks_counted = 0

    # -- protocol -------------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(self.bits.nbytes + self.weights.nbytes)

    @property
    def n_count_chunks(self) -> int:
        # upper bound on a call's flush-group count: one group per vocab item
        # plus the empty-mask group.  A given call's chunk grid is the set of
        # DISTINCT tail items among its masks in ascending-rank order —
        # deterministic from the masks, so the driver's mid-level resume
        # (same itemsets + signature => start_chunk) replays it exactly.
        return self.vocab.size + 1

    def chunk_signature(self) -> dict:
        return {"backend": "gfp", "n_rows": int(self.bits.shape[0]),
                "guide": self.guide}

    def mine_signature(self) -> dict:
        return dict(self._mine_sig)

    def traits(self):
        from .chooser import DatasetTraits
        return DatasetTraits.measure(self.bits, self.weights, self.vocab,
                                     self.n_rows)

    def item_counts(self) -> np.ndarray:
        """Level-1 shortcut: host column sums (paper optimization #2's O(1)
        header consult, bitmap form) — zero launches for the singles pass."""
        rows = np.zeros((self.vocab.size, self.n_classes), np.int64)
        for c in range(self.vocab.size):
            bit = (self.bits[:, c >> 5] >> np.uint32(c & 31)) & 1
            rows[c] = (bit[:, None] * self.weights).sum(axis=0)
        return rows

    def counts(self, masks, *, start_chunk=0, init=None, on_chunk=None):
        masks = np.ascontiguousarray(np.asarray(masks), np.uint32)
        k = int(masks.shape[0])
        acc = (np.zeros((k, self.n_classes), np.int32) if init is None
               else np.array(np.asarray(init), np.int32))
        if k == 0:
            return acc
        groups = self._flush_groups(masks)
        with TRACER.span("gfp.counts",
                         {"n_masks": k, "n_groups": len(groups),
                          "start_chunk": start_chunk}):
            for j in range(start_chunk, len(groups)):
                tail, idx = groups[j]
                acc[idx] += self._count_group(tail, masks[idx])
                self.blocks_counted += 1
                if on_chunk is not None:
                    on_chunk(j, acc)
        return acc

    # -- the guided flush -----------------------------------------------------
    def _flush_groups(self, masks):
        """[(tail_col, mask_row_indices)] in deterministic ascending-rank
        order; np.unique sorts, so an empty-mask group (-1) leads."""
        tails = _tail_columns(masks)
        return [(int(t), np.flatnonzero(tails == t)) for t in np.unique(tails)]

    def _conditional_block(self, col: int):
        """Conditional pattern base of the item at bit column ``col``: rows
        containing it, projected to the prefix columns ``0..col``, re-deduped
        (the FP-tree prefix-path extraction, bitmap form).  Cached per item —
        every mining level with this tail reuses the same block."""
        blk = self._cpb.get(col)
        if blk is None:
            _M_CPB_BUILDS.inc()
            bit = (self.bits[:, col >> 5] >> np.uint32(col & 31)) & np.uint32(1)
            sel = bit.astype(bool)
            rows = self.bits[sel] & _prefix_mask(col, self.bits.shape[1])
            wts = self.weights[sel]
            if rows.shape[0]:
                rows, wts = dedup_rows(rows, wts)
            blk = (rows, wts)
            self._cpb[col] = blk
        else:
            _M_CPB_REUSES.inc()
        return blk

    def _count_group(self, tail: int, gmasks: np.ndarray) -> np.ndarray:
        kg = gmasks.shape[0]
        if tail < 0:
            # the empty itemset is contained in every row
            _M_BLOCKS_EMPTY.inc()
            return np.broadcast_to(self._class_totals,
                                   (kg, self.n_classes))
        rows, wts = self._conditional_block(tail)
        if self.guide and rows.shape[0]:
            # guided data reduction (#4): project the block to the union of
            # this group's target bits (the tail bit is in every mask, so it
            # survives) and re-dedup — fewer distinct conditional paths
            union = np.bitwise_or.reduce(gmasks, axis=0)
            rows, wts = dedup_rows(rows & union, wts)
        p = rows.shape[0]
        if p == 0:
            _M_BLOCKS_EMPTY.inc()
            return np.zeros((kg, self.n_classes), np.int32)
        if p <= self.host_rows:
            self.host_blocks += 1
            _M_BLOCKS_HOST.inc()
            return self._host_count(rows, wts, gmasks)
        self.kernel_launches += 1
        _M_BLOCKS_KERNEL.inc()
        return np.asarray(itemset_counts(
            jnp.asarray(rows), jnp.asarray(gmasks), jnp.asarray(wts),
            use_kernel=self.use_kernel))

    def _host_count(self, rows, wts, gmasks) -> np.ndarray:
        """Vectorized containment over a small deduped block — the same
        integers the kernel would produce, without a launch."""
        kg = gmasks.shape[0]
        p, w = rows.shape
        out = np.empty((kg, self.n_classes), np.int64)
        wts64 = wts.astype(np.int64)
        slab = max(1, _HOST_SLAB_BYTES // max(1, p * w * 4))
        for s in range(0, kg, slab):
            m = gmasks[s:s + slab]
            contain = ((rows[None, :, :] & m[:, None, :])
                       == m[:, None, :]).all(axis=2)
            out[s:s + slab] = contain.astype(np.int64) @ wts64
        return out.astype(np.int32)


def gfp_mine_frequent(
    db,                       # DenseDB | StreamingDB (host views are taken)
    min_count: float,
    *,
    class_column: Optional[int] = None,
    max_len: int = 0,
    use_kernel: bool = True,
    host_rows: Optional[int] = None,
    guide: bool = True,
    checkpoint=None,          # Optional[MiningCheckpoint]
    on_chunk=None,
) -> Dict[Tuple[Item, ...], int]:
    """Exact frequent-itemset mining through the GFP-hybrid backend — a shim
    over the unified driver (``mining/driver.py``), like every other engine
    entry point.  Kill/resume via ``checkpoint`` works at flush-group
    granularity: a restart skips every conditional block already counted."""
    from .driver import mine_frequent as _driver_mine

    backend = GFPBackend(db, use_kernel=use_kernel, host_rows=host_rows,
                         guide=guide)
    return _driver_mine(backend, min_count, class_column=class_column,
                        max_len=max_len, checkpoint=checkpoint,
                        on_chunk=on_chunk)


def gfp_multitude_counts(
    tis,                      # repro.core.TISTree
    db,                       # DenseDB | StreamingDB
    *,
    use_kernel: bool = True,
    host_rows: Optional[int] = None,
    guide: bool = True,
) -> Dict[Tuple[Item, ...], np.ndarray]:
    """The GFP-growth contract on the hybrid backend: {sorted-itemset-tuple
    -> (C,) int32 per-class counts} for every *target* node of the TIS-tree.
    Targets naming items absent from the DB vocab count exactly 0 (the
    paper's note that such targets never appear in the FP-tree) — the same
    unknown-item contract as ``dense_gfp_counts``."""
    targets, keys, zero_keys = [], [], []
    for node in tis.targets():
        itemset = node.itemset()
        key = tuple(sorted(itemset, key=repr))
        if all(a in db.vocab for a in itemset):
            targets.append(itemset)
            keys.append(key)
        else:
            zero_keys.append(key)
    out = {kk: np.zeros(db.n_classes, np.int32) for kk in zero_keys}
    if targets:
        backend = GFPBackend(db, use_kernel=use_kernel, host_rows=host_rows,
                             guide=guide)
        rows = backend.counts(encode_targets(targets, db.vocab))
        for key, row in zip(keys, rows):
            out[key] = row
    return out
