"""Distributed multitude-targeted mining — the GFP-growth engine on a mesh.

Parallel decomposition (maps the paper's workload to a (data, model) mesh):

  * transactions (N axis)  -> sharded over the 'data' mesh axis (and 'pod'):
    each device counts its local rows; ONE psum of the small (K_loc, C) count
    block per launch is the only communication — the dense analogue of
    "collecting counts from reduced conditional trees" with no tree traffic;
  * targets (K axis)       -> sharded over the 'model' mesh axis: devices hold
    disjoint target blocks, so the count matrix never materializes globally
    (multitude-targeted = K can be millions).

Scaling: work O(N·K·W / P) per device, comm O(K·C / model_size) per level —
independent of N.  At 1000+ nodes the N axis shards freely (transactions are
i.i.d. rows); elasticity = re-encode shard boundaries, nothing else changes.

Fault tolerance: level-synchronous mining checkpoints (level index + frequent
frontier + accumulated counts) via MiningCheckpoint — a restart (possibly on a
DIFFERENT mesh shape) resumes from the last completed level.
"""
from __future__ import annotations

import functools
import json
import os
import tempfile
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..kernels.itemset_count import itemset_counts
from .encode import ItemVocab, encode_targets

Item = Hashable


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.lru_cache(maxsize=None)
def _count_shard_fn(mesh: Mesh, data_axes: Tuple[str, ...],
                    model_axis: Optional[str], use_kernel: bool,
                    block_k: Optional[int] = None,
                    block_n: Optional[int] = None,
                    accum: Optional[str] = None):
    """Build (and cache) the jitted shard_map counting launch.

    Cached on (mesh, axes, use_kernel, launch config) so repeated launches —
    per mining level, and per chunk of a streaming sweep — reuse one
    executable per input shape instead of re-tracing a fresh closure every
    call.  The launch config is part of the cache key ON PURPOSE: callers
    resolve the tuning table eagerly and pass CONCRETE values, so a table
    swap retraces instead of silently reusing a stale config baked into a
    cached trace.
    """
    tx_spec = P(data_axes, None)
    tgt_spec = P(model_axis, None)
    w_spec = P(data_axes, None)
    out_spec = P(model_axis, None)

    @functools.partial(
        jax.jit,
        in_shardings=(NamedSharding(mesh, tx_spec), NamedSharding(mesh, tgt_spec),
                      NamedSharding(mesh, w_spec)),
        out_shardings=NamedSharding(mesh, out_spec),
    )
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(tx_spec, tgt_spec, w_spec), out_specs=out_spec,
        check_vma=False,  # pallas_call out_shape carries no vma annotation
    )
    def count_shard(tx, tgt, wts):
        local = itemset_counts(tx, tgt, wts, use_kernel=use_kernel,
                               block_k=block_k, block_n=block_n, accum=accum)
        return jax.lax.psum(local, data_axes)

    return count_shard


def _resolve_shard_config(n_local: int, k_local: int, w: int, c: int):
    """Per-DEVICE launch config for a sharded launch: the table is keyed on
    the geometry each device actually sees (its local row/target block), not
    the global problem."""
    from ..roofline import autotune
    cfg = autotune.resolve_launch_config(max(1, n_local), max(1, k_local),
                                         max(1, w), max(1, c))
    return cfg.block_k, cfg.block_n, cfg.accum


def distributed_counts(
    tx_bits: np.ndarray,      # (N, W) uint32 (host; will be sharded)
    tgt_bits: np.ndarray,     # (K, W) uint32
    weights: np.ndarray,      # (N, C) int32
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: Optional[str] = "model",
    use_kernel: bool = True,
    chunk_rows: Optional[int] = None,
    start_chunk: int = 0,
    init: Optional[np.ndarray] = None,
    on_chunk=None,
) -> np.ndarray:              # (K, C) int32
    """Exact counts on a mesh: N over data axes, K over the model axis.

    ``chunk_rows`` composes sharding-over-devices with streaming-within-
    device: the N axis is swept in host-side chunks (each chunk itself
    sharded over the data axes), so per-device residency is
    O(chunk_rows / data_size) regardless of total N.  Counts are int32 sums —
    the chunked sweep is bit-identical to the single pass.

    ``start_chunk`` / ``init`` / ``on_chunk`` follow the streaming resume
    discipline (``mining/stream.py``): ``on_chunk(j, acc)`` fires after
    chunk ``j`` with the running int32 accumulator, and a resumed sweep
    seeded with a checkpointed accumulator skips the chunks already counted
    — the driver's mid-level checkpoint hook, now available on a mesh.
    """
    k, w = tgt_bits.shape
    n, c = weights.shape
    # counts are bounded by the per-class weight-column sums; guard BEFORE any
    # device work — the kernel and psum run in int32 and would wrap silently
    if n and np.any(np.asarray(weights).sum(axis=0, dtype=np.int64)
                    > np.iinfo(np.int32).max):
        raise OverflowError("per-class weight totals exceed int32; counts "
                            "could wrap — split the DB")
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape[model_axis] if model_axis else 1
    k_pad = _round_up(max(k, 1), msize)
    tgt_p = np.zeros((k_pad, w), np.uint32)
    tgt_p[:k] = tgt_bits

    if chunk_rows is not None and 0 < chunk_rows < n:
        from .plan import stream_chunks
        # fixed chunk shape (zero-pad the ragged tail) and a single device
        # copy of the target block: one executable, one target upload
        n_pad = _round_up(chunk_rows, dsize)
        count_shard = _count_shard_fn(
            mesh, tuple(data_axes), model_axis, use_kernel,
            *_resolve_shard_config(n_pad // dsize, k_pad // msize, w, c))
        tgt_d = jnp.asarray(tgt_p)
        txc = np.zeros((n_pad, tx_bits.shape[1]), np.uint32)
        wc = np.zeros((n_pad, c), np.int32)
        total = (np.zeros((k, c), np.int64) if init is None
                 else np.asarray(init).astype(np.int64))
        chunks = stream_chunks(n, chunk_rows)
        if start_chunk >= len(chunks):
            return total.astype(np.int32)  # fully counted: resume is a no-op
        for j in range(start_chunk, len(chunks)):
            s, e = chunks[j]
            txc[: e - s] = tx_bits[s:e]
            txc[e - s:] = 0
            wc[: e - s] = weights[s:e]
            wc[e - s:] = 0
            # host int64 accumulation of the small (K, C) block (per-chunk
            # sync; the block is tiny).  The upfront weight-sum guard bounds
            # every count under int32, so the final cast cannot wrap.
            total += np.asarray(count_shard(jnp.asarray(txc), tgt_d,
                                            jnp.asarray(wc)))[:k]
            if on_chunk is not None:
                on_chunk(j, total.astype(np.int32))
        return total.astype(np.int32)

    base = (np.zeros((k, c), np.int32) if init is None
            else np.array(np.asarray(init), np.int32))
    if start_chunk >= 1:
        return base                        # single-chunk resume discipline
    n_pad = _round_up(max(n, 1), dsize)
    count_shard = _count_shard_fn(
        mesh, tuple(data_axes), model_axis, use_kernel,
        *_resolve_shard_config(n_pad // dsize, k_pad // msize, w, c))
    tx_p = np.zeros((n_pad, tx_bits.shape[1]), np.uint32)
    tx_p[:n] = tx_bits
    w_p = np.zeros((n_pad, c), np.int32)
    w_p[:n] = weights
    out = base + np.asarray(count_shard(jnp.asarray(tx_p), jnp.asarray(tgt_p),
                                        jnp.asarray(w_p)))[:k]
    if on_chunk is not None:
        on_chunk(0, out)
    return out


def place_rows(
    bits: np.ndarray,        # (N, W) uint32, host
    weights: np.ndarray,     # (N, C) int32, host
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
):
    """Row-shard an encoded DB over the mesh data axes ONCE, for reuse.

    Pads N to the data-axis multiple (zero rows count nothing) and
    ``device_put``s both arrays with the row-partitioned sharding that
    :func:`resident_distributed_counts` expects.  The serving hot path calls
    this once per store version and then answers every query against the
    resident placement — no per-query H2D sweep upload."""
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    n = int(bits.shape[0])
    n_pad = _round_up(max(n, 1), dsize)
    bp = np.zeros((n_pad, bits.shape[1]), np.uint32)
    bp[:n] = bits
    wp = np.zeros((n_pad, weights.shape[1]), np.int32)
    wp[:n] = weights
    sharding = NamedSharding(mesh, P(data_axes, None))
    return (jax.device_put(bp, sharding), jax.device_put(wp, sharding))


def resident_distributed_counts(
    tx_dev,                   # (N_pad, W) uint32, placed by place_rows
    tgt_bits: np.ndarray,     # (K, W) uint32, host
    w_dev,                    # (N_pad, C) int32, placed by place_rows
    mesh: Mesh,
    *,
    data_axes: Tuple[str, ...] = ("data",),
    model_axis: Optional[str] = None,
    use_kernel: bool = True,
) -> np.ndarray:              # (K, C) int32
    """:func:`distributed_counts` for a RESIDENT row placement: every device
    counts its local rows, one psum all-reduces the small (K, C) block.

    The transaction rows and weights stay on the mesh across calls (the
    serving analogue of the resident ``DenseDB``); only the target block is
    padded and uploaded per call.  The int32 overflow guard is the CALLER's
    contract — a serving store guards its per-class row totals on every
    append, before rows ever reach the placement."""
    k, w = tgt_bits.shape
    c = int(w_dev.shape[1])
    if k == 0:
        return np.zeros((0, c), np.int32)
    msize = mesh.shape[model_axis] if model_axis else 1
    k_pad = _round_up(k, msize)
    tgt_p = np.zeros((k_pad, w), np.uint32)
    tgt_p[:k] = tgt_bits
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    count_shard = _count_shard_fn(
        mesh, tuple(data_axes), model_axis, use_kernel,
        *_resolve_shard_config(int(tx_dev.shape[0]) // dsize,
                               k_pad // msize, w, c))
    out = np.asarray(count_shard(tx_dev, jnp.asarray(tgt_p), w_dev))
    return np.array(out[:k], np.int32)


@dataclass
class MiningCheckpoint:
    """Restartable state of a level-synchronous mine.

    ``level``/``frequent`` record the last COMPLETED level; the optional
    ``partial`` dict records an in-flight level of a streaming sweep
    ({level, itemsets, next_chunk, acc}) so a restart resumes mid-level from
    the last completed chunk (see ``mining/stream.py``).
    """
    path: str

    def save(self, level: int, frequent: Dict[Tuple[Item, ...], int],
             meta: Optional[dict] = None,
             partial: Optional[dict] = None) -> None:
        tmp = self.path + ".tmp"
        payload = {
            "level": level,
            "frequent": [[list(k), int(v)] for k, v in frequent.items()],
            "meta": meta or {},
            "partial": partial,
        }
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)  # atomic

    def load(self) -> Optional[Tuple[int, Dict[Tuple[Item, ...], int], dict]]:
        state = self.load_state()
        if state is None:
            return None
        return state["level"], state["frequent"], state["meta"]

    def load_state(self) -> Optional[dict]:
        """Full state incl. the mid-level ``partial`` record (or None).
        A missing or EMPTY file means no state: saves are atomic (write tmp
        + rename), so a 0-byte file can only be a pre-created placeholder
        (e.g. ``mkstemp``), never a torn write."""
        if not os.path.exists(self.path) or os.path.getsize(self.path) == 0:
            return None
        with open(self.path) as f:
            payload = json.load(f)
        freq = {tuple(k): v for k, v in payload["frequent"]}
        return {
            "level": payload["level"],
            "frequent": freq,
            "meta": payload.get("meta", {}),
            "partial": payload.get("partial"),
        }


class DistributedMiner:
    """Level-synchronous exact frequent-itemset mining over a mesh, with
    optional per-level checkpointing (fault tolerance) and elastic resume.

    ``chunk_rows`` enables the streaming composition: every counting launch
    sweeps the N axis in host chunks, each chunk sharded over the data axes
    (sharding-over-devices x streaming-within-device)."""

    def __init__(self, mesh: Mesh, *, data_axes: Tuple[str, ...] = ("data",),
                 model_axis: Optional[str] = "model", use_kernel: bool = True,
                 checkpoint: Optional[MiningCheckpoint] = None,
                 chunk_rows: Optional[int] = None):
        self.mesh = mesh
        self.data_axes = data_axes
        self.model_axis = model_axis
        self.use_kernel = use_kernel
        self.checkpoint = checkpoint
        self.chunk_rows = chunk_rows

    def counts(self, tx_bits, tgt_bits, weights) -> np.ndarray:
        return distributed_counts(
            tx_bits, tgt_bits, weights, self.mesh,
            data_axes=self.data_axes, model_axis=self.model_axis,
            use_kernel=self.use_kernel, chunk_rows=self.chunk_rows)

    def gfp_counts(
        self,
        tis,                       # repro.core.TISTree
        tx_bits: np.ndarray,
        weights: np.ndarray,
        vocab: ItemVocab,
    ) -> Dict[Tuple[Item, ...], np.ndarray]:
        """The GFP-growth contract, distributed: counts for all TIS targets."""
        targets, keys, zeros = [], [], []
        for node in tis.targets():
            itemset = node.itemset()
            key = tuple(sorted(itemset, key=repr))
            if all(a in vocab for a in itemset):
                targets.append(itemset)
                keys.append(key)
            else:
                zeros.append(key)
        out = {k: np.zeros(weights.shape[1], np.int32) for k in zeros}
        if targets:
            masks = encode_targets(targets, vocab)
            rows = self.counts(tx_bits, masks, weights)
            for key, row in zip(keys, rows):
                out[key] = row
        return out

    def backend(self, tx_bits: np.ndarray, weights: np.ndarray,
                vocab: ItemVocab):
        """The miner's :class:`~repro.mining.backend.DistributedBackend` over
        host arrays.  With ``chunk_rows`` active the backend exposes the
        N-axis sweep's chunk grid to the driver (one resumable chunk per
        host chunk), so a mesh mine checkpoints MID-level — the sharding
        composition's last gap."""
        from .backend import DistributedBackend
        from .plan import stream_chunks

        n = int(tx_bits.shape[0])
        nbytes = int(tx_bits.nbytes + weights.nbytes)
        if self.chunk_rows is not None and 0 < self.chunk_rows < n:
            return DistributedBackend(
                lambda masks, **kw: distributed_counts(
                    tx_bits, masks, weights, self.mesh,
                    data_axes=self.data_axes, model_axis=self.model_axis,
                    use_kernel=self.use_kernel, chunk_rows=self.chunk_rows,
                    **kw),
                vocab, n, int(weights.shape[1]), nbytes=nbytes,
                n_chunks=len(stream_chunks(n, self.chunk_rows)),
                chunk_rows=self.chunk_rows)
        return DistributedBackend(
            lambda masks: self.counts(tx_bits, masks, weights),
            vocab, n, int(weights.shape[1]), nbytes=nbytes)

    def mine_frequent(
        self,
        tx_bits: np.ndarray,
        weights: np.ndarray,
        vocab: ItemVocab,
        min_count: float,
        *,
        class_column: Optional[int] = None,
        max_len: int = 0,
        on_chunk=None,
    ) -> Dict[Tuple[Item, ...], int]:
        """Shim over the unified driver (``mining/driver.py``): one mesh
        counting launch per level (singles included), per-level checkpoint
        saves — plus the driver's mid-level partial at N-chunk granularity
        when ``chunk_rows`` is active, so a restart (possibly on a DIFFERENT
        mesh shape: the signature is mesh-independent) skips any counted
        level AND any counted chunk of the in-flight level."""
        from .driver import mine_frequent as _driver_mine

        backend = self.backend(tx_bits, weights, vocab)
        return _driver_mine(backend, min_count, class_column=class_column,
                            max_len=max_len, checkpoint=self.checkpoint,
                            on_chunk=on_chunk)
