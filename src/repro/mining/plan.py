"""TIS scheduling for the dense engine.

The paper's GFP-growth walks the TIS-tree depth-first, recursively; TPUs want
big homogeneous batches.  The schedule below converts the same TIS-tree into a
LEVEL-SYNCHRONOUS plan: level l holds the masks of all depth-(l+1) TIS nodes.
Correctness is unchanged (Theorem 1's argument is independent across siblings);
the guidance survives as:

  * only target-node masks are materialized at all (opt. #6: non-target
    internal prefixes get counted only when a min-support prune needs them);
  * levels allow early termination: children of below-threshold (or zero)
    parents are dropped host-side before their kernel launch — the dense
    analogue of the O(1) header consult + empty-conditional-tree check;
  * the union of live items per level drives column projection (opt. #4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.tis import TISNode, TISTree
from .encode import ItemVocab, encode_targets

Item = Hashable


@dataclass
class LevelPlan:
    """One TIS level: nodes + their (K, W) masks in a fixed row order."""
    nodes: List[TISNode]
    itemsets: List[Tuple[Item, ...]]
    masks: np.ndarray             # (K, W) uint32
    parent_rows: np.ndarray       # (K,) int32 row of parent in previous level (-1 = root child)
    is_target: np.ndarray         # (K,) bool


@dataclass
class TISSchedule:
    vocab: ItemVocab
    levels: List[LevelPlan]
    n_nodes: int

    @property
    def max_depth(self) -> int:
        return len(self.levels)


def build_schedule(tis: TISTree, vocab: ItemVocab) -> TISSchedule:
    """Flatten a TIS-tree into level-synchronous mask batches."""
    levels_nodes = tis.levels()
    levels: List[LevelPlan] = []
    prev_row: Dict[int, int] = {}  # id(node) -> row in previous level
    n_nodes = 0
    for depth, nodes in enumerate(levels_nodes):
        itemsets = [n.itemset() for n in nodes]
        masks = encode_targets(itemsets, vocab)
        parent_rows = np.full(len(nodes), -1, dtype=np.int32)
        if depth > 0:
            for i, n in enumerate(nodes):
                parent_rows[i] = prev_row[id(n.parent)]
        is_target = np.array([n.target for n in nodes], dtype=bool)
        levels.append(LevelPlan(list(nodes), itemsets, masks, parent_rows, is_target))
        prev_row = {id(n): i for i, n in enumerate(nodes)}
        n_nodes += len(nodes)
    return TISSchedule(vocab=vocab, levels=levels, n_nodes=n_nodes)


# --------------------------------------------------------------------------
# Streaming chunk planning (the out-of-core N axis).
#
# The counting kernel is oblivious to N-chunking: counts are int32 sums, so a
# sweep over row-chunks accumulated on device is bit-identical to one pass.
# The planner only decides WHERE to cut: chunk_rows from a host->device
# staging budget (two in-flight buffers of bits+weights), aligned to the
# kernel's N-block so chunk boundaries never add padding work.
# --------------------------------------------------------------------------

DEFAULT_STREAM_BUDGET_BYTES = 64 << 20   # per staging buffer (x2 in flight)


def choose_chunk_rows(n_words: int, n_classes: int, *,
                      budget_bytes: int = DEFAULT_STREAM_BUDGET_BYTES,
                      align: int = 1024,
                      n_rows: Optional[int] = None) -> int:
    """Rows per streamed chunk so one buffer (bits + weights) fits the budget.

    When the caller knows the total row count (``n_rows``), the active tuning
    table gets first say: a sweep-measured ``chunk_rows`` for this geometry
    bucket overrides the staging-budget heuristic (aligned to the kernel's
    N-block so chunk boundaries never add padding work).

    Either source is CLAMPED to the align-rounded row count: a tuned entry
    measured on a bigger bucket must not hand a 2k-row DB a 16384-row chunk
    shape — the sweep would zero-pad the single ragged chunk up to the full
    chunk and burn 8x the kernel work on rows that count nothing."""
    cap = None
    if n_rows is not None and n_rows > 0:
        cap = max(align, -(-int(n_rows) // align) * align)
        from ..roofline import autotune
        tuned = autotune.resolve_launch_config(
            n_rows, autotune.DEFAULT_BLOCK_K, n_words, n_classes).chunk_rows
        if tuned is not None and tuned > 0:
            return min(cap, max(align, (int(tuned) // align) * align))
    row_bytes = 4 * (max(1, n_words) + max(1, n_classes))
    rows = budget_bytes // row_bytes
    rows = max(align, (rows // align) * align)
    return rows if cap is None else min(cap, rows)


def stream_chunks(n_rows: int, chunk_rows: int) -> List[Tuple[int, int]]:
    """(start, stop) spans covering [0, n_rows); the last may be ragged."""
    if n_rows <= 0:
        return []
    if chunk_rows <= 0:
        raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
    return [(s, min(s + chunk_rows, n_rows))
            for s in range(0, n_rows, chunk_rows)]


def canonical_itemsets(cands) -> List[Tuple[Item, ...]]:
    """Frozenset candidates -> repr-sorted tuples in a deterministic list
    order — the repo-wide canonical level layout (checkpoint partials store
    this exact list, so resume can regenerate and compare it)."""
    return [tuple(sorted(s, key=repr)) for s in cands]


def live_items(level: LevelPlan, vocab: ItemVocab) -> List[Item]:
    """Union of items appearing in a level's masks (column-projection driver)."""
    union = np.zeros(level.masks.shape[1], dtype=np.uint32)
    for w in range(level.masks.shape[1]):
        union[w] = np.bitwise_or.reduce(level.masks[:, w]) if level.masks.shape[0] else 0
    out = []
    for c, a in enumerate(vocab.items):
        if (int(union[c >> 5]) >> (c & 31)) & 1:
            out.append(a)
    return out
