# TPU-native multitude-targeted mining engine (the GFP-growth hardware
# adaptation): bitmap encoding, TIS level scheduling, dense counting engine,
# the streaming out-of-core engine, the shard_map-distributed runtime, the
# guided FP-growth device hybrid, the adaptive backend chooser, and the
# CountBackend protocol + unified level-wise driver they all share.
from .encode import (ItemVocab, class_weights, dedup_rows, decode_row,
                     encode_bitmap, encode_targets, extend_vocab, pad_words,
                     project_columns)
from .backend import (CountBackend, DenseBackend, DistributedBackend,
                      StreamingBackend)
from .chooser import (BackendChoice, DatasetTraits, backend_for_db,
                      choose_backend)
from .dense import (DenseDB, DenseMRAResult, dense_gfp_counts,
                    dense_mine_frequent, minority_report_dense)
from .driver import mine_frequent as mine_frequent_backend
from .gfp_backend import GFPBackend, gfp_mine_frequent, gfp_multitude_counts
from .plan import (TISSchedule, build_schedule, canonical_itemsets,
                   choose_chunk_rows, live_items, stream_chunks)
from .spill import (SpilledBackend, SpilledDB, default_spill_dir,
                    spilled_counts)
from .stream import (StreamingDB, streaming_counts, streaming_mine_frequent)
