# TPU-native multitude-targeted mining engine (the GFP-growth hardware
# adaptation): bitmap encoding, TIS level scheduling, dense counting engine,
# the streaming out-of-core engine, and the shard_map-distributed runtime.
from .encode import (ItemVocab, class_weights, dedup_rows, decode_row,
                     encode_bitmap, encode_targets, extend_vocab, pad_words,
                     project_columns)
from .dense import (DenseDB, DenseMRAResult, dense_gfp_counts,
                    dense_mine_frequent, minority_report_dense)
from .plan import (TISSchedule, build_schedule, choose_chunk_rows, live_items,
                   stream_chunks)
from .stream import (StreamingDB, streaming_counts, streaming_mine_frequent)
