"""The ONE level-wise mining loop, parameterized on a :class:`CountBackend`.

Every engine entry point (``dense_mine_frequent``, ``streaming_mine_frequent``,
``DistributedMiner.mine_frequent``, ``serve.versioned_mine_frequent`` /
``CountServer.mine``) is a thin shim over :func:`mine_frequent` below: the
driver owns candidate generation (``apriori_gen`` + canonical ordering),
threshold absorption, the level-1 singles pass (with the dense column-sum
shortcut when the backend offers one), and ``MiningCheckpoint`` save/load —
including the MID-LEVEL partial state generalized from the streaming engine,
so kill/resume works on every backend at that backend's chunk granularity.

The paper-faithful host baselines (``core.apriori``, ``core.apriori_gfp``)
deliberately keep their own independent loops: they are the oracles the
engine parity tests validate this driver against.

Checkpoint format (shared with the pre-driver streaming engine, forward and
backward compatible):

  * completed levels: ``{level, frequent, meta}`` where ``meta`` carries the
    backend's ``mine_signature()`` — a mismatch on load discards the whole
    state (e.g. a ``VersionedDB`` resume across an ``append``);
  * mid-level partial: ``{level, itemsets, next_chunk, acc}`` merged with the
    backend's ``chunk_signature()`` — resumed only when the signature AND the
    regenerated candidate list match, else the level restarts from chunk 0.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from ..obs import REGISTRY, TRACER
from .backend import CountBackend
from .encode import encode_targets
from .plan import canonical_itemsets

Item = Hashable
Key = Tuple[Item, ...]

_M_LEVELS = REGISTRY.counter("mine_levels_total")
_M_CANDIDATES = REGISTRY.counter("mine_candidates_total")
_M_FREQUENT = REGISTRY.counter("mine_frequent_total")
_M_CHUNKS = REGISTRY.counter("mine_chunks_total")


def mine_frequent(
    backend: CountBackend,
    min_count: float,
    *,
    class_column: Optional[int] = None,
    max_len: int = 0,
    checkpoint=None,                 # Optional[MiningCheckpoint]
    on_level: Optional[Callable[[int, int, int], None]] = None,
    on_chunk: Optional[Callable[[int, int], None]] = None,
    level1_shortcut: Optional[bool] = None,
) -> Dict[Key, int]:
    """Exact level-synchronous frequent-itemset mining over any backend.

    Returns ``{sorted-itemset-tuple -> count}`` with ``count >= min_count``
    (``class_column`` restricts support to one weight column; ``max_len``
    caps the itemset length; 0 = unbounded).  The threshold comparison is
    ``count >= min_count`` with ``min_count`` as given — use
    ``repro.core.incremental.ceil_count(theta * n)`` to turn a relative
    threshold into a count.

    With a ``checkpoint``, progress is durable at the backend's chunk
    granularity: each completed level is saved, and each completed chunk of
    an in-flight level saves a partial ``(itemsets, next_chunk, accumulator)``
    record, so a killed mine resumes mid-level — on a multi-chunk backend
    from the last completed chunk, on a single-chunk backend by skipping any
    fully-counted level.  Hooks: ``on_chunk(level, chunk_idx)`` after each
    chunk's (durable) save, ``on_level(level, n_candidates, n_frequent)``
    after each level's absorb.  ``level1_shortcut`` controls the backend's
    ``item_counts`` fast path for singles (None = use it when available).
    """
    out: Dict[Key, int] = {}
    partial: Optional[dict] = None
    level = 0
    # the checkpoint identity is the backend state AND the mining parameters:
    # a saved total-count mine must not answer a class-guided resume (or a
    # different threshold/cap) at the same store version — the absorbed
    # levels would be silently wrong for the new query
    msig = dict(backend.mine_signature())
    msig.update(min_count=float(min_count), class_column=class_column,
                max_len=max_len)
    if checkpoint is not None:
        state = checkpoint.load_state()
        if state is not None and all(
                state.get("meta", {}).get(k) == v for k, v in msig.items()):
            level = int(state["level"])
            out = dict(state["frequent"])
            partial = state.get("partial")

    csig = backend.chunk_signature()

    def _count_level(itemsets: List[Key], lvl: int) -> np.ndarray:
        nonlocal partial
        masks = encode_targets(itemsets, backend.vocab)
        # JSON-stable level identity; only materialized when durability or
        # progress hooks are in play (the hot path skips it)
        wire = ([list(t) for t in itemsets]
                if (checkpoint is not None or partial) else None)
        start, init = 0, None
        if (partial and partial.get("level") == lvl
                and partial.get("itemsets") == wire
                and all(partial.get(k) == v for k, v in csig.items())):
            start = int(partial["next_chunk"])
            init = np.asarray(partial["acc"], np.int32)
        partial = None

        def _ckpt(j: int, acc) -> None:
            if checkpoint is not None:
                checkpoint.save(lvl - 1, out, meta=msig, partial={
                    "level": lvl, "itemsets": wire, "next_chunk": j + 1,
                    "acc": np.asarray(acc).tolist(), **csig,
                })
            if on_chunk is not None:  # after the save: a crash resumes at j+1
                on_chunk(lvl, j)

        hook = _ckpt if (checkpoint is not None or on_chunk is not None) \
            else None
        # chunk accounting without forcing the hook on (the hot path skips
        # the per-chunk callback entirely): the sweep covers exactly the
        # chunks from the resume point to the end of the grid
        _M_CHUNKS.inc(backend.n_count_chunks - start)
        with TRACER.span("mine.level",
                         {"level": lvl, "n_candidates": len(itemsets),
                          "start_chunk": start}):
            return np.asarray(backend.counts(masks, start_chunk=start,
                                             init=init, on_chunk=hook))

    def _absorb(itemsets: List[Key], rows: np.ndarray) -> set:
        frequent = set()
        for itemset, row in zip(itemsets, rows):
            cnt = (int(row.sum()) if class_column is None
                   else int(row[class_column]))
            if cnt >= min_count:
                frequent.add(frozenset(itemset))
                out[itemset] = cnt
        return frequent

    if level == 0:
        singles: List[Key] = [(a,) for a in backend.vocab.items]
        frequent: set = set()
        if singles:
            shortcut = (backend.item_counts()
                        if level1_shortcut is not False else None)
            if level1_shortcut is True and shortcut is None:
                raise ValueError("backend has no level-1 item_counts shortcut")
            rows = shortcut if shortcut is not None \
                else _count_level(singles, 1)
            frequent = _absorb(singles, rows)
        level = 1
        _M_LEVELS.inc()
        _M_CANDIDATES.inc(len(singles))
        _M_FREQUENT.inc(len(frequent))
        if checkpoint is not None:
            checkpoint.save(level, out, meta=msig)
        if on_level is not None:
            on_level(1, len(singles), len(frequent))
    else:
        frequent = {frozenset(t) for t in out if len(t) == level}

    from ..core.apriori import apriori_gen

    while frequent and (max_len == 0 or level < max_len):
        itemsets = canonical_itemsets(apriori_gen(frequent, level))
        if not itemsets:
            break
        rows = _count_level(itemsets, level + 1)
        frequent = _absorb(itemsets, rows)
        level += 1
        _M_LEVELS.inc()
        _M_CANDIDATES.inc(len(itemsets))
        _M_FREQUENT.inc(len(frequent))
        if checkpoint is not None:
            checkpoint.save(level, out, meta=msig)
        if on_level is not None:
            on_level(level, len(itemsets), len(frequent))
    return out
