"""Dense (TPU-native) GFP-growth engine and dense Minority-Report.

Three entry points:

  * ``dense_gfp_counts``     — the GFP-growth contract: given a TIS-tree and an
    encoded database, return the exact count of every target (per class).
    One fused kernel pass over a column-projected, deduped bitmap.
  * ``dense_mine_frequent``  — level-synchronous frequent-itemset mining on the
    device (Apriori-shaped candidate levels, kernel counting, host pruning);
    used for antecedent discovery on the (small) rare class.
  * ``minority_report_dense``— the MRA pipeline on the dense engine: one fused
    two-class counting pass replaces the separate FP-growth(FP1)+GFP(FP0)
    mining of the big tree.

All counts are exact; tests cross-validate against the host-faithful core and
the brute-force oracle.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.mra import Rule
from ..core.tis import TISTree
from ..kernels.itemset_count import itemset_counts
from .encode import (ItemVocab, class_weights, dedup_rows, encode_bitmap,
                     encode_targets, project_columns)
from .plan import TISSchedule, build_schedule, live_items
from .stream import (DEFAULT_STREAM_THRESHOLD_BYTES, StreamingDB,
                     streaming_counts, streaming_mine_frequent)

Item = Hashable


def _resolve_streaming(db, streaming: Optional[bool],
                       chunk_rows: Optional[int] = None) -> bool:
    """Engine selection.  A StreamingDB always streams; an explicit flag or
    chunk_rows opts in; otherwise stream iff the DB is host-resident (numpy
    bits) AND over the size threshold.  A device-resident DenseDB never
    auto-streams: its allocation already succeeded, and streaming it would
    only add a D2H copy + re-upload (size-based selection belongs BEFORE
    encoding — see minority_report_dense)."""
    if isinstance(db, StreamingDB):
        return True
    if streaming is not None:
        return streaming
    if chunk_rows is not None:
        return True
    if isinstance(db.bits, np.ndarray):
        return (db.bits.size + db.weights.size) * 4 > \
            DEFAULT_STREAM_THRESHOLD_BYTES
    return False


def _count_block(db, masks: np.ndarray, *, use_kernel: bool, streaming: bool,
                 chunk_rows: Optional[int]) -> np.ndarray:
    """(K, C) counts for one target batch on either engine (bit-identical).

    No block shape is pinned here: ``itemset_counts`` / ``streaming_counts``
    resolve block_k/block_n/accum (and, for None ``chunk_rows``, the chunk
    size) through the active per-device tuning table
    (``roofline.autotune.resolve_launch_config``)."""
    if streaming:
        if isinstance(db, StreamingDB):
            return np.asarray(db.counts(masks, use_kernel=use_kernel,
                                        **({"chunk_rows": chunk_rows}
                                           if chunk_rows else {})))
        return np.asarray(streaming_counts(
            np.asarray(db.bits), masks, np.asarray(db.weights),
            chunk_rows=chunk_rows, use_kernel=use_kernel))
    return np.asarray(itemset_counts(
        db.bits, jnp.asarray(masks), db.weights, use_kernel=use_kernel))


@dataclass
class DenseDB:
    """Encoded, deduped, class-weighted transaction database on device."""
    vocab: ItemVocab
    bits: jnp.ndarray      # (U, W) uint32 unique rows
    weights: jnp.ndarray   # (U, C) int32 per-class multiplicities
    n_rows: int            # original N (sum of weights)
    n_classes: int

    @staticmethod
    def encode(
        transactions: Sequence[Sequence[Item]],
        classes: Optional[Sequence[int]] = None,
        n_classes: Optional[int] = None,
        vocab: Optional[ItemVocab] = None,
        min_item_count: int = 1,
    ) -> "DenseDB":
        if vocab is None:
            vocab = ItemVocab.from_transactions(transactions, min_count=min_item_count)
        bits = encode_bitmap(transactions, vocab)
        if classes is None:
            w = np.ones((len(transactions), 1), np.int32)
            n_classes = 1
        else:
            n_classes = n_classes or (int(max(classes)) + 1)
            w = class_weights(classes, n_classes)
        ub, uw = dedup_rows(bits, w)
        return DenseDB(vocab=vocab, bits=jnp.asarray(ub), weights=jnp.asarray(uw),
                       n_rows=len(transactions), n_classes=n_classes)

    @staticmethod
    def from_arrays(vocab: ItemVocab, bits, weights, n_rows: int,
                    n_classes: int) -> "DenseDB":
        """Wrap already-encoded/deduped arrays (serving-store residency hook):
        uploads host arrays to device without re-encoding."""
        return DenseDB(vocab=vocab, bits=jnp.asarray(bits),
                       weights=jnp.asarray(weights), n_rows=n_rows,
                       n_classes=n_classes)

    def project(self, keep_items: Sequence[Item]) -> "DenseDB":
        """Column projection + re-dedup (GFP data reduction, dense form)."""
        bits_np = np.asarray(self.bits)
        proj, sub = project_columns(bits_np, self.vocab, keep_items)
        ub, uw = dedup_rows(proj, np.asarray(self.weights))
        return DenseDB(vocab=sub, bits=jnp.asarray(ub), weights=jnp.asarray(uw),
                       n_rows=self.n_rows, n_classes=self.n_classes)


def dense_gfp_counts(
    tis: TISTree,
    db,                       # DenseDB | StreamingDB
    *,
    use_kernel: bool = True,
    project: bool = True,
    streaming: Optional[bool] = None,
    chunk_rows: Optional[int] = None,
) -> Dict[Tuple[Item, ...], np.ndarray]:
    """GFP-growth contract on the dense engine.

    Returns {sorted-itemset-tuple -> (C,) int32 per-class counts} for every
    *target* node of the TIS-tree (items missing from the DB vocab yield 0,
    matching the paper's note that such targets never appear in the FP-tree).
    ``streaming`` selects the out-of-core chunked sweep (None = auto by DB
    size; always on for a ``StreamingDB``) — counts are bit-identical.
    """
    targets: List[Tuple[Item, ...]] = []
    keys: List[Tuple[Item, ...]] = []
    zero_keys: List[Tuple[Item, ...]] = []
    for node in tis.targets():
        itemset = node.itemset()
        key = tuple(sorted(itemset, key=repr))
        if all(a in db.vocab for a in itemset):
            targets.append(itemset)
            keys.append(key)
        else:
            zero_keys.append(key)

    out: Dict[Tuple[Item, ...], np.ndarray] = {
        k: np.zeros(db.n_classes, np.int32) for k in zero_keys
    }
    if not targets:
        return out

    work_db = db
    if project:
        union: set = set()
        for t in targets:
            union |= set(t)
        work_db = db.project(sorted(union, key=repr))

    masks = encode_targets(targets, work_db.vocab)
    counts = _count_block(work_db, masks, use_kernel=use_kernel,
                          streaming=_resolve_streaming(db, streaming,
                                                       chunk_rows),
                          chunk_rows=chunk_rows)
    for key, row in zip(keys, counts):
        out[key] = row
    return out


def dense_mine_frequent(
    db,                       # DenseDB | StreamingDB
    min_count: float,
    *,
    class_column: Optional[int] = None,
    max_len: int = 0,
    use_kernel: bool = True,
    streaming: Optional[bool] = None,
    chunk_rows: Optional[int] = None,
    checkpoint=None,          # Optional[MiningCheckpoint] (streaming path)
    on_chunk=None,            # streaming progress hook: (level, chunk_idx)
) -> Dict[Tuple[Item, ...], int]:
    """Level-synchronous exact frequent-itemset mining on the device.

    A shim over the unified driver (``mining/driver.py``): candidate level
    k+1 is generated (host) from frequent level k via prefix join +
    anti-monotone prune; each level is counted in ONE kernel launch — the
    §5.1 'single guided invocation per level' realized densely (level 1 via
    the host column-sum shortcut).  ``class_column`` restricts support to one
    weight column (rare class).

    The streaming path (``streaming=True``, a ``StreamingDB`` input, an
    auto-selected large DB, or any ``checkpoint``) runs the same driver over
    the out-of-core backend: each level's counts sweep in N-chunks with
    per-chunk durable progress, so a killed mine resumes mid-level (see
    ``streaming_mine_frequent``).
    """
    from .backend import DenseBackend
    from .driver import mine_frequent as _driver_mine

    if checkpoint is not None and streaming is False:
        raise ValueError("per-chunk checkpointing requires the streaming "
                         "engine; drop streaming=False or the checkpoint")
    if _resolve_streaming(db, streaming, chunk_rows) or checkpoint is not None:
        from dataclasses import replace

        sdb = (db if isinstance(db, StreamingDB)
               else StreamingDB.from_dense(db, chunk_rows))
        if chunk_rows and sdb.chunk_rows != chunk_rows:
            sdb = replace(sdb, chunk_rows=chunk_rows)
        return streaming_mine_frequent(
            sdb, min_count, class_column=class_column, max_len=max_len,
            use_kernel=use_kernel, checkpoint=checkpoint, on_chunk=on_chunk)

    return _driver_mine(DenseBackend(db, use_kernel=use_kernel), min_count,
                        class_column=class_column, max_len=max_len)


@dataclass
class DenseMRAResult:
    rules: List[Rule]
    items_kept: List[Item]
    n_db: int
    n_rare: int
    kernel_launches: int
    engine: str = "dense"


def _crosscheck_fused(itemset, fused_count: int, discovered_count: int,
                      engine: str) -> None:
    """Exactness cross-check: the fused two-class count of an antecedent
    must equal the count the discovery mine produced for the same itemset.
    A mismatch means a kernel/engine exactness bug, not bad user input —
    survives ``python -O``, unlike the bare assert it replaces."""
    if fused_count != discovered_count:
        raise RuntimeError(
            f"minority_report_dense: fused C1 count {fused_count} for "
            f"antecedent {itemset!r} != discovery count "
            f"{discovered_count} (engine={engine}) — exactness violation")


def minority_report_dense(
    transactions: Sequence[Sequence[Item]],
    classes: Sequence[int],
    *,
    target_class: int = 1,
    min_support: float,
    min_confidence: float,
    use_kernel: bool = True,
    streaming: Optional[bool] = None,
    chunk_rows: Optional[int] = None,
    checkpoint=None,          # Optional[MiningCheckpoint] (streaming path)
) -> DenseMRAResult:
    """MRA on the dense engine (see module docstring).

    ``streaming=True`` (or auto, by encoded size) runs both the antecedent
    mine and the fused two-class pass as chunked out-of-core sweeps — the
    rule list is identical to the single-pass engine.
    """
    db_list = [list(t) for t in transactions]
    n_db = len(db_list)
    c_star = min_support * n_db
    min_count = max(1, math.ceil(c_star - 1e-9))

    # ---- pass 1: I' = items frequent in the rare class ----------------------
    c1: Dict[Item, int] = {}
    c_all: Dict[Item, int] = {}
    n_rare = 0
    y01 = []
    for t, y in zip(db_list, classes):
        rare = int(y == target_class)
        y01.append(rare)
        n_rare += rare
        for a in set(t):
            c_all[a] = c_all.get(a, 0) + 1
            if rare:
                c1[a] = c1.get(a, 0) + 1
    items_kept = [a for a, c in c1.items() if c >= c_star]
    items_kept.sort(key=lambda a: (-c_all[a], repr(a)))  # shared global order
    vocab = ItemVocab(tuple(items_kept))

    # ---- pass 2: one encoded DB, two weight columns (C0, C1) ---------------
    # engine selection mirrors _resolve_streaming: explicit flag wins, then
    # chunk_rows/checkpoint opt in, then pre-encode size estimate
    if checkpoint is not None and streaming is False:
        raise ValueError("per-chunk checkpointing requires the streaming "
                         "engine; drop streaming=False or the checkpoint")
    if streaming is not None:
        stream = streaming
    elif chunk_rows is not None or checkpoint is not None:
        stream = True
    else:
        est = n_db * 4 * (max(1, (len(items_kept) + 31) // 32) + 2)
        stream = est > DEFAULT_STREAM_THRESHOLD_BYTES
    if stream:
        db = StreamingDB.encode(db_list, classes=y01, n_classes=2, vocab=vocab,
                                chunk_rows=chunk_rows)
    else:
        db = DenseDB.encode(db_list, classes=y01, n_classes=2, vocab=vocab)

    # ---- antecedent discovery on the rare class (small) ---------------------
    launches = 0
    chunk_counter = [0]
    freq1 = dense_mine_frequent(
        db, min_count, class_column=1, use_kernel=use_kernel, streaming=stream,
        chunk_rows=chunk_rows, checkpoint=checkpoint,
        on_chunk=(lambda lvl, j: chunk_counter.__setitem__(
            0, chunk_counter[0] + 1)) if stream else None)
    if stream:
        launches += chunk_counter[0]  # exact: one launch per swept chunk
    else:
        launches += max(0, max((len(k) for k in freq1), default=1) - 1)
    engine = "streaming" if stream else "dense"

    if not freq1:
        return DenseMRAResult([], items_kept, n_db, n_rare, launches, engine)

    # ---- fused counting of (C0, C1) for all antecedents ----------------------
    itemsets = sorted(freq1.keys())
    masks = encode_targets(itemsets, vocab)
    counts = _count_block(db, masks, use_kernel=use_kernel, streaming=stream,
                          chunk_rows=chunk_rows)
    launches += db.n_chunks if stream else 1

    rules: List[Rule] = []
    for itemset, row in zip(itemsets, counts):
        c0_, c1_ = int(row[0]), int(row[1])
        _crosscheck_fused(itemset, c1_, freq1[itemset], engine)
        conf = c1_ / (c1_ + c0_) if (c0_ + c1_) else 0.0
        if conf >= min_confidence:
            rules.append(Rule(itemset, target_class, c1_ / n_db, conf, c1_, c0_))
    rules.sort(key=lambda r: (-r.confidence, -r.support, r.antecedent))
    return DenseMRAResult(rules, items_kept, n_db, n_rare, launches, engine)
