"""Streaming out-of-core counting engine — N unbounded by device memory.

The dense engine (``dense.py``) requires the whole encoded bitmap resident in
one device allocation.  This module removes that limit the way "Mining
Frequent Itemsets from Secondary Memory" (Grahne & Zhu, 2004) does for
host-memory FP-trees, adapted to the TPU layout:

  * ``StreamingDB`` keeps the (U, W) bitmap + (U, C) class weights HOST-side
    and serves them in N-chunks;
  * ``streaming_counts`` sweeps the chunks through the SAME Pallas kernel,
    accumulating the small (K, C) count block on device
    (``itemset_counts_into``, donated accumulator).  Counts are int32 sums,
    so the sweep is bit-identical to a single dense pass for every chunking;
  * ``streaming_mine_frequent`` is the level-synchronous miner on top — a
    shim over the unified driver (``mining/driver.py``) with the
    ``StreamingBackend``, whose per-chunk checkpointing (a
    ``MiningCheckpoint`` records completed levels, the current level's
    itemsets, next chunk, and the partial accumulator) lets a killed mine
    resume MID-LEVEL from the last completed chunk.

Overlap: jax dispatch is async — the ``jax.device_put`` of chunk i+1 is
enqueued before the host blocks on chunk i's compute, double-buffering the
H2D copy against the kernel (the dispatch-level analogue of the in-kernel
DMA pipeline the grid already runs HBM->VMEM).  Ragged last chunks are
zero-padded to the fixed chunk shape (zero-weight rows count nothing), so the
whole sweep reuses one compiled executable.

Exactness bonus: the ``accum='mxu_f32'`` kernel variant requires N < 2^24 per
launch; chunking re-establishes that bound per chunk, making the MXU path
exact for unbounded total N (total per-class counts must still fit the int32
accumulator — guarded at sweep start).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Hashable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.itemset_count import itemset_counts_into
from .encode import (ItemVocab, class_weights, dedup_rows, encode_bitmap,
                     project_columns)
from .plan import choose_chunk_rows, stream_chunks

Item = Hashable

# Auto-select streaming when the encoded DB exceeds this device footprint.
DEFAULT_STREAM_THRESHOLD_BYTES = 512 << 20


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    if arr.shape[0] == rows:
        return arr
    pad = np.zeros((rows - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([arr, pad], axis=0)


def streaming_counts(
    tx_bits,                      # (N, W) uint32 (host array or device)
    tgt_bits,                     # (K, W) uint32
    weights,                      # (N, C) int32 (or (N,) -> C=1)
    *,
    chunk_rows: Optional[int] = None,
    use_kernel: bool = True,
    accum: Optional[str] = None,
    interpret: Optional[bool] = None,
    block_k: Optional[int] = None,
    block_n: Optional[int] = None,
    init: Optional[np.ndarray] = None,     # (K, C) resume accumulator
    start_chunk: int = 0,
    on_chunk: Optional[Callable[[int, jnp.ndarray], None]] = None,
) -> jnp.ndarray:                 # (K, C) int32
    """Chunked sweep of the counting kernel; bit-identical to one dense pass.

    ``init``/``start_chunk`` resume a partially completed sweep; ``on_chunk``
    is called after each chunk with (chunk_idx, device accumulator) — the
    checkpoint hook (pulling the accumulator to host forces a sync, so only
    pass it when you need durability).  The accumulator is DONATED to the
    next chunk's launch: materialize it inside the callback (np.asarray) —
    holding the array object past the callback reads a deleted buffer on
    accelerator backends.
    """
    tx = np.asarray(tx_bits)
    w = np.asarray(weights)
    if w.ndim == 1:
        w = w[:, None]
    tgt = np.asarray(tgt_bits)
    n = tx.shape[0]
    k, c = tgt.shape[0], w.shape[1]
    if k == 0:
        return jnp.zeros((0, c), jnp.int32)
    # int32 accumulator guard: the largest possible count is the per-class
    # weight-column sum; "unbounded N" holds only while that fits int32
    if n and np.any(w.sum(axis=0, dtype=np.int64) > np.iinfo(np.int32).max):
        raise OverflowError(
            "per-class weight totals exceed int32; streamed counts could "
            "wrap — split the DB or widen the accumulator")
    if chunk_rows is None:
        chunk_rows = choose_chunk_rows(tx.shape[1], c, n_rows=n)
    chunks = stream_chunks(n, chunk_rows)
    acc = (jnp.zeros((k, c), jnp.int32) if init is None
           else jnp.asarray(np.asarray(init), jnp.int32))
    if n == 0 or start_chunk >= len(chunks):
        return acc
    tgt_d = jax.device_put(jnp.asarray(tgt))
    # fixed chunk shape (ragged tail zero-padded): one compiled executable
    pad_to = chunk_rows if len(chunks) > 1 else (chunks[0][1] - chunks[0][0])

    def _prep(j: int):
        s, e = chunks[j]
        return _pad_rows(tx[s:e], pad_to), _pad_rows(w[s:e], pad_to)

    buf = jax.device_put(_prep(start_chunk))
    for j in range(start_chunk, len(chunks)):
        cur_tx, cur_w = buf
        if j + 1 < len(chunks):
            # enqueue next H2D before consuming the current chunk: async
            # dispatch overlaps the copy with this chunk's kernel launches
            buf = jax.device_put(_prep(j + 1))
        acc = itemset_counts_into(
            acc, cur_tx, tgt_d, cur_w, block_k=block_k, block_n=block_n,
            interpret=interpret, use_kernel=use_kernel, accum=accum)
        if on_chunk is not None:
            on_chunk(j, acc)
    return acc


@dataclass
class StreamingDB:
    """Encoded, deduped, class-weighted transaction DB in host-side chunks.

    Mirrors ``DenseDB`` (same encode discipline: support-descending vocab,
    row dedup with per-class weights) but ``bits``/``weights`` stay numpy on
    host and all counting goes through ``streaming_counts``.
    """
    vocab: ItemVocab
    bits: np.ndarray       # (U, W) uint32 unique rows (host)
    weights: np.ndarray    # (U, C) int32 per-class multiplicities (host)
    n_rows: int            # original N (sum of weights)
    n_classes: int
    chunk_rows: int

    @property
    def n_chunks(self) -> int:
        return len(stream_chunks(self.bits.shape[0], self.chunk_rows))

    @property
    def nbytes(self) -> int:
        return int(self.bits.nbytes + self.weights.nbytes)

    @staticmethod
    def encode(
        transactions: Sequence[Sequence[Item]],
        classes: Optional[Sequence[int]] = None,
        n_classes: Optional[int] = None,
        vocab: Optional[ItemVocab] = None,
        min_item_count: int = 1,
        chunk_rows: Optional[int] = None,
    ) -> "StreamingDB":
        if vocab is None:
            vocab = ItemVocab.from_transactions(transactions,
                                                min_count=min_item_count)
        bits = encode_bitmap(transactions, vocab)
        if classes is None:
            w = np.ones((len(transactions), 1), np.int32)
            n_classes = 1
        else:
            n_classes = n_classes or (int(max(classes)) + 1)
            w = class_weights(classes, n_classes)
        ub, uw = dedup_rows(bits, w)
        if chunk_rows is None:
            chunk_rows = choose_chunk_rows(vocab.n_words, n_classes,
                                           n_rows=ub.shape[0])
        return StreamingDB(vocab=vocab, bits=ub, weights=uw,
                           n_rows=len(transactions), n_classes=n_classes,
                           chunk_rows=chunk_rows)

    @staticmethod
    def from_dense(db, chunk_rows: Optional[int] = None) -> "StreamingDB":
        """Host view of a ``DenseDB`` (duck-typed to avoid a module cycle)."""
        bits = np.asarray(db.bits)
        weights = np.asarray(db.weights)
        if chunk_rows is None:
            chunk_rows = choose_chunk_rows(bits.shape[1], weights.shape[1],
                                           n_rows=bits.shape[0])
        return StreamingDB(vocab=db.vocab, bits=bits, weights=weights,
                           n_rows=db.n_rows, n_classes=db.n_classes,
                           chunk_rows=chunk_rows)

    @staticmethod
    def from_arrays(vocab: ItemVocab, bits: np.ndarray, weights: np.ndarray,
                    n_rows: int, n_classes: int,
                    chunk_rows: Optional[int] = None) -> "StreamingDB":
        """Wrap already-encoded/deduped host arrays (serving-store hook)."""
        if chunk_rows is None:
            chunk_rows = choose_chunk_rows(bits.shape[1], weights.shape[1],
                                           n_rows=np.asarray(bits).shape[0])
        return StreamingDB(vocab=vocab, bits=np.asarray(bits),
                           weights=np.asarray(weights), n_rows=n_rows,
                           n_classes=n_classes, chunk_rows=chunk_rows)

    def project(self, keep_items: Sequence[Item]) -> "StreamingDB":
        """Column projection + re-dedup (GFP data reduction, host-side)."""
        proj, sub = project_columns(self.bits, self.vocab, keep_items)
        ub, uw = dedup_rows(proj, self.weights)
        return replace(self, vocab=sub, bits=ub, weights=uw)

    def counts(self, tgt_bits, **kwargs) -> jnp.ndarray:
        kwargs.setdefault("chunk_rows", self.chunk_rows)
        return streaming_counts(self.bits, tgt_bits, self.weights, **kwargs)


# ---------------------------------------------------------------------------
# Level-synchronous mining over a StreamingDB with mid-level checkpointing.
# ---------------------------------------------------------------------------

def streaming_mine_frequent(
    db: StreamingDB,
    min_count: float,
    *,
    class_column: Optional[int] = None,
    max_len: int = 0,
    use_kernel: bool = True,
    accum: Optional[str] = None,
    checkpoint=None,                 # Optional[MiningCheckpoint]
    on_chunk: Optional[Callable[[int, int], None]] = None,
) -> Dict[Tuple[Item, ...], int]:
    """Exact level-synchronous mining, out-of-core, resumable mid-level.

    A shim over the unified driver (``mining/driver.py``) with the
    out-of-core :class:`~repro.mining.backend.StreamingBackend`.  Same
    contract as ``dense_mine_frequent`` (identical result dict).  With a
    ``checkpoint``, progress is durable per chunk: a restart re-loads the
    completed levels, regenerates the interrupted level's candidate list
    (deterministic), and resumes its sweep from the last completed chunk.
    ``on_chunk(level, chunk_idx)`` is a test/progress hook.
    """
    # function-level import: backend.py consumes this module's sweep
    from .backend import StreamingBackend
    from .driver import mine_frequent as _driver_mine

    return _driver_mine(
        StreamingBackend(db, use_kernel=use_kernel, accum=accum), min_count,
        class_column=class_column, max_len=max_len, checkpoint=checkpoint,
        on_chunk=on_chunk)
