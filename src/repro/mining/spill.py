"""Disk-tier chunk store: spill the encoded bitmap to mmap'd segment files.

``StreamingDB`` broke the DEVICE memory ceiling but still keeps every chunk
in host RAM, so real N is bounded by the host.  This module extends the same
chunked-sweep discipline one tier down, the way "Mining Frequent Itemsets
from Secondary Memory" (Grahne & Zhu, 2004) partitions the database on disk
and overlaps IO with computation:

  * ``SpilledDB`` persists the (U, W) bitmap + (U, C) class weights as
    per-chunk ``.npy`` SEGMENT files under one directory, described by a
    ``MANIFEST.json`` written last (tmp + ``os.replace``, the repo's atomic
    checkpoint discipline) — a crashed spill leaves either the previous
    manifest or none, never a torn store.  ``SpilledDB.open(directory)``
    reopens the store after a process death: the segments ARE the durable
    chunk grid, so a killed mine resumes from disk (pair with a
    ``MiningCheckpoint`` for the level/chunk cursor).
  * ``spilled_counts`` sweeps the segments through the same Pallas kernel as
    ``streaming_counts`` — counts are int32 sums, so the sweep is
    bit-identical to the all-RAM streaming sweep and to one dense pass — with
    an ASYNC PREFETCH thread that reads segment i+1 from disk (mmap), pads
    it, and ``jax.device_put``s it while the kernel counts segment i.  The
    host-RAM high-water mark stays at ~2 segments regardless of total N
    (the queue holds at most ``depth`` decoded segments).
  * ``SpilledBackend`` adapts the store to the ``CountBackend`` protocol so
    the unified mining driver checkpoints per SEGMENT — the chunk files are
    the natural checkpoint unit.

Reads go through ``np.load(mmap_mode="r")``: the OS page cache, not the
process heap, holds the bytes, and a re-read after restart touches only the
pages the sweep actually walks.

Telemetry (PR 7 obs layer): ``spill_bytes_written_total`` /
``spill_bytes_read_total`` / ``spill_segments_written_total`` counters, the
``spill_prefetch_hits_total`` / ``spill_prefetch_misses_total`` pair (a hit
means the next segment was already decoded + device-put when the consumer
asked — the overlap worked), and a ``spill_prefetch_hit_ratio`` gauge per
sweep.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from dataclasses import dataclass, field
from typing import Callable, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.itemset_count import itemset_counts_into
from ..obs import REGISTRY, TRACER
from .encode import ItemVocab
from .plan import choose_chunk_rows, stream_chunks
from .stream import _pad_rows

Item = Hashable

MANIFEST_NAME = "MANIFEST.json"
_FORMAT = "repro-spill-v1"

# Host-RAM budget past which VersionedDB residency selection (and the
# chooser, when a spill directory is configured) moves the base to disk.
DEFAULT_SPILL_THRESHOLD_BYTES = int(
    os.environ.get("REPRO_SPILL_THRESHOLD_BYTES", 2 << 30))

# How many decoded+device-put segments the prefetcher may run ahead — 2
# mirrors the double-buffered H2D overlap of the in-RAM streaming sweep.
PREFETCH_DEPTH = 2

_M_SEGS_WRITTEN = REGISTRY.counter("spill_segments_written_total")
_M_BYTES_WRITTEN = REGISTRY.counter("spill_bytes_written_total")
_M_BYTES_READ = REGISTRY.counter("spill_bytes_read_total")
_M_PREFETCH_HITS = REGISTRY.counter("spill_prefetch_hits_total")
_M_PREFETCH_MISSES = REGISTRY.counter("spill_prefetch_misses_total")
_M_PREFETCH_ERRORS = REGISTRY.counter("spill_prefetch_errors_total")


def default_spill_dir() -> str:
    """The spill root when none was configured: ``$REPRO_SPILL_DIR`` or a
    per-process tmp directory (callers own cleanup of explicit dirs)."""
    root = os.environ.get("REPRO_SPILL_DIR")
    if root:
        return root
    import tempfile
    return tempfile.mkdtemp(prefix="repro-spill-")


def _atomic_save(path: str, arr: np.ndarray) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _check_items_jsonable(items: Sequence[Item]) -> list:
    """The manifest persists the vocab; items must survive a JSON
    round-trip IDENTICALLY or a reopened store would mis-key every mask."""
    as_list = list(items)
    try:
        back = json.loads(json.dumps(as_list))
    except TypeError as e:
        raise TypeError(
            f"vocab items must be JSON-serializable to spill to disk: {e}"
        ) from e
    if back != as_list:
        raise TypeError(
            "vocab items do not round-trip through JSON (e.g. tuples become "
            "lists); re-key the items as strings/ints before spilling")
    return as_list


@dataclass
class SpilledDB:
    """Encoded, deduped, class-weighted DB persisted as on-disk segments.

    Mirrors ``StreamingDB`` (same encode discipline, same chunk grid for a
    given ``chunk_rows``) but the rows live in ``.npy`` segment files under
    ``directory`` and every sweep goes through ``spilled_counts``.  The
    ``bits`` / ``weights`` properties MATERIALIZE the full arrays (used by
    compaction and ``GFPBackend.from_store``); steady-state counting never
    does.
    """
    vocab: ItemVocab
    directory: str
    n_rows: int              # original logical N (sum of weights)
    n_classes: int
    chunk_rows: int
    seg_rows: Tuple[int, ...] = field(default_factory=tuple)
    n_words: int = 1

    # -- shape facts (no disk IO) ---------------------------------------------
    @property
    def n_unique(self) -> int:
        return int(sum(self.seg_rows))

    @property
    def n_chunks(self) -> int:
        return len(self.seg_rows)

    @property
    def nbytes(self) -> int:
        """Logical encoded footprint (what the rows would occupy in RAM)."""
        return 4 * (self.n_words + self.n_classes) * self.n_unique

    def _seg_paths(self, j: int) -> Tuple[str, str]:
        return (os.path.join(self.directory, f"seg{j:05d}.bits.npy"),
                os.path.join(self.directory, f"seg{j:05d}.w.npy"))

    # -- construction ---------------------------------------------------------
    @classmethod
    def spill(cls, vocab: ItemVocab, bits: np.ndarray, weights: np.ndarray,
              n_rows: int, n_classes: int, directory: str,
              chunk_rows: Optional[int] = None) -> "SpilledDB":
        """Write already-encoded/deduped host arrays as segment files.

        Segments first, ``MANIFEST.json`` last — each via tmp +
        ``os.replace`` — so a crash mid-spill never leaves an openable but
        torn store.  Raises ``OverflowError`` if per-class totals exceed
        int32 (the same accumulator guard as the streaming sweep, checked
        once here instead of re-reading every segment per sweep)."""
        bits = np.ascontiguousarray(np.asarray(bits, np.uint32))
        weights = np.ascontiguousarray(np.asarray(weights, np.int32))
        if weights.ndim == 1:
            weights = weights[:, None]
        u, n_words = bits.shape
        totals = weights.sum(axis=0, dtype=np.int64)
        if np.any(totals > np.iinfo(np.int32).max):
            raise OverflowError(
                "per-class weight totals exceed int32; spilled counts could "
                "wrap — split the DB or widen the accumulator")
        if chunk_rows is None:
            chunk_rows = choose_chunk_rows(n_words, n_classes, n_rows=u)
        items = _check_items_jsonable(vocab.items)
        os.makedirs(directory, exist_ok=True)
        chunks = stream_chunks(u, chunk_rows)
        db = cls(vocab=vocab, directory=directory, n_rows=int(n_rows),
                 n_classes=int(n_classes), chunk_rows=int(chunk_rows),
                 seg_rows=tuple(e - s for s, e in chunks),
                 n_words=int(n_words))
        with TRACER.span("spill.write", {"segments": len(chunks),
                                         "rows": u}):
            for j, (s, e) in enumerate(chunks):
                bp, wp = db._seg_paths(j)
                _atomic_save(bp, bits[s:e])
                _atomic_save(wp, weights[s:e])
                _M_SEGS_WRITTEN.inc()
                _M_BYTES_WRITTEN.inc(bits[s:e].nbytes + weights[s:e].nbytes)
            manifest = {
                "format": _FORMAT,
                "n_rows": int(n_rows), "n_classes": int(n_classes),
                "chunk_rows": int(chunk_rows), "n_words": int(n_words),
                "seg_rows": [int(r) for r in db.seg_rows],
                "items": items,
                "class_totals": [int(t) for t in totals],
            }
            tmp = os.path.join(directory, MANIFEST_NAME + ".tmp")
            with open(tmp, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(directory, MANIFEST_NAME))
        return db

    @classmethod
    def from_streaming(cls, db, directory: str,
                       chunk_rows: Optional[int] = None) -> "SpilledDB":
        """Spill a ``StreamingDB`` (or any DB exposing host
        bits/weights/vocab/n_rows/n_classes) keeping its chunk grid."""
        return cls.spill(db.vocab, np.asarray(db.bits),
                         np.asarray(db.weights), int(db.n_rows),
                         int(db.n_classes), directory,
                         chunk_rows=chunk_rows if chunk_rows is not None
                         else getattr(db, "chunk_rows", None))

    @classmethod
    def open(cls, directory: str) -> "SpilledDB":
        """Reopen a spilled store from its manifest (the kill/resume seam).

        Validates format and that every listed segment file exists with the
        advertised row count — a torn or truncated store must fail loudly
        here, not miscount later."""
        path = os.path.join(directory, MANIFEST_NAME)
        with open(path) as f:
            m = json.load(f)
        if m.get("format") != _FORMAT:
            raise ValueError(
                f"{path}: unknown spill format {m.get('format')!r} "
                f"(expected {_FORMAT!r})")
        db = cls(vocab=ItemVocab(tuple(m["items"])), directory=directory,
                 n_rows=int(m["n_rows"]), n_classes=int(m["n_classes"]),
                 chunk_rows=int(m["chunk_rows"]),
                 seg_rows=tuple(int(r) for r in m["seg_rows"]),
                 n_words=int(m["n_words"]))
        for j, rows in enumerate(db.seg_rows):
            bp, wp = db._seg_paths(j)
            for p in (bp, wp):
                if not os.path.exists(p):
                    raise FileNotFoundError(
                        f"spilled store at {directory} is torn: manifest "
                        f"lists {p} but the file is missing")
            got = np.load(bp, mmap_mode="r").shape[0]
            if got != rows:
                raise ValueError(
                    f"{bp}: manifest says {rows} rows, file has {got}")
        return db

    # -- IO -------------------------------------------------------------------
    def segment(self, j: int) -> Tuple[np.ndarray, np.ndarray]:
        """Memory-mapped (rows_j, W) bits + (rows_j, C) weights of segment j
        — pages fault in lazily as the sweep (or prefetcher) walks them."""
        bp, wp = self._seg_paths(j)
        return np.load(bp, mmap_mode="r"), np.load(wp, mmap_mode="r")

    @property
    def bits(self) -> np.ndarray:
        """Full (U, W) bitmap, MATERIALIZED from disk.  Compaction-path only;
        counting sweeps stream segments instead."""
        if not self.seg_rows:
            return np.zeros((0, self.n_words), np.uint32)
        return np.concatenate([np.asarray(self.segment(j)[0])
                               for j in range(self.n_chunks)])

    @property
    def weights(self) -> np.ndarray:
        """Full (U, C) weights, MATERIALIZED from disk (see ``bits``)."""
        if not self.seg_rows:
            return np.zeros((0, self.n_classes), np.int32)
        return np.concatenate([np.asarray(self.segment(j)[1])
                               for j in range(self.n_chunks)])

    def head(self, rows: int) -> Tuple[np.ndarray, np.ndarray]:
        """First ``min(rows, seg0)`` rows as host arrays — the trait-sampling
        hook, so the chooser never materializes the whole store."""
        if not self.seg_rows:
            return (np.zeros((0, self.n_words), np.uint32),
                    np.zeros((0, self.n_classes), np.int32))
        b, w = self.segment(0)
        take = min(int(rows), b.shape[0])
        return np.asarray(b[:take]), np.asarray(w[:take])

    def delete(self) -> None:
        """Remove the segment directory (a replaced spilled base is dead
        weight on disk the moment its successor's manifest lands)."""
        shutil.rmtree(self.directory, ignore_errors=True)

    def counts(self, tgt_bits, **kwargs) -> jnp.ndarray:
        return spilled_counts(self, tgt_bits, **kwargs)


def _load_segment(db: SpilledDB, j: int, pad_to: int):
    """Read segment j from disk, zero-pad to the fixed chunk shape, and
    enqueue the H2D copy.  Runs on the prefetch thread during overlapped
    sweeps; the same code serves the synchronous fallback."""
    bits, w = db.segment(j)
    _M_BYTES_READ.inc(bits.nbytes + w.nbytes)
    return jax.device_put((_pad_rows(np.asarray(bits), pad_to),
                           _pad_rows(np.asarray(w), pad_to)))


class _SegmentPrefetcher:
    """Background reader: decodes + ``device_put``s up to ``depth`` segments
    ahead of the consuming sweep.

    All cross-thread state flows through one bounded ``queue.Queue`` (items
    ``("ok", j, bufs)`` / ``("err", exc)``) plus a stop ``Event`` — the
    thread assigns no shared attributes, so there is nothing for a lock to
    guard.  ``get(j)`` counts a prefetch HIT when the segment was already
    decoded and queued at request time (the disk read truly overlapped the
    previous segment's kernel work) and a MISS when the consumer had to
    wait."""

    def __init__(self, db: SpilledDB, order: Sequence[int], pad_to: int,
                 depth: int = PREFETCH_DEPTH):
        self.hits = 0
        self.misses = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, args=(db, list(order), pad_to),
            name="spill-prefetch", daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def _run(self, db: SpilledDB, order: List[int], pad_to: int) -> None:
        try:
            for j in order:
                if self._stop.is_set():
                    return
                if not self._put(("ok", j, _load_segment(db, j, pad_to))):
                    return
        except BaseException as e:   # surface on the consumer, never lost
            _M_PREFETCH_ERRORS.inc()
            self._put(("err", e))

    def get(self, j: int):
        """The consumer's handoff for segment ``j`` (segments are consumed
        strictly in the order the prefetcher was given)."""
        if not self._q.empty():
            self.hits += 1
            _M_PREFETCH_HITS.inc()
        else:
            self.misses += 1
            _M_PREFETCH_MISSES.inc()
        kind, *rest = self._q.get()
        if kind == "err":
            raise rest[0]
        got_j, bufs = rest
        if got_j != j:
            raise RuntimeError(
                f"prefetch order diverged: wanted segment {j}, got {got_j}")
        return bufs

    def shutdown(self) -> None:
        # named shutdown (not close): "close" would collide with the serving
        # layer's lock-holding close() methods in repro-lint's name-resolved
        # call graph and manufacture a phantom lock-order edge
        self._stop.set()
        # unblock a producer stuck in put(): drain whatever is queued
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=10)


def spilled_counts(
    db: SpilledDB,
    tgt_bits,                     # (K, W) uint32
    *,
    use_kernel: bool = True,
    accum: Optional[str] = None,
    interpret: Optional[bool] = None,
    block_k: Optional[int] = None,
    block_n: Optional[int] = None,
    init: Optional[np.ndarray] = None,     # (K, C) resume accumulator
    start_chunk: int = 0,
    on_chunk: Optional[Callable[[int, jnp.ndarray], None]] = None,
    prefetch: bool = True,
    chunk_rows: Optional[int] = None,      # accepted for StreamingDB parity
) -> jnp.ndarray:                 # (K, C) int32
    """Disk-tier chunked sweep; bit-identical to the all-RAM streaming sweep.

    Same resume contract as ``streaming_counts`` (``init`` / ``start_chunk``
    / ``on_chunk``), with segment files as the chunk grid.  With
    ``prefetch=True`` a background thread reads + ``device_put``s segment
    i+1 while the kernel counts segment i; ``prefetch=False`` is the
    synchronous ablation (the benchmark's baseline).  ``chunk_rows`` is
    accepted for call-site parity with ``StreamingDB.counts`` but must match
    the on-disk grid — segments are immutable once spilled."""
    if chunk_rows is not None and int(chunk_rows) != db.chunk_rows:
        raise ValueError(
            f"spilled segments are fixed at chunk_rows={db.chunk_rows}; "
            f"re-spill to change the grid (got {chunk_rows})")
    tgt = np.asarray(tgt_bits)
    k, c = int(tgt.shape[0]), db.n_classes
    if k == 0:
        return jnp.zeros((0, c), jnp.int32)
    acc = (jnp.zeros((k, c), jnp.int32) if init is None
           else jnp.asarray(np.asarray(init), jnp.int32))
    nseg = db.n_chunks
    if db.n_unique == 0 or start_chunk >= nseg:
        return acc
    tgt_d = jax.device_put(jnp.asarray(tgt))
    # fixed chunk shape, ragged tail zero-padded — one compiled executable,
    # single-segment stores launch their exact row count (no padding waste)
    pad_to = db.chunk_rows if nseg > 1 else db.seg_rows[0]
    order = range(start_chunk, nseg)
    fetcher = (_SegmentPrefetcher(db, order, pad_to) if prefetch and
               nseg - start_chunk > 1 else None)
    try:
        with TRACER.span("spill.sweep", {"segments": nseg - start_chunk,
                                         "k": k, "prefetch": bool(fetcher)}):
            for j in order:
                cur_tx, cur_w = (fetcher.get(j) if fetcher is not None
                                 else _load_segment(db, j, pad_to))
                acc = itemset_counts_into(
                    acc, cur_tx, tgt_d, cur_w, block_k=block_k,
                    block_n=block_n, interpret=interpret,
                    use_kernel=use_kernel, accum=accum)
                if on_chunk is not None:
                    on_chunk(j, acc)
    finally:
        if fetcher is not None:
            fetcher.shutdown()
            total = fetcher.hits + fetcher.misses
            if total:
                REGISTRY.set_gauge("spill_prefetch_hit_ratio",
                                   fetcher.hits / total)
    return acc


class SpilledBackend:
    """:class:`~repro.mining.backend.CountBackend` over a :class:`SpilledDB`
    — segment files are the checkpoint unit, so a mine killed mid-level
    resumes from the last durable segment after ``SpilledDB.open``."""

    def __init__(self, db: SpilledDB, *, use_kernel: bool = True,
                 accum: Optional[str] = None, prefetch: bool = True):
        self.db = db
        self.use_kernel = use_kernel
        self.accum = accum
        self.prefetch = prefetch
        self.vocab = db.vocab
        self.n_rows = db.n_rows
        self.n_classes = db.n_classes

    @property
    def nbytes(self) -> int:
        return self.db.nbytes

    @property
    def n_count_chunks(self) -> int:
        return self.db.n_chunks

    def chunk_signature(self) -> dict:
        return {"backend": "spilled", "chunk_rows": self.db.chunk_rows,
                "n_rows": self.db.n_unique}

    def mine_signature(self) -> dict:
        return {}

    def item_counts(self):
        return None

    def traits(self):
        """Sampled traits (head segment) with the TRUE on-disk footprint —
        the chooser must see the full nbytes, not the sample's."""
        from dataclasses import replace as _dc_replace

        from .chooser import TRAIT_SAMPLE_ROWS, DatasetTraits
        bits, w = self.db.head(TRAIT_SAMPLE_ROWS)
        t = DatasetTraits.measure(bits, w, self.vocab, self.n_rows)
        return _dc_replace(t, nbytes=self.db.nbytes,
                           n_unique=self.db.n_unique,
                           dedup_ratio=(self.db.n_unique / self.n_rows
                                        if self.n_rows else 1.0))

    def counts(self, masks, *, start_chunk: int = 0,
               init: Optional[np.ndarray] = None, on_chunk=None):
        rows = spilled_counts(
            self.db, masks, use_kernel=self.use_kernel, accum=self.accum,
            start_chunk=start_chunk, init=init, on_chunk=on_chunk,
            prefetch=self.prefetch)
        return np.asarray(rows)
