"""Adaptive backend chooser: pick a counting engine from MEASURED dataset
characteristics instead of a fixed size threshold.

``DatasetTraits.measure`` samples the encoded bitmap and derives:

  * ``density``     — mean fraction of vocab bits set per (sampled) unique
                      row.  Dense rows mean long frequent patterns and deep
                      level-wise sweeps — FP-growth's home turf.
  * ``skew``        — ratio of the top item's weighted support to the median
                      item's.  Heavy skew concentrates rows under a few tree
                      items, so conditional pattern bases stay small and the
                      guided walk wins even at moderate density.
  * ``dedup_ratio`` — unique rows / logical rows.  Low ratio = heavy prefix
                      compression = the bitmap behaves like a compact
                      FP-tree; conditional blocks are tiny.
  * ``n_rows`` / ``nbytes`` / ``vocab_size`` / ``n_classes`` — the scale
                      facts the residency rules already used.

``choose_backend(traits, ...)`` maps those to one of the four engines
(decision order, first match wins; thresholds are keyword-tunable):

  1. ``distributed`` — a multi-device mesh was handed in: shard the sweep.
  2. ``spilled``     — ``nbytes`` beyond the HOST-RAM spill budget (only when
                       the caller passes ``spill_threshold_bytes``, i.e. a
                       disk tier is configured): mmap segment files + async
                       prefetch (``mining/spill.py``).
  3. ``streaming``   — ``nbytes`` beyond the device-residency threshold:
                       correctness of residency beats per-launch efficiency.
  4. ``dense``       — tiny row counts: launch overhead dwarfs everything;
                       one resident sweep per level is unbeatable.
  5. ``gfp``         — a deep mine (unbounded ``max_len`` or >= ``min_depth``)
                       over a dense-and-compressible or heavily skewed DB:
                       the guided conditional walk replaces one whole-DB
                       launch per level with per-tree-item blocks.
  6. ``dense``       — otherwise: shallow mines and sparse uniform data keep
                       the level-wise sweep.

Every engine is exact, so the chooser is a pure performance policy — the
regression pins in ``tests/test_chooser.py`` assert identical mining results
whichever backend it selects.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..obs import REGISTRY
from .stream import DEFAULT_STREAM_THRESHOLD_BYTES

# Decision thresholds (first-match order documented above).  These are the
# hand-tuned FALLBACKS: thresholds passed as None resolve through the active
# tuning table's measured launch throughput first
# (``roofline.autotune.derived_chooser_thresholds``), so a tuned box derives
# its dense-vs-streaming and gfp-depth crossovers from evidence.
DEFAULT_TINY_ROWS = 2048        # below: dense, always
DEFAULT_DENSE_DENSITY = 0.25    # mean set-bit fraction marking a "dense" DB
DEFAULT_DEDUP_RATIO = 0.6       # unique/logical rows marking compressibility
DEFAULT_SKEW = 4.0              # top/median item support marking heavy skew
DEFAULT_MIN_DEPTH = 4           # pattern depth where per-level launches hurt


def _resolved_thresholds(stream_threshold_bytes, tiny_rows, min_depth):
    """Fill None thresholds from the tuning table's measured-throughput
    derivations, then from the hand-tuned defaults."""
    derived = None
    if stream_threshold_bytes is None or tiny_rows is None or min_depth is None:
        from ..roofline import autotune
        derived = autotune.derived_chooser_thresholds()
    if stream_threshold_bytes is None:
        stream_threshold_bytes = derived.get("stream_threshold_bytes",
                                             DEFAULT_STREAM_THRESHOLD_BYTES)
    if tiny_rows is None:
        tiny_rows = derived.get("tiny_rows", DEFAULT_TINY_ROWS)
    if min_depth is None:
        min_depth = derived.get("min_depth", DEFAULT_MIN_DEPTH)
    return int(stream_threshold_bytes), int(tiny_rows), int(min_depth)

# Trait measurement samples at most this many unique rows / columns.
TRAIT_SAMPLE_ROWS = 4096
_TRAIT_SAMPLE_COLS = 4096


@dataclass(frozen=True)
class DatasetTraits:
    """Measured characteristics of an encoded DB (see module docstring)."""
    n_rows: int          # logical rows (pre-dedup, weight total)
    n_unique: int        # deduped bitmap rows
    vocab_size: int
    n_classes: int
    nbytes: int          # bitmap + weights footprint
    density: float       # mean set-bit fraction per sampled unique row
    skew: float          # top weighted item support / median
    dedup_ratio: float   # n_unique / n_rows

    @classmethod
    def measure(cls, bits, weights, vocab, n_rows: int, *,
                sample_rows: int = TRAIT_SAMPLE_ROWS) -> "DatasetTraits":
        bits = np.asarray(bits)
        weights = np.asarray(weights)
        u = int(bits.shape[0])
        nbytes = int(bits.nbytes + weights.nbytes)
        if u == 0 or vocab.size == 0 or n_rows == 0:
            return cls(n_rows=int(n_rows), n_unique=u, vocab_size=vocab.size,
                       n_classes=int(weights.shape[1]) if weights.ndim == 2
                       else 1,
                       nbytes=nbytes, density=0.0, skew=1.0, dedup_ratio=1.0)
        s = min(u, sample_rows)
        sample = np.ascontiguousarray(bits[:s], np.uint32)
        # mean bits-set per sampled unique row, as a fraction of the vocab
        popcnt = np.unpackbits(sample.view(np.uint8), axis=1).sum(axis=1)
        density = float(popcnt.mean()) / vocab.size
        # weighted per-item supports over the sample (stride-capped columns)
        wtot = weights[:s].sum(axis=1, dtype=np.int64)
        ncols = min(vocab.size, _TRAIT_SAMPLE_COLS)
        sup = np.empty(ncols, np.int64)
        for c in range(ncols):
            bit = (sample[:, c >> 5] >> np.uint32(c & 31)) & 1
            sup[c] = int((bit.astype(np.int64) * wtot).sum())
        top = float(sup.max())
        med = float(np.median(sup))
        skew = top / med if med > 0 else (float("inf") if top > 0 else 1.0)
        return cls(n_rows=int(n_rows), n_unique=u, vocab_size=vocab.size,
                   n_classes=int(weights.shape[1]), nbytes=nbytes,
                   density=density, skew=skew,
                   dedup_ratio=u / float(n_rows))

    @classmethod
    def of_db(cls, db) -> "DatasetTraits":
        return cls.measure(np.asarray(db.bits), np.asarray(db.weights),
                           db.vocab, int(db.n_rows))


@dataclass(frozen=True)
class BackendChoice:
    """A chooser decision: engine ``name``, human-readable ``reason``, and
    the ``traits`` it was derived from (None for forced/explicit picks)."""
    name: str
    reason: str
    traits: Optional[DatasetTraits] = field(default=None)


def _record_choice(choice: BackendChoice) -> BackendChoice:
    """Publish one chooser verdict: a per-engine decision counter plus a
    one-hot ``chooser_last_decision`` gauge (``exclusive=True`` clears the
    previous engine's label, so exactly one label set reads 1)."""
    REGISTRY.counter("chooser_decisions_total", backend=choice.name).inc()
    REGISTRY.set_gauge("chooser_last_decision", 1, exclusive=True,
                       backend=choice.name)
    return choice


def choose_backend(
    traits: DatasetTraits,
    *,
    mesh=None,
    max_len: int = 0,
    stream_threshold_bytes: Optional[int] = None,
    spill_threshold_bytes: Optional[int] = None,
    tiny_rows: Optional[int] = None,
    dense_density: float = DEFAULT_DENSE_DENSITY,
    dedup_ratio: float = DEFAULT_DEDUP_RATIO,
    skew: float = DEFAULT_SKEW,
    min_depth: Optional[int] = None,
) -> BackendChoice:
    """Map measured traits to an engine name (decision order in the module
    docstring; first match wins).  Every verdict — whichever of the return
    points produced it — is recorded through :func:`_record_choice`."""
    return _record_choice(_choose_backend(
        traits, mesh=mesh, max_len=max_len,
        stream_threshold_bytes=stream_threshold_bytes,
        spill_threshold_bytes=spill_threshold_bytes, tiny_rows=tiny_rows,
        dense_density=dense_density, dedup_ratio=dedup_ratio, skew=skew,
        min_depth=min_depth))


def _choose_backend(
    traits: DatasetTraits,
    *,
    mesh=None,
    max_len: int = 0,
    stream_threshold_bytes: Optional[int] = None,
    spill_threshold_bytes: Optional[int] = None,
    tiny_rows: Optional[int] = None,
    dense_density: float = DEFAULT_DENSE_DENSITY,
    dedup_ratio: float = DEFAULT_DEDUP_RATIO,
    skew: float = DEFAULT_SKEW,
    min_depth: Optional[int] = None,
) -> BackendChoice:
    stream_threshold_bytes, tiny_rows, min_depth = _resolved_thresholds(
        stream_threshold_bytes, tiny_rows, min_depth)
    if mesh is not None and getattr(mesh, "size", 1) > 1:
        return BackendChoice(
            "distributed",
            f"multi-device mesh ({getattr(mesh, 'size', 0)} devices): "
            "shard the sweep", traits)
    # spill_threshold_bytes is opt-in (None = no disk tier configured): past
    # the host-RAM budget the rows cannot stay resident ANYWHERE, so disk
    # wins before the device-residency question is even asked
    if spill_threshold_bytes is not None and \
            traits.nbytes > int(spill_threshold_bytes):
        return BackendChoice(
            "spilled",
            f"{traits.nbytes} bytes exceeds the {int(spill_threshold_bytes)}"
            "-byte host-RAM spill budget: mmap disk segments + async "
            "prefetch", traits)
    if traits.nbytes > stream_threshold_bytes:
        return BackendChoice(
            "streaming",
            f"{traits.nbytes} bytes exceeds the {stream_threshold_bytes}-byte "
            "device-residency threshold", traits)
    deep = max_len == 0 or max_len >= min_depth
    if traits.n_rows < tiny_rows:
        return BackendChoice(
            "dense",
            f"tiny DB ({traits.n_rows} rows < {tiny_rows}): launch overhead "
            "dominates, one resident sweep per level", traits)
    if deep and traits.density >= dense_density \
            and traits.dedup_ratio <= dedup_ratio:
        return BackendChoice(
            "gfp",
            f"dense ({traits.density:.2f} >= {dense_density}) and "
            f"compressible ({traits.dedup_ratio:.2f} <= {dedup_ratio}) with "
            "deep patterns: guided conditional counting beats per-level "
            "launches", traits)
    if deep and traits.skew >= skew:
        return BackendChoice(
            "gfp",
            f"skewed item supports ({traits.skew:.1f}x >= {skew}x): "
            "conditional pattern bases stay small", traits)
    return BackendChoice(
        "dense",
        "shallow mine or sparse uniform data: level-wise resident sweep",
        traits)


def backend_for_db(db, *, mesh=None, max_len: int = 0, use_kernel: bool = True,
                   name: Optional[str] = None, **thresholds):
    """Construct the chosen (or ``name``-forced) backend over ``db`` — a host
    :class:`~repro.mining.dense.DenseDB` (or anything exposing
    bits/weights/vocab/n_rows/n_classes).  Returns ``(backend, choice)``.

    Engine imports stay function-level: the chooser is imported by the
    backends' ``traits()`` hook, so module-level engine imports would cycle.
    """
    if name is None or name == "auto":
        traits = DatasetTraits.of_db(db)
        choice = choose_backend(traits, mesh=mesh, max_len=max_len,
                                **thresholds)
    else:
        choice = BackendChoice(name, "explicitly requested")

    if choice.name == "distributed":
        from .distributed import DistributedMiner
        miner = DistributedMiner(mesh, use_kernel=use_kernel)
        return miner.backend(np.asarray(db.bits), np.asarray(db.weights),
                             db.vocab), choice
    if choice.name == "spilled":
        from .spill import SpilledBackend, SpilledDB, default_spill_dir
        if isinstance(db, SpilledDB):
            sdb = db
        else:
            sdb = SpilledDB.spill(db.vocab, np.asarray(db.bits),
                                  np.asarray(db.weights), int(db.n_rows),
                                  int(db.n_classes), default_spill_dir())
        return SpilledBackend(sdb, use_kernel=use_kernel), choice
    if choice.name == "streaming":
        from .backend import StreamingBackend
        from .stream import StreamingDB
        sdb = db if isinstance(db, StreamingDB) else StreamingDB.from_dense(db)
        return StreamingBackend(sdb, use_kernel=use_kernel), choice
    if choice.name == "gfp":
        from .gfp_backend import GFPBackend
        return GFPBackend(db, use_kernel=use_kernel), choice
    if choice.name == "dense":
        from .backend import DenseBackend
        from .dense import DenseDB
        ddb = db if isinstance(db, DenseDB) else DenseDB.from_arrays(
            db.vocab, np.asarray(db.bits), np.asarray(db.weights),
            n_rows=int(db.n_rows), n_classes=int(db.n_classes))
        return DenseBackend(ddb, use_kernel=use_kernel), choice
    raise ValueError(f"unknown backend {choice.name!r}")
