"""The ``CountBackend`` protocol — one counting seam under one mining loop.

The repo's four counting engines (device-dense, host-streaming, mesh-
distributed, versioned serving store) used to each carry their own copy of
the level-synchronous singles -> candidate-generation -> absorb loop.  The
loop now lives ONCE in ``mining/driver.py``; what varies per engine is
captured here:

  ``counts(masks, *, start_chunk=0, init=None, on_chunk=None) -> (K, C)``
      Exact per-class counts of a (K, W) uint32 target block.  The sweep is
      CHUNKED at whatever granularity the engine naturally has
      (``n_count_chunks``): the streaming engine sweeps N-chunks, the
      versioned store sweeps base chunks + a delta chunk, the dense and
      distributed engines are a single chunk.  ``on_chunk(j, acc)`` fires
      after chunk ``j`` with the running (K, C) accumulator (device or host
      array; callers materialize with ``np.asarray`` before holding it) —
      the driver's mid-level checkpoint hook.  ``start_chunk``/``init``
      resume a partially completed sweep; with ``start_chunk >=
      n_count_chunks`` the call returns ``init`` untouched (a fully-counted
      level resumes without recounting).

  ``chunk_signature() -> dict``
      JSON-able identity of the chunk geometry.  A checkpointed mid-level
      partial is only resumed when the saved signature matches — chunk
      indices never transfer between geometries (e.g. a changed
      ``chunk_rows`` restarts the level from chunk 0, still exact).

  ``mine_signature() -> dict``
      JSON-able identity of the counted DB *state*.  A mismatch discards the
      ENTIRE checkpoint (completed levels included): counts taken from a
      different logical DB are not valid progress.  The dense/streaming/
      distributed backends return ``{}`` (one checkpoint path per DB is the
      caller's contract, as before); the versioned store pins its
      ``version`` so a resume across an ``append`` restarts cleanly.

  ``item_counts() -> Optional[(V, C) array]``
      Optional level-1 shortcut: per-item per-class counts for every vocab
      item without a kernel launch (the dense engine's host column sums).
      ``None`` means level 1 is counted through ``counts`` like any level.

  ``traits() -> Optional[DatasetTraits]``
      Optional measured dataset characteristics
      (:class:`~repro.mining.chooser.DatasetTraits`: row count, footprint,
      density, item skew, dedup ratio) for the adaptive backend chooser.
      ``None`` means the engine cannot cheaply inspect its rows; callers
      fall back to whatever they were explicitly given.

plus ``vocab`` / ``n_rows`` / ``n_classes`` / ``nbytes`` for introspection
and backend selection heuristics.

Backend selection is no longer a bare size threshold: ``mining/chooser.py``
maps measured traits to an engine (first match wins) — a multi-device mesh
picks ``distributed``; a footprint beyond the device-residency threshold
picks ``streaming``; tiny DBs pick ``dense``; deep mines over dense-and-
compressible or heavily item-skewed data pick the ``gfp`` hybrid
(:class:`~repro.mining.gfp_backend.GFPBackend`, conditional-pattern-base
counting batched per tree item); everything else keeps the level-wise
``dense`` sweep.  All engines are exact, so the choice is purely a
performance policy.

This module implements the protocol for the mining-layer engines (the GFP
hybrid lives in ``mining/gfp_backend.py``); the serving store's
:class:`~repro.serve.store.VersionedCountBackend` lives with the store
(serving composes on mining, never the reverse).
"""
from __future__ import annotations

from typing import Callable, Hashable, Optional

import jax.numpy as jnp
import numpy as np

from ..kernels.itemset_count import itemset_counts
from .encode import ItemVocab
from .stream import StreamingDB, streaming_counts

Item = Hashable
ChunkHook = Optional[Callable[[int, np.ndarray], None]]


class CountBackend:
    """Base (and documentation) of the counting protocol above.

    Subclasses must set ``vocab``, ``n_rows``, ``n_classes`` and implement
    ``counts``/``nbytes``; the chunking defaults model a single-chunk engine.
    """

    vocab: ItemVocab
    n_rows: int
    n_classes: int

    @property
    def nbytes(self) -> int:
        raise NotImplementedError

    @property
    def n_count_chunks(self) -> int:
        return 1

    def chunk_signature(self) -> dict:
        raise NotImplementedError

    def mine_signature(self) -> dict:
        return {}

    def item_counts(self) -> Optional[np.ndarray]:
        return None

    def traits(self):
        """Measured dataset characteristics for the adaptive chooser, or
        ``None`` when the engine cannot cheaply inspect its rows."""
        return None

    def counts(self, masks: np.ndarray, *, start_chunk: int = 0,
               init: Optional[np.ndarray] = None,
               on_chunk: ChunkHook = None) -> np.ndarray:
        raise NotImplementedError

    # single-chunk engines share this resume discipline
    def _single_chunk(self, count_fn, masks, start_chunk, init, on_chunk
                      ) -> np.ndarray:
        k = int(masks.shape[0])
        base = (np.zeros((k, self.n_classes), np.int32) if init is None
                else np.array(np.asarray(init), np.int32))
        if start_chunk >= 1 or k == 0:
            return base              # already counted: resume skips the launch
        out = base + np.asarray(count_fn(masks))
        if on_chunk is not None:
            on_chunk(0, out)
        return out


class DenseBackend(CountBackend):
    """Device-resident single-launch counting over a :class:`DenseDB`."""

    def __init__(self, db, *, use_kernel: bool = True):
        self.db = db
        self.use_kernel = use_kernel
        self.vocab = db.vocab
        self.n_rows = db.n_rows
        self.n_classes = db.n_classes

    @property
    def nbytes(self) -> int:
        # device arrays expose .nbytes without a D2H transfer
        return int(self.db.bits.nbytes + self.db.weights.nbytes)

    def chunk_signature(self) -> dict:
        return {"backend": "dense", "n_rows": int(self.db.bits.shape[0])}

    def traits(self):
        from .chooser import DatasetTraits
        return DatasetTraits.of_db(self.db)

    def item_counts(self) -> np.ndarray:
        """Level-1 shortcut: per-item counts from host column sums (exact,
        no kernel launch — the same integers the kernel would produce)."""
        bits = np.asarray(self.db.bits)
        w = np.asarray(self.db.weights)
        rows = np.zeros((self.vocab.size, self.n_classes), np.int64)
        for c in range(self.vocab.size):
            bit = (bits[:, c >> 5] >> np.uint32(c & 31)) & 1
            rows[c] = (bit[:, None] * w).sum(axis=0)
        return rows

    def counts(self, masks, *, start_chunk=0, init=None, on_chunk=None):
        return self._single_chunk(
            lambda m: itemset_counts(self.db.bits, jnp.asarray(m),
                                     self.db.weights,
                                     use_kernel=self.use_kernel),
            masks, start_chunk, init, on_chunk)


class StreamingBackend(CountBackend):
    """Out-of-core chunked sweep over a :class:`StreamingDB` (host-resident);
    the only backend with sub-level chunk granularity on a single device."""

    def __init__(self, db: StreamingDB, *, use_kernel: bool = True,
                 accum: Optional[str] = None):
        # accum=None defers to the tuning-table resolution in the kernel seam
        self.db = db
        self.use_kernel = use_kernel
        self.accum = accum
        self.vocab = db.vocab
        self.n_rows = db.n_rows
        self.n_classes = db.n_classes

    @property
    def nbytes(self) -> int:
        return self.db.nbytes

    @property
    def n_count_chunks(self) -> int:
        return self.db.n_chunks

    def chunk_signature(self) -> dict:
        # exactly the keys the pre-driver streaming checkpoints wrote, so
        # existing on-disk partials stay resumable
        return {"chunk_rows": self.db.chunk_rows,
                "n_rows": int(self.db.bits.shape[0])}

    def traits(self):
        from .chooser import DatasetTraits
        return DatasetTraits.of_db(self.db)

    def counts(self, masks, *, start_chunk=0, init=None, on_chunk=None):
        rows = streaming_counts(
            self.db.bits, masks, self.db.weights,
            chunk_rows=self.db.chunk_rows, use_kernel=self.use_kernel,
            accum=self.accum, start_chunk=start_chunk, init=init,
            on_chunk=on_chunk)
        return np.asarray(rows)


class DistributedBackend(CountBackend):
    """Mesh-sharded counting: wraps a sharded launch closure (see
    :class:`~repro.mining.distributed.DistributedMiner`, which shards N over
    the data axes and K over the model axis).

    With ``n_chunks == 1`` (the default) the closure is ``(masks) -> (K, C)``
    and the single-chunk resume discipline applies.  With ``chunk_rows``
    set, the closure must accept the resume keywords (``start_chunk`` /
    ``init`` / ``on_chunk`` — ``distributed_counts`` with its ``chunk_rows``
    sweep) and the backend exposes the sweep's chunk grid to the driver, so
    a mesh mine checkpoints mid-level."""

    def __init__(self, count_fn: Callable[..., np.ndarray],
                 vocab: ItemVocab, n_rows: int, n_classes: int,
                 nbytes: int = 0, *, n_chunks: int = 1,
                 chunk_rows: Optional[int] = None):
        self._count_fn = count_fn
        self.vocab = vocab
        self.n_rows = n_rows
        self.n_classes = n_classes
        self._nbytes = nbytes
        self._n_chunks = int(n_chunks)
        self.chunk_rows = chunk_rows

    @property
    def nbytes(self) -> int:
        return self._nbytes

    @property
    def n_count_chunks(self) -> int:
        return self._n_chunks

    def chunk_signature(self) -> dict:
        sig = {"backend": "distributed", "n_rows": self.n_rows}
        if self._n_chunks > 1:
            # chunked geometry: mid-level partials only transfer between
            # identical chunk_rows sweeps
            sig["chunk_rows"] = self.chunk_rows
        return sig

    def counts(self, masks, *, start_chunk=0, init=None, on_chunk=None):
        if self._n_chunks == 1:
            return self._single_chunk(self._count_fn, masks, start_chunk,
                                      init, on_chunk)
        k = int(masks.shape[0])
        if k == 0:
            return (np.zeros((0, self.n_classes), np.int32) if init is None
                    else np.array(np.asarray(init), np.int32))
        return np.asarray(self._count_fn(masks, start_chunk=start_chunk,
                                         init=init, on_chunk=on_chunk))
