# Online count-serving subsystem: a versioned resident encoded DB answering
# micro-batched itemset-count queries (the paper's "count of a given large
# list of itemsets" contract as a serving workload), with an
# (itemset, version)-keyed LRU result cache and §5.2 incremental re-mining.
from .batcher import (BatchPlan, MicroBatcher, QueryRequest, build_masks,
                      canonical_itemset)
from .cache import CountCache
from .service import (CountServer, MiningRefreshError,
                      versioned_mine_frequent)
from .store import VersionedCountBackend, VersionedDB

__all__ = [
    "BatchPlan", "MicroBatcher", "QueryRequest", "build_masks",
    "canonical_itemset", "CountCache", "CountServer", "MiningRefreshError",
    "versioned_mine_frequent", "VersionedCountBackend", "VersionedDB",
]
