# Online count-serving subsystem: a versioned resident encoded DB answering
# micro-batched itemset-count queries (the paper's "count of a given large
# list of itemsets" contract as a serving workload), with an
# (itemset, version)-keyed LRU result cache, §5.2 incremental re-mining, a
# sharded store spanning a device mesh (exact all-reduced counts), a
# deadline/occupancy-triggered background flush loop, and MRA minority-rule
# serving (RuleServer: confidence from the per-class count rows, rule cache
# keyed on (antecedent, version, min_conf), version prefetch on append).
from .async_loop import AsyncFlusher, CountFuture
from .compactor import AsyncCompactor
from .batcher import (BatchPlan, MicroBatcher, QueryRequest, build_masks,
                      canonical_itemset)
from .cache import CountCache
from .rules import RuleCache, RuleServer
from .service import (CountServer, MiningRefreshError,
                      versioned_mine_frequent)
from .shard import ShardedCountBackend, ShardedDB
from .store import VersionedCountBackend, VersionedDB, check_class_labels

__all__ = [
    "AsyncCompactor", "AsyncFlusher", "BatchPlan", "CountFuture",
    "MicroBatcher",
    "QueryRequest", "build_masks", "canonical_itemset", "CountCache",
    "CountServer", "MiningRefreshError", "versioned_mine_frequent",
    "RuleCache", "RuleServer", "ShardedCountBackend", "ShardedDB",
    "VersionedCountBackend", "VersionedDB", "check_class_labels",
]
