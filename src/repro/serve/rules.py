"""Online minority-rule serving — the MRA rule surface over the count path.

The paper's headline application (Algorithm 4.1, the Minority-Report
Algorithm) turns exact per-class counts into minority-class rules

    antecedent -> target_class,   confidence = C1 / (C1 + C0)

where ``C1`` is the antecedent's count within the target (rare) class and
``C0`` its count everywhere else.  The serving store already holds exactly
that: every count row is a (C,) per-class block, so a rule is one cached
count lookup plus two integer reads — no tree mining on the serving path.

:class:`RuleServer` layers the rule surface on a :class:`CountServer`:

  * ``rules_for(antecedents, ...)`` — batch rule lookups.  Antecedents ride
    the existing ``MicroBatcher``/``CountCache`` machinery (canonicalized,
    cross-deduped, one block_k-padded composed counting pass for the
    uncached rest), then confidence/support are derived from the (K, C)
    rows.  Bit-exact against the host ``minority_report`` on the same
    history: same integers, same float divisions.
  * ``top_rules(theta, min_conf, optimal=...)`` — the full §5.1 workload:
    a CLASS-GUIDED resumable mine (``CountServer.mine(theta,
    class_column=target)``, the same checkpointed driver bootstrap) finds
    every antecedent with C1 >= ceil_count(theta * n_rows), the batch path
    above prices them, and ``optimal_rule_set`` (Li, Shen & Topor 2002)
    drops confidence-dominated supersets on demand.
  * :class:`RuleCache` — LRU keyed on ``(antecedent, target_class,
    min_conf)`` x STORE VERSION: an append invalidates every cached rule by
    construction, exactly like ``CountCache`` (a stale rule hit is
    impossible, no coordination needed).
  * version prefetch — ``append()`` commits the batch through the count
    server, purges stale rule entries, and RE-WARMS the hottest rule keys
    at the new version before traffic hits it (the ROADMAP's
    version-prefetch cache item, scoped to rules).

Everything here works unchanged over a ``VersionedDB`` or a ``ShardedDB``
(host all-reduce loop or mesh psum path): the only store contract used is
``version`` / ``n_rows`` / ``n_classes`` plus the count path itself.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from ..core.mra import Rule
from ..core.optimal_rules import optimal_rule_set
from .batcher import canonical_itemset
from .cache import BudgetedLRU
from .service import CountServer, MiningRefreshError

Item = Hashable
Key = Tuple[Item, ...]
# (antecedent, target_class, min_conf): the version-independent identity of
# a rule query — the cache key half, and the heat-tracking key
RuleKey = Tuple[Key, int, float]


class RuleCache(BudgetedLRU):
    """Bounded LRU: (rule key, version) -> Optional[Rule].

    ``None`` is a first-class cached verdict ("below min_conf at this
    version"): recomputing it would cost the same counting pass as a kept
    rule.  The version in the key makes every ``append`` invalidate by
    construction; ``purge_stale`` reclaims the bytes eagerly.

    The shared :class:`~repro.serve.cache.BudgetedLRU` ledger prices
    entries with :meth:`entry_nbytes` — a fixed deterministic host-side
    estimate (rules are tiny python objects, not device rows) — so
    ``stats()['bytes']`` always equals the sum over resident entries.
    """

    @staticmethod
    def entry_nbytes(rule: Optional[Rule]) -> int:
        """Deterministic priced size of one cached verdict."""
        if rule is None:
            return 16
        return 96 + 16 * len(rule.antecedent)

    def _price(self, value: Optional[Rule]) -> int:
        return self.entry_nbytes(value)

    def get(self, key: RuleKey, version: int) -> Tuple[bool, Optional[Rule]]:
        """Returns ``(hit, rule_or_None)`` — the verdict itself may be None,
        so presence and payload are reported separately."""
        return self._lookup((key, version))

    def put(self, key: RuleKey, version: int, rule: Optional[Rule]) -> None:
        self._store((key, version), rule)


class RuleServer:
    """Minority-rule serving over a :class:`CountServer`.

    ``target_class`` is the default rare class (the paper's class '1');
    per-call overrides are allowed.  ``prefetch_top`` bounds how many of the
    hottest rule keys ``append()`` re-warms at the new version.
    """

    def __init__(
        self,
        server: CountServer,
        *,
        target_class: int = 1,
        cache: bool = True,
        cache_size: int = 65536,
        cache_bytes: Optional[int] = None,
        prefetch_top: int = 8,
        heat_capacity: int = 4096,
    ):
        if not (0 <= target_class < server.store.n_classes):
            raise ValueError(
                f"target_class {target_class} out of range for "
                f"n_classes={server.store.n_classes}")
        if prefetch_top < 0:
            raise ValueError("prefetch_top must be >= 0")
        if heat_capacity <= 0:
            raise ValueError("heat_capacity must be positive")
        self.server = server
        self.target_class = target_class
        self.cache: Optional[RuleCache] = \
            RuleCache(cache_size, max_bytes=cache_bytes) if cache else None
        self.prefetch_top = prefetch_top
        self.heat_capacity = heat_capacity
        self._heat: Dict[RuleKey, int] = {}
        self.n_rule_queries = 0
        self.n_prefetches = 0
        self.n_prefetched_keys = 0

    # -- rule math ------------------------------------------------------------
    def _make_rule(self, key: Key, row, target_class: int,
                   min_conf: float, n_db: int) -> Optional[Rule]:
        # same integers, same float divisions as core.mra.minority_report:
        # served Rule objects compare EQUAL to the host oracle's
        cnt = int(row[target_class])
        gcnt = int(row.sum()) - cnt
        conf = cnt / (cnt + gcnt) if (cnt + gcnt) else 0.0
        if conf < min_conf:
            return None
        return Rule(antecedent=key, consequent=target_class,
                    support=cnt / n_db, confidence=conf,
                    count=cnt, g_count=gcnt)

    def _check_args(self, target_class: Optional[int],
                    min_conf: float) -> int:
        tc = self.target_class if target_class is None else target_class
        if not (0 <= tc < self.server.store.n_classes):
            raise ValueError(
                f"target_class {tc} out of range for "
                f"n_classes={self.server.store.n_classes}")
        if not (0.0 <= min_conf <= 1.0):
            raise ValueError("min_conf must be in [0, 1]")
        return tc

    def _resolve(self, keys: List[Key], target_class: int, min_conf: float,
                 *, touch_heat: bool = True) -> Dict[Key, Optional[Rule]]:
        """{canonical antecedent -> Optional[Rule]} at the current version:
        rule-cache hits first, ONE batched count resolve for the rest."""
        store = self.server.store
        version, n_db = store.version, store.n_rows
        resolved: Dict[Key, Optional[Rule]] = {}
        missing: List[Key] = []
        for key in dict.fromkeys(keys):
            rk: RuleKey = (key, target_class, min_conf)
            if self.cache is not None:
                hit, rule = self.cache.get(rk, version)
                if hit:
                    resolved[key] = rule
                    continue
            missing.append(key)
        if missing:
            # the count path does the heavy lifting: canonical keys, count
            # cache, one composed block_k-padded pass for the uncached rest
            rows = self.server.query(missing, client_id="_rules")
            for key, row in zip(missing, rows):
                rule = self._make_rule(key, row, target_class, min_conf, n_db)
                resolved[key] = rule
                if self.cache is not None:
                    self.cache.put((key, target_class, min_conf), version,
                                   rule)
        if touch_heat:
            for key in keys:
                rk = (key, target_class, min_conf)
                self._heat[rk] = self._heat.get(rk, 0) + 1
            if len(self._heat) > self.heat_capacity:
                self._trim_heat()
        return resolved

    def _trim_heat(self) -> None:
        # keep the hottest half (deterministic tie-break) so the tracker
        # cannot grow without bound under adversarial key churn
        keep = sorted(self._heat.items(),
                      key=lambda kv: (-kv[1], repr(kv[0])))
        self._heat = dict(keep[:self.heat_capacity // 2])

    # -- serving surface ------------------------------------------------------
    def rules_for(
        self,
        antecedents: Sequence[Sequence[Item]],
        *,
        target_class: Optional[int] = None,
        min_conf: float = 0.0,
    ) -> List[Optional[Rule]]:
        """One rule verdict per antecedent, aligned with the input order:
        the :class:`~repro.core.mra.Rule` when confidence >= ``min_conf`` at
        the current version, else ``None``.  Antecedents are canonicalized
        (sorted, deduped) exactly like count queries; an empty antecedent is
        the class prior.  Counts come through the count-serving path, so
        every verdict is exact at the store's current version."""
        tc = self._check_args(target_class, min_conf)
        with self.server._lock:
            keys = [canonical_itemset(a) for a in antecedents]
            resolved = self._resolve(keys, tc, min_conf)
            self.n_rule_queries += len(keys)
            return [resolved[k] for k in keys]

    def top_rules(
        self,
        theta: float,
        min_conf: float = 0.0,
        *,
        target_class: Optional[int] = None,
        optimal: bool = False,
        checkpoint=None,
    ) -> List[Rule]:
        """The complete minority rule set at relative support ``theta``:
        every antecedent with C1 >= ceil_count(theta * n_rows) whose
        confidence clears ``min_conf`` — exactly the host
        ``minority_report(..., min_support=theta, min_confidence=min_conf)``
        rule list (same sort: confidence desc, support desc, antecedent).

        The antecedent discovery is ``CountServer.mine``'s resumable
        class-guided bootstrap: with a ``checkpoint`` a killed ``top_rules``
        resumes the mine mid-level, version-pinned like any other serving
        mine.  ``optimal=True`` filters the result through
        ``optimal_rule_set`` (drop a rule when a proper-subset antecedent
        already achieves its confidence)."""
        tc = self._check_args(target_class, min_conf)
        with self.server._lock:
            frequent = self.server.mine(theta, class_column=tc,
                                        checkpoint=checkpoint)
            antecedents = list(frequent)
            resolved = self._resolve(antecedents, tc, min_conf,
                                     touch_heat=False)
            self.n_rule_queries += len(antecedents)
            rules = [resolved[k] for k in antecedents
                     if resolved[k] is not None]
            rules.sort(key=lambda r: (-r.confidence, -r.support,
                                      r.antecedent))
            return optimal_rule_set(rules) if optimal else rules

    # -- growth path ----------------------------------------------------------
    def append(self, transactions: Sequence[Sequence[Item]],
               classes: Optional[Sequence[int]] = None) -> int:
        """Fold a batch through the count server, purge superseded rule
        verdicts, and re-warm the ``prefetch_top`` hottest rule keys at the
        NEW version — so post-append traffic on the hot keys never pays the
        cold counting pass.  A ``MiningRefreshError`` (batch committed,
        frequent-set refresh failed) still purges and prefetches before
        propagating: the rule path must not serve stale verdicts either way.
        """
        with self.server._lock:
            try:
                version = self.server.append(transactions, classes=classes)
            except MiningRefreshError as e:
                self._after_append(e.version)
                raise
            self._after_append(version)
            return version

    def _after_append(self, version: int) -> None:
        if self.cache is not None:
            self.cache.purge_stale(version)
        if self.prefetch_top <= 0 or not self._heat:
            return
        hottest = sorted(self._heat.items(),
                         key=lambda kv: (-kv[1], repr(kv[0])))
        grouped: Dict[Tuple[int, float], List[Key]] = {}
        for (key, tc, mc), _ in hottest[:self.prefetch_top]:
            grouped.setdefault((tc, mc), []).append(key)
        for (tc, mc), group in grouped.items():
            # current-version verdicts only — _resolve reads store.version
            # inside the lock, so nothing older can be warmed
            self._resolve(group, tc, mc, touch_heat=False)
        self.n_prefetches += 1
        self.n_prefetched_keys += min(self.prefetch_top, len(hottest))

    # -- introspection --------------------------------------------------------
    def stats(self) -> dict:
        with self.server._lock:
            return {
                "rule_cache": (self.cache.stats() if self.cache is not None
                               else None),
                "rule_queries": self.n_rule_queries,
                "target_class": self.target_class,
                "heat_tracked": len(self._heat),
                "prefetch_top": self.prefetch_top,
                "prefetches": self.n_prefetches,
                "prefetched_keys": self.n_prefetched_keys,
            }
