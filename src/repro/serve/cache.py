"""LRU result caches for served counts and rules, keyed on (identity, version).

The DB version is half the key, so an ``append`` (which bumps the store's
version) invalidates every cached entry BY CONSTRUCTION — a stale hit is
impossible, no flush coordination needed.  Stale-version entries age out of
the LRU naturally; ``purge_stale`` drops them eagerly after an append when
memory matters more than the O(capacity) sweep.

Capacity is dual-budgeted: ``capacity`` bounds the entry COUNT, ``max_bytes``
(optional) bounds the PRICED BYTES of the cached values — the right knob
when entry size varies (multi-class count rows, variable-length rule
antecedents) or when the cache shares a host-memory budget with a
streaming-resident DB.  Eviction is LRU under whichever budget is exceeded.

Admission rule: an entry larger than ``max_bytes`` on its own is REJECTED up
front (counted in ``oversized_rejects``), before any resident entry is
touched — admitting it would evict the entire warm working set only to drop
the oversized entry itself once the budget check ran.

:class:`BudgetedLRU` owns that discipline ONCE (ledger, admission, eviction,
purge, stats); :class:`CountCache` instances it for (C,) int32 count rows
(priced at ``nbytes``, hits return a defensive copy) and
``serve.rules.RuleCache`` for rule verdicts (deterministic host-side
pricing, ``None`` as a first-class cached value).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Optional, Tuple

import numpy as np

from ..obs import REGISTRY

Key = Tuple[Hashable, ...]


class BudgetedLRU:
    """Dual-budget LRU core: (key, version) -> value with an exact byte
    ledger.  Subclasses define :meth:`_price` (value -> int bytes) and wrap
    :meth:`_lookup` / :meth:`_store` with their value semantics."""

    def __init__(self, capacity: int = 65536,
                 max_bytes: Optional[int] = None):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError("max_bytes must be positive (or None)")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._d: "OrderedDict[Tuple[Key, int], Any]" = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.oversized_rejects = 0
        self.inserts = 0        # admitted stores of a NEW key
        self.replacements = 0   # admitted stores over a resident key
        self.purged = 0         # entries dropped by purge_stale, cumulative
        # Registry mirrors, labeled by cache kind (both caches share the
        # metric names; the label keeps them separable in the export).  The
        # per-key hot path touches only the plain int counters above;
        # ``publish_metrics`` pushes the deltas into the registry at drain
        # points (flush end, ``stats()``) so a warm-cache hit costs zero
        # registry work.
        kind = type(self).__name__
        self._mirrors = [
            ("hits", REGISTRY.counter("cache_hits_total", cache=kind)),
            ("misses", REGISTRY.counter("cache_misses_total", cache=kind)),
            ("evictions",
             REGISTRY.counter("cache_evictions_total", cache=kind)),
            ("inserts", REGISTRY.counter("cache_inserts_total", cache=kind)),
            ("oversized_rejects",
             REGISTRY.counter("cache_oversized_rejects_total", cache=kind)),
            ("purged", REGISTRY.counter("cache_purged_total", cache=kind)),
        ]
        self._published = {name: 0 for name, _ in self._mirrors}

    def _price(self, value) -> int:
        raise NotImplementedError

    def __len__(self) -> int:
        return len(self._d)

    @property
    def nbytes(self) -> int:
        """Priced resident bytes of the cached values."""
        return self._bytes

    def _over_budget(self) -> bool:
        return (len(self._d) > self.capacity
                or (self.max_bytes is not None
                    and self._bytes > self.max_bytes))

    def _lookup(self, k) -> Tuple[bool, Any]:
        """LRU-touching lookup; counts the hit/miss."""
        if k not in self._d:
            self.misses += 1
            return False, None
        self._d.move_to_end(k)
        self.hits += 1
        return True, self._d[k]

    def _store(self, k, value) -> None:
        size = self._price(value)
        if self.max_bytes is not None and size > self.max_bytes:
            # an entry that can never fit must not touch resident entries:
            # admitting it first would evict the whole warm set before the
            # budget loop finally dropped the oversized entry itself
            self.oversized_rejects += 1
            return
        if k in self._d:
            self._bytes -= self._price(self._d[k])
            self.replacements += 1
        else:
            self.inserts += 1
        self._d[k] = value
        self._bytes += size
        self._d.move_to_end(k)
        while self._d and self._over_budget():
            _, dropped = self._d.popitem(last=False)
            self._bytes -= self._price(dropped)
            self.evictions += 1

    def purge_stale(self, current_version: int) -> int:
        """Eagerly drop entries from superseded versions; returns how many."""
        stale = [k for k in self._d if k[1] != current_version]
        for k in stale:
            self._bytes -= self._price(self._d[k])
            del self._d[k]
        self.purged += len(stale)
        self.publish_metrics()
        return len(stale)

    def publish_metrics(self) -> None:
        """Push the plain-counter deltas since the last publish into the
        registry mirrors.  Called at drain points (flush end, purge,
        ``stats()``) — never on the per-key path.  Deltas are withheld while
        the registry is disabled, so nothing recorded in between is lost
        when it is re-enabled."""
        if not REGISTRY.enabled:
            return
        pub = self._published
        for name, mirror in self._mirrors:
            delta = getattr(self, name) - pub[name]
            if delta:
                mirror.inc(delta)
                pub[name] += delta

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        self.publish_metrics()
        return {"size": len(self._d), "capacity": self.capacity,
                "bytes": self._bytes, "max_bytes": self.max_bytes,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "oversized_rejects": self.oversized_rejects,
                "inserts": self.inserts,
                "replacements": self.replacements,
                "purged": self.purged,
                "hit_rate": round(self.hit_rate, 4)}


def check_cache_ledger(cache: BudgetedLRU, *,
                       miss_driven: bool = False) -> dict:
    """Assert the exact ledger identities every :class:`BudgetedLRU` must
    satisfy at ANY quiescent point; returns ``cache.stats()`` for further
    assertions.  Shared by the count-cache and rule-cache test batteries.

    Internal identities (hold unconditionally):

      * ``inserts - evictions - purged == size`` — every resident entry was
        inserted exactly once and leaves by exactly one of eviction/purge;
      * ``bytes`` equals a from-scratch recount of the resident values, and
        respects ``max_bytes``; ``size`` respects ``capacity``.

    Serving-flow identity (``miss_driven=True``): when every store is
    triggered by a miss (the get-miss-compute-put discipline both serving
    caches follow), ``misses - oversized_rejects == inserts + replacements``.
    A cache populated out-of-band (warmup pre-fill) breaks only this one.

    Raises :class:`AssertionError` explicitly (not via ``assert``) so the
    ledger check still fires under ``python -O``.
    """
    s = cache.stats()
    _require(s["size"] == len(cache._d),
             f"stats size {s['size']} != resident {len(cache._d)}", s)
    _require(s["inserts"] - s["evictions"] - s["purged"] == s["size"],
             "inserts - evictions - purged != size", s)
    recount = sum(cache._price(v) for v in cache._d.values())
    _require(s["bytes"] == recount == cache.nbytes,
             f"byte ledger {s['bytes']} != recount {recount} "
             f"(nbytes {cache.nbytes})", s)
    _require(s["size"] <= s["capacity"], "size exceeds capacity", s)
    if cache.max_bytes is not None:
        _require(s["bytes"] <= cache.max_bytes,
                 "bytes exceed max_bytes budget", s)
    if miss_driven:
        _require(s["misses"] - s["oversized_rejects"]
                 == s["inserts"] + s["replacements"],
                 "misses - oversized_rejects != inserts + replacements", s)
    return s


def _require(cond: bool, detail: str, stats: dict) -> None:
    if not cond:
        raise AssertionError(f"cache ledger violation: {detail} ({stats})")


class CountCache(BudgetedLRU):
    """Bounded LRU: (itemset key, version) -> (C,) int32 count row.

    ``capacity`` caps the entry count; ``max_bytes`` (None = unbounded)
    additionally caps the summed ``nbytes`` of the cached rows.  A hit
    returns a defensive copy: cached rows are immutable serving results,
    never views into a caller's buffer.
    """

    def _price(self, value: np.ndarray) -> int:
        return value.nbytes

    def get(self, key: Key, version: int) -> Optional[np.ndarray]:
        hit, entry = self._lookup((key, version))
        return entry.copy() if hit else None

    def put(self, key: Key, version: int, counts: np.ndarray) -> None:
        self._store((key, version), np.array(counts, np.int32, copy=True))
