"""LRU result cache for served counts, keyed on (canonical itemset, version).

The DB version is half the key, so an ``append`` (which bumps the store's
version) invalidates every cached row BY CONSTRUCTION — a stale hit is
impossible, no flush coordination needed.  Stale-version entries age out of
the LRU naturally; ``purge_stale`` drops them eagerly after an append when
memory matters more than the O(capacity) sweep.

A hit returns a defensive copy: cached rows are immutable serving results,
never views into a caller's buffer.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple

import numpy as np

Key = Tuple[Hashable, ...]


class CountCache:
    """Bounded LRU: (itemset key, version) -> (C,) int32 count row."""

    def __init__(self, capacity: int = 65536):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._d: "OrderedDict[Tuple[Key, int], np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Key, version: int) -> Optional[np.ndarray]:
        entry = self._d.get((key, version))
        if entry is None:
            self.misses += 1
            return None
        self._d.move_to_end((key, version))
        self.hits += 1
        return entry.copy()

    def put(self, key: Key, version: int, counts: np.ndarray) -> None:
        k = (key, version)
        self._d[k] = np.array(counts, np.int32, copy=True)
        self._d.move_to_end(k)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def purge_stale(self, current_version: int) -> int:
        """Eagerly drop rows from superseded versions; returns how many."""
        stale = [k for k in self._d if k[1] != current_version]
        for k in stale:
            del self._d[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self._d), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": round(self.hit_rate, 4)}
