"""LRU result caches for served counts and rules, keyed on (identity, version).

The DB version is half the key, so an ``append`` (which bumps the store's
version) invalidates every cached entry BY CONSTRUCTION — a stale hit is
impossible, no flush coordination needed.  Stale-version entries age out of
the LRU naturally; ``purge_stale`` drops them eagerly after an append when
memory matters more than the O(capacity) sweep.

Capacity is dual-budgeted: ``capacity`` bounds the entry COUNT, ``max_bytes``
(optional) bounds the PRICED BYTES of the cached values — the right knob
when entry size varies (multi-class count rows, variable-length rule
antecedents) or when the cache shares a host-memory budget with a
streaming-resident DB.  Eviction is LRU under whichever budget is exceeded.

Admission rule: an entry larger than ``max_bytes`` on its own is REJECTED up
front (counted in ``oversized_rejects``), before any resident entry is
touched — admitting it would evict the entire warm working set only to drop
the oversized entry itself once the budget check ran.

:class:`BudgetedLRU` owns that discipline ONCE (ledger, admission, eviction,
purge, stats); :class:`CountCache` instances it for (C,) int32 count rows
(priced at ``nbytes``, hits return a defensive copy) and
``serve.rules.RuleCache`` for rule verdicts (deterministic host-side
pricing, ``None`` as a first-class cached value).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, Optional, Tuple

import numpy as np

Key = Tuple[Hashable, ...]


class BudgetedLRU:
    """Dual-budget LRU core: (key, version) -> value with an exact byte
    ledger.  Subclasses define :meth:`_price` (value -> int bytes) and wrap
    :meth:`_lookup` / :meth:`_store` with their value semantics."""

    def __init__(self, capacity: int = 65536,
                 max_bytes: Optional[int] = None):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError("max_bytes must be positive (or None)")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._d: "OrderedDict[Tuple[Key, int], Any]" = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.oversized_rejects = 0

    def _price(self, value) -> int:
        raise NotImplementedError

    def __len__(self) -> int:
        return len(self._d)

    @property
    def nbytes(self) -> int:
        """Priced resident bytes of the cached values."""
        return self._bytes

    def _over_budget(self) -> bool:
        return (len(self._d) > self.capacity
                or (self.max_bytes is not None
                    and self._bytes > self.max_bytes))

    def _lookup(self, k) -> Tuple[bool, Any]:
        """LRU-touching lookup; counts the hit/miss."""
        if k not in self._d:
            self.misses += 1
            return False, None
        self._d.move_to_end(k)
        self.hits += 1
        return True, self._d[k]

    def _store(self, k, value) -> None:
        size = self._price(value)
        if self.max_bytes is not None and size > self.max_bytes:
            # an entry that can never fit must not touch resident entries:
            # admitting it first would evict the whole warm set before the
            # budget loop finally dropped the oversized entry itself
            self.oversized_rejects += 1
            return
        if k in self._d:
            self._bytes -= self._price(self._d[k])
        self._d[k] = value
        self._bytes += size
        self._d.move_to_end(k)
        while self._d and self._over_budget():
            _, dropped = self._d.popitem(last=False)
            self._bytes -= self._price(dropped)
            self.evictions += 1

    def purge_stale(self, current_version: int) -> int:
        """Eagerly drop entries from superseded versions; returns how many."""
        stale = [k for k in self._d if k[1] != current_version]
        for k in stale:
            self._bytes -= self._price(self._d[k])
            del self._d[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"size": len(self._d), "capacity": self.capacity,
                "bytes": self._bytes, "max_bytes": self.max_bytes,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "oversized_rejects": self.oversized_rejects,
                "hit_rate": round(self.hit_rate, 4)}


class CountCache(BudgetedLRU):
    """Bounded LRU: (itemset key, version) -> (C,) int32 count row.

    ``capacity`` caps the entry count; ``max_bytes`` (None = unbounded)
    additionally caps the summed ``nbytes`` of the cached rows.  A hit
    returns a defensive copy: cached rows are immutable serving results,
    never views into a caller's buffer.
    """

    def _price(self, value: np.ndarray) -> int:
        return value.nbytes

    def get(self, key: Key, version: int) -> Optional[np.ndarray]:
        hit, entry = self._lookup((key, version))
        return entry.copy() if hit else None

    def put(self, key: Key, version: int, counts: np.ndarray) -> None:
        self._store((key, version), np.array(counts, np.int32, copy=True))
