"""Micro-batching query planner — many small requests, one kernel launch.

Every counting launch sweeps the whole resident bitmap regardless of how many
targets ride along (up to ``block_k`` per K-block), so per-query launches waste
almost the entire sweep.  The batcher coalesces the queries of many clients
into one padded (K, W) target block:

  * itemsets are canonicalized (sorted, deduped) so identical targets from
    different clients collapse to ONE mask row — cross-client dedup;
  * the block is zero-padded up to a ``block_k`` multiple so the kernel grid
    is full and one compiled executable serves every batch shape bucket;
  * after the launch, the (K, C) result rows are scattered back per request
    in each request's original submission order.

The batcher is pure planning (host, numpy): the device pass and the result
cache live in ``serve.service`` / ``serve.cache``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..mining.encode import ItemVocab, encode_targets
from ..obs import REGISTRY, TRACER

Item = Hashable
Key = Tuple[Item, ...]

# Process-wide serving counters (thread-confined shard bumps, see repro.obs).
# All three are recorded at the DRAIN point (``take()``), in bulk, and rolled
# back by ``restore()`` — the submit path stays registry-free, which is what
# keeps enabled-metrics overhead inside the obs_overhead bench's gate.
_M_REQUESTS = REGISTRY.counter("serve_requests_total")
_M_QUERIES = REGISTRY.counter("serve_queries_total")
_M_DEDUPED = REGISTRY.counter("serve_deduped_queries_total")
_H_QUEUE_WAIT = REGISTRY.histogram("serve_queue_wait_ms")


def canonical_itemset(itemset: Sequence[Item]) -> Key:
    """Deterministic identity of an itemset query: sorted, duplicate-free.
    The cache key half and the cross-client dedup key."""
    return tuple(sorted(set(itemset), key=repr))


@dataclass
class QueryRequest:
    """One client's submitted query list (keys already canonical).
    ``t_submit`` (perf_counter at submit) feeds the queue-wait histogram."""
    request_id: int
    client_id: str
    keys: List[Key]
    t_submit: float = 0.0


@dataclass
class BatchPlan:
    """A drained batch: unique targets + the per-request scatter map."""
    unique_keys: List[Key]
    rows: Dict[Key, int]                  # key -> row in unique_keys
    requests: List[QueryRequest] = field(default_factory=list)

    @property
    def n_queries(self) -> int:
        return sum(len(r.keys) for r in self.requests)


class MicroBatcher:
    """Accumulates (client_id, itemsets) requests; ``take()`` drains them into
    one deduplicated :class:`BatchPlan`."""

    def __init__(self, block_k: Optional[int] = None):
        # None = the tuning-table default; explicit values pin the pad size
        if block_k is None:
            from ..roofline import autotune
            block_k = autotune.DEFAULT_BLOCK_K
        if block_k <= 0:
            raise ValueError("block_k must be positive")
        self.block_k = block_k
        self._pending: List[QueryRequest] = []
        self._next_id = 0
        self.n_requests = 0
        self.n_queries = 0
        self.n_deduped = 0     # queries answered by another request's mask row

    @property
    def pending(self) -> int:
        return len(self._pending)

    def submit(self, client_id: str, itemsets: Sequence[Sequence[Item]]) -> int:
        """Queue one request; returns its ticket (the ``flush()`` result key)."""
        rid = self._next_id
        self._next_id += 1
        keys = [canonical_itemset(s) for s in itemsets]
        self._pending.append(QueryRequest(rid, client_id, keys,
                                          time.perf_counter()))
        self.n_requests += 1
        self.n_queries += len(keys)
        # instant (not a span): the queue wait is the flush's story, and
        # cross-thread nesting would be fake — the ticket id is the link.
        # Guarded so the disabled path allocates nothing (not even the
        # attrs dict) per submit.
        if TRACER.enabled:
            TRACER.instant("serve.submit",
                           {"ticket": rid, "n_queries": len(keys)})
        return rid

    def take(self) -> BatchPlan:
        """Drain pending requests into one plan (unique keys in first-seen
        order — deterministic, so repeated workloads build identical blocks)."""
        now = time.perf_counter()
        rows: Dict[Key, int] = {}
        unique: List[Key] = []
        total = 0
        for req in self._pending:
            total += len(req.keys)
            for key in req.keys:
                if key not in rows:
                    rows[key] = len(unique)
                    unique.append(key)
        dups = total - len(unique)
        self.n_deduped += dups
        # registry mirrors, recorded once per drain (bulk, not per query)
        _M_REQUESTS.inc(len(self._pending))
        _M_QUERIES.inc(total)
        if dups:
            _M_DEDUPED.inc(dups)
        _H_QUEUE_WAIT.observe_many(
            [(now - req.t_submit) * 1e3 for req in self._pending])
        plan = BatchPlan(unique_keys=unique, rows=rows,
                         requests=self._pending)
        self._pending = []
        return plan

    def restore(self, requests: List[QueryRequest]) -> None:
        """Re-queue a taken plan's requests (failed flush): tickets stay
        answerable by a retry.  Requests go back at the FRONT in their
        original order, and the ``n_deduped`` increments their ``take()``
        made are rolled back — a retried flush re-takes the same requests
        and would otherwise double-count every dedup, skewing ``stats()``
        after any retry.  Submit-time stats are untouched."""
        # take() incremented n_deduped once per non-first occurrence of a key
        # within the drained set: total keys minus distinct keys, independent
        # of request order — exactly the amount a re-take will add again
        total = sum(len(r.keys) for r in requests)
        distinct = len({key for r in requests for key in r.keys})
        self.n_deduped -= total - distinct
        # the registry mirrors are drain-time ledgers, so the rollback
        # applies to all of them (negative bumps — exactness over
        # monotonicity): a re-take must leave each request counted once
        _M_REQUESTS.inc(-len(requests))
        _M_QUERIES.inc(-total)
        _M_DEDUPED.inc(-(total - distinct))
        self._pending = list(requests) + self._pending

    def stats(self) -> dict:
        return {"requests": self.n_requests, "queries": self.n_queries,
                "deduped": self.n_deduped, "pending": self.pending,
                "block_k": self.block_k}


def build_masks(
    keys: Sequence[Key],
    vocab: ItemVocab,
    block_k: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Encode unique targets into a (K_pad, W) block, K_pad a ``block_k``
    multiple (zero rows pad the tail; their counts are sliced off).
    ``block_k=None`` pads to the autotuner's default K-block.

    Returns ``(masks, known)`` where ``known[i]`` is False for keys naming
    items outside the vocab: those get an all-zero mask row, and since an
    empty mask is contained in EVERY row, the caller must zero their counts
    (the exact count of a never-seen item's itemset is 0).
    """
    if block_k is None:
        from ..roofline import autotune
        block_k = autotune.DEFAULT_BLOCK_K
    k = len(keys)
    k_pad = max(block_k, ((k + block_k - 1) // block_k) * block_k)
    masks = np.zeros((k_pad, vocab.n_words), np.uint32)
    known = np.array([all(a in vocab for a in key) for key in keys], bool) \
        if k else np.zeros(0, bool)
    idx = np.flatnonzero(known)
    if idx.size:
        masks[idx] = encode_targets([keys[i] for i in idx], vocab)
    return masks, known
