"""Background compactor: move the delta fold off the serving path.

``VersionedDB.append`` used to pay the full re-dedup + residency rebuild
inline the moment the delta crossed ``merge_ratio`` — on a big base that is
the single largest stall an appending client can hit.  ``AsyncCompactor``
follows the ``AsyncFlusher`` pattern (one daemon thread, an ``Event`` wake,
``close()`` drains): ``request()`` just wakes the thread and returns; the
thread runs :meth:`~repro.serve.store.VersionedDB._compact_pass`, which

  * SNAPSHOTS (base, delta, epoch) under the store lock,
  * builds the new deduped base OFF-lock (the expensive part — appends and
    queries proceed against the old base+delta, which stays exact),
  * commits under the lock ONLY if the epoch is unchanged; a concurrent
    append invalidates the build, which is discarded and retried.

Failure safety is inherited from the synchronous path: the new base is built
BEFORE the delta drops, and a failed build records
``last_compaction_error`` / ``n_failed_compactions`` in ``stats()`` while
the store keeps serving exact counts from base+delta.

Lock discipline (registered with repro-lint's CONC001 graph): the compactor
thread never holds its own ``_mu`` while calling into the store, so the only
cross-object edge is ``VersionedDB._store_lock -> AsyncCompactor._mu``
(``request()``/``stats()`` called from under the store lock) — acyclic
against the serving graph.  ``obs.lockwatch.instrument_server`` wraps both
locks for the dynamic cross-check.

Telemetry: ``store_bg_compactions_total`` / ``store_bg_compaction_retries_
total`` counters and a ``store_compactor_queue_depth`` gauge.
"""
from __future__ import annotations

import threading

from ..obs import REGISTRY

_M_BG_RUNS = REGISTRY.counter("store_bg_compactions_total")
_M_BG_RETRIES = REGISTRY.counter("store_bg_compaction_retries_total")
_G_QUEUE_DEPTH = REGISTRY.gauge("store_compactor_queue_depth")

# A build invalidated by concurrent appends is retried at most this many
# times per wake; under sustained append pressure the NEXT append's request
# picks the work up again, so capping only bounds wasted rebuilds.
MAX_RETRIES = 3


class AsyncCompactor:
    """One background thread folding a ``VersionedDB``'s delta off-path."""

    def __init__(self, store, *, max_retries: int = MAX_RETRIES):
        self._store = store
        self.max_retries = max_retries
        self._mu = threading.Lock()
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._pending = 0
        self._closed = False
        self.n_runs = 0
        self.n_retries = 0
        self._thread = threading.Thread(target=self._run,
                                        name="store-compactor", daemon=True)
        self._thread.start()

    # -- serving-side API -----------------------------------------------------
    def request(self) -> None:
        """Ask for one compaction pass; returns immediately (the append's
        only cost).  Coalescing is free: N requests before the thread wakes
        still fold into one pass over the latest delta."""
        with self._mu:
            if self._closed:
                return
            self._pending += 1
            depth = self._pending
            self._idle.clear()
        _G_QUEUE_DEPTH.set(depth)
        self._wake.set()

    def drain(self, timeout: float = 60.0) -> bool:
        """Block until every requested pass has run (test/shutdown hook).
        Never call while holding the store lock — the pass needs it."""
        return self._idle.wait(timeout)

    def close(self, timeout: float = 60.0) -> None:
        """Drain outstanding requests, then stop the thread."""
        self.drain(timeout)
        with self._mu:
            self._closed = True
        self._wake.set()
        self._thread.join(timeout=timeout)

    def stats(self) -> dict:
        with self._mu:
            return {"pending": self._pending, "runs": self.n_runs,
                    "retries": self.n_retries, "closed": self._closed,
                    "alive": self._thread.is_alive()}

    # -- the thread -----------------------------------------------------------
    def _run(self) -> None:
        while True:
            self._wake.wait(timeout=0.5)
            with self._mu:
                if self._closed and self._pending == 0:
                    return
                pending = self._pending
                self._pending = 0
                self._wake.clear()
            if pending == 0:
                continue
            _G_QUEUE_DEPTH.set(0)
            committed = False
            retries = 0
            while not committed and retries <= self.max_retries:
                # _compact_pass absorbs build failures (recording them on
                # the store) and returns False only when a concurrent
                # append invalidated the epoch — worth an immediate retry
                committed = self._store._compact_pass()
                if not committed:
                    retries += 1
                    _M_BG_RETRIES.inc()
            _M_BG_RUNS.inc()
            with self._mu:
                self.n_runs += 1
                self.n_retries += retries
                if self._pending == 0:
                    self._idle.set()
