"""Sharded serving store — one logical ``VersionedDB`` spanning many shards.

The ROADMAP's multi-host serving item, built by COMPOSITION: each shard is a
full :class:`~repro.serve.store.VersionedDB` (resident dense/streaming base +
delta segment, versioned appends), and the sharded layer adds row
partitioning plus the all-reduce.  Exactness is the same additivity argument
as the base+delta composition inside one store: counts are int32 sums over
disjoint row sets, so

    counts(history) == sum over shards of counts(shard rows)

bit-identically, at every version ("Mining Frequent Itemsets from Secondary
Memory", Grahne & Zhu 2004 — partitioned row sweeps with exact merged
counts).

Routing and the vocab invariant
-------------------------------
Every query's block_k-padded target block is routed to EVERY shard and the
(K, C) int32 partials are all-reduced.  Targets are encoded once under the
GLOBAL vocab; that works because each shard's vocab is maintained as a
PREFIX-CONSISTENT extension snapshot of the global vocab: shards are
constructed with the global vocab, and ``append`` syncs the receiving shard
to the current global vocab before folding the batch (``extend_vocab`` only
ever appends bit columns, so a stale shard's resident rows remain valid and
its segments simply read a prefix of the global mask — bits beyond a
segment's width zero that segment's count, exactly the base+delta ``oob``
rule).

``append`` routes the whole batch to the least-loaded shard (fewest resident
rows) and bumps ONE logical version; a rejected batch (label out of range,
int32 overflow) leaves no trace on any shard.  The int32 overflow guard runs
against the GLOBAL per-class totals — per-shard totals fitting int32 does not
bound their sum.

Two all-reduce paths
--------------------
* **host loop** (``mesh=None``): each shard answers with its own resident
  engine (dense single launch / streaming chunk sweep / composed delta) and
  the host sums the partials — works on a single device, any shard count.
* **mesh** (``mesh=`` a jax Mesh): the shards' segments are stacked into one
  row-partitioned placement (``mining.distributed.place_rows``, rebuilt
  lazily per version) and every query is ONE
  ``resident_distributed_counts`` launch — each device counts its local rows
  and a psum all-reduces the (K, C) block.  This is the
  ``mining/distributed.py`` composition: serving rides the exact same
  shard_map counting launch mining uses.

Mining over a sharded store goes through :class:`ShardedCountBackend` — the
:class:`~repro.mining.backend.CountBackend` with one checkpoint chunk PER
SHARD, so ``CountServer.mine``/``versioned_mine_frequent`` kill/resume works
unchanged: the shard grid is part of ``chunk_signature`` and the logical
version pins ``mine_signature`` (a resume across an append restarts cleanly).
"""
from __future__ import annotations

from typing import Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..mining.backend import CountBackend
from ..mining.encode import ItemVocab, extend_vocab, pad_words
from ..obs import REGISTRY
from .store import VersionedDB, check_class_labels, counts_for_itemsets

Item = Hashable

# all-reduce path taken per counting sweep: mesh = one fused psum launch,
# host_loop = per-shard sweeps summed on the host
_M_SWEEP_MESH = REGISTRY.counter("shard_count_sweeps_total", path="mesh")
_M_SWEEP_HOST = REGISTRY.counter("shard_count_sweeps_total", path="host_loop")
_M_SHARD_APPENDS = REGISTRY.counter("shard_appends_total")


class ShardedDB:
    """Row-partitioned :class:`VersionedDB` shards behind one logical store.

    Mirrors the ``VersionedDB`` serving surface (``version`` / ``n_rows`` /
    ``vocab`` / ``counts`` / ``counts_masks`` / ``append`` / ``compact`` /
    ``stats``), so ``CountServer`` and the mining driver run unchanged on
    top of it.
    """

    def __init__(
        self,
        transactions: Sequence[Sequence[Item]] = (),
        classes: Optional[Sequence[int]] = None,
        n_classes: Optional[int] = None,
        *,
        n_shards: int = 2,
        mesh=None,
        data_axes: Tuple[str, ...] = ("data",),
        use_kernel: bool = True,
        streaming: Optional[bool] = None,
        chunk_rows: Optional[int] = None,
        stream_threshold_bytes: Optional[int] = None,
        merge_ratio: float = 0.25,
        min_compact_rows: Optional[int] = None,
    ):
        if n_shards <= 0:
            raise ValueError("n_shards must be positive")
        transactions = [list(t) for t in transactions]
        if classes is not None and len(classes) != len(transactions):
            # validate BEFORE partitioning: the round-robin slice would
            # silently drop surplus labels (after they widened n_classes)
            # or IndexError on a short list
            raise ValueError("classes length != transactions length")
        self.n_classes = check_class_labels(classes, n_classes)
        self.n_shards = n_shards
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.use_kernel = use_kernel
        self.version = 0
        self.n_appends = 0
        self._mesh_launches = 0
        self._mesh_resident = None   # (bits, weights) device placement, lazy
        # one GLOBAL vocab; every shard starts from it (prefix invariant)
        self.vocab = ItemVocab.from_transactions(transactions)
        self.shards: List[VersionedDB] = []
        for s in range(n_shards):
            part = list(range(s, len(transactions), n_shards))  # round-robin
            self.shards.append(VersionedDB(
                [transactions[i] for i in part],
                classes=[classes[i] for i in part] if classes is not None
                else None,
                n_classes=self.n_classes, vocab=self.vocab,
                use_kernel=use_kernel, streaming=streaming,
                chunk_rows=chunk_rows,
                stream_threshold_bytes=stream_threshold_bytes,
                merge_ratio=merge_ratio,
                min_compact_rows=min_compact_rows))
        # per-shard totals fitting int32 does not bound their SUM — the
        # serving guarantee is on the merged counts, so guard globally
        self._class_totals = VersionedDB._guard_totals(
            sum((s._class_totals for s in self.shards),
                np.zeros(self.n_classes, np.int64)))

    # -- introspection --------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.shards)

    @property
    def resident(self) -> str:
        kinds = ",".join(s.resident for s in self.shards)
        return f"sharded[{kinds}]"

    @property
    def base_rows(self) -> int:
        return sum(s.base_rows for s in self.shards)

    @property
    def delta_rows(self) -> int:
        return sum(s.delta_rows for s in self.shards)

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shards)

    @property
    def kernel_launches(self) -> int:
        return self._mesh_launches + sum(s.kernel_launches
                                         for s in self.shards)

    def stats(self) -> dict:
        return {
            "version": self.version, "n_rows": self.n_rows,
            "n_classes": self.n_classes, "vocab_size": self.vocab.size,
            "resident": self.resident, "n_shards": self.n_shards,
            "shard_rows": [s.n_rows for s in self.shards],
            "base_rows": self.base_rows, "delta_rows": self.delta_rows,
            "nbytes": self.nbytes, "kernel_launches": self.kernel_launches,
            "appends": self.n_appends,
            "compactions": sum(s.n_compactions for s in self.shards),
            "failed_compactions": sum(s.n_failed_compactions
                                      for s in self.shards),
            "mesh": (None if self.mesh is None
                     else dict(self.mesh.shape)),
        }

    # -- append ---------------------------------------------------------------
    def append(
        self,
        transactions: Sequence[Sequence[Item]],
        classes: Optional[Sequence[int]] = None,
    ) -> int:
        """Route the batch to the least-loaded shard; bump ONE logical
        version.  A rejected batch leaves no trace on any shard."""
        transactions = [list(t) for t in transactions]
        if not transactions:
            return self.version
        # validate + guard against the GLOBAL totals before any shard state
        check_class_labels(classes, self.n_classes)
        inc = np.zeros(self.n_classes, np.int64)
        if classes is not None:
            if len(classes) != len(transactions):
                raise ValueError("classes length != transactions length")
            np.add.at(inc, np.asarray(classes, np.int64), 1)
        else:
            if self.n_classes != 1:
                raise ValueError(
                    "classes are required on a multi-class store "
                    f"(n_classes={self.n_classes})")
            inc[0] = len(transactions)
        totals = VersionedDB._guard_totals(self._class_totals + inc)

        shard = min(self.shards, key=lambda s: s.n_rows)
        old_vocab = shard.vocab
        # sync the receiving shard to the current global vocab FIRST: its own
        # extend_vocab then lands on exactly the new global (deterministic),
        # keeping every shard a prefix snapshot of one global column order
        shard.vocab = self.vocab
        try:
            shard.append(transactions, classes=classes)
        except BaseException:
            shard.vocab = old_vocab          # rejected: no trace
            raise
        self.vocab = shard.vocab
        self._class_totals = totals
        self._mesh_resident = None           # placement is version-stale
        self.n_appends += 1
        self.version += 1
        _M_SHARD_APPENDS.inc()
        return self.version

    def compact(self) -> None:
        """Fold every shard's delta into its base (counts unchanged)."""
        for s in self.shards:
            s.compact()
        self._mesh_resident = None           # chunk geometry changed

    # -- counting -------------------------------------------------------------
    def _resident_placement(self):
        """Lazily (re)build the mesh row placement from every shard's
        segments, padded to the current global width.  Rebuilt per version —
        appends invalidate; queries between appends reuse one placement."""
        if self._mesh_resident is None:
            from ..mining.distributed import place_rows

            w_now = self.vocab.n_words
            bit_parts, w_parts = [], []
            for s in self.shards:
                if s.base_rows:
                    bit_parts.append(pad_words(np.asarray(s.base.bits),
                                               w_now))
                    w_parts.append(np.asarray(s.base.weights))
                if s._delta_bits is not None:
                    bit_parts.append(pad_words(s._delta_bits, w_now))
                    w_parts.append(s._delta_weights)
            bits = (np.concatenate(bit_parts) if bit_parts
                    else np.zeros((0, w_now), np.uint32))
            weights = (np.concatenate(w_parts) if w_parts
                       else np.zeros((0, self.n_classes), np.int32))
            self._mesh_resident = place_rows(bits, weights, self.mesh,
                                             data_axes=self.data_axes)
        return self._mesh_resident

    def counts_masks(self, masks: np.ndarray,
                     block_k: Optional[int] = None) -> np.ndarray:
        """(K, C) exact counts for a (K, W_global) target block: the block is
        routed to every shard and the int32 partials are all-reduced — on the
        host when ``mesh`` is None, via one psum launch otherwise."""
        k = int(masks.shape[0])
        if k == 0:
            return np.zeros((0, self.n_classes), np.int32)
        if self.mesh is not None:
            from ..mining.distributed import resident_distributed_counts

            bits_d, w_d = self._resident_placement()
            narrow = masks
            if masks.shape[1] < int(bits_d.shape[1]):
                narrow = pad_words(np.ascontiguousarray(masks),
                                   int(bits_d.shape[1]))
            got = resident_distributed_counts(
                bits_d, narrow, w_d, self.mesh, data_axes=self.data_axes,
                model_axis=None, use_kernel=self.use_kernel)
            self._mesh_launches += 1
            _M_SWEEP_MESH.inc()
            return got
        _M_SWEEP_HOST.inc()
        total = np.zeros((k, self.n_classes), np.int32)
        for shard in self.shards:
            total += shard.counts_masks(masks, block_k=block_k)
        return total

    def counts(self, itemsets: Sequence[Sequence[Item]]) -> np.ndarray:
        """(K, C) counts for raw itemsets under the global vocab; itemsets
        naming never-seen items count exactly 0 (same contract as
        ``VersionedDB.counts``, same code)."""
        return counts_for_itemsets(self, itemsets)


class ShardedCountBackend(CountBackend):
    """:class:`~repro.mining.backend.CountBackend` over a :class:`ShardedDB`:
    the seam that runs the unified mining driver against the sharded store.

    Checkpoint chunk grid = ONE CHUNK PER SHARD (each chunk is that shard's
    full composed base+delta sweep), so a killed mine resumes after the last
    fully-counted shard.  ``chunk_signature`` carries the shard grid — a
    resume onto a different shard layout restarts the in-flight level from
    chunk 0 (still exact) — and ``mine_signature`` pins the logical version:
    a resume across an ``append`` discards the whole checkpoint.
    """

    def __init__(self, store: ShardedDB):
        self.store = store

    @property
    def vocab(self) -> ItemVocab:
        return self.store.vocab

    @property
    def n_rows(self) -> int:
        return self.store.n_rows

    @property
    def n_classes(self) -> int:
        return self.store.n_classes

    @property
    def nbytes(self) -> int:
        return self.store.nbytes

    @property
    def n_count_chunks(self) -> int:
        return len(self.store.shards)

    def chunk_signature(self) -> dict:
        return {
            "backend": "sharded", "version": self.store.version,
            "n_shards": self.store.n_shards,
            "shard_rows": [s.n_rows for s in self.store.shards],
        }

    def mine_signature(self) -> dict:
        return {"version": self.store.version,
                "n_shards": self.store.n_shards}

    def counts(self, masks: np.ndarray, *, start_chunk: int = 0,
               init: Optional[np.ndarray] = None, on_chunk=None) -> np.ndarray:
        store = self.store
        k = int(masks.shape[0])
        total = (np.zeros((k, store.n_classes), np.int32) if init is None
                 else np.array(np.asarray(init), np.int32))
        if k == 0:
            return total
        # per-shard sweeps (not the fused mesh launch): the chunk boundary IS
        # the resume point, and every shard — empty ones included — completes
        # its chunk, so recorded progress always matches n_count_chunks
        for i in range(start_chunk, len(store.shards)):
            total = total + store.shards[i].counts_masks(masks)
            if on_chunk is not None:
                on_chunk(i, total)
        return total
