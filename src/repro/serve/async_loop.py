"""Background flush driver — the async serving loop over ``CountServer``.

The synchronous driver loop (``submit`` / ``flush``) makes every client wait
for an explicit flush.  ``AsyncFlusher`` runs the flush decision in a
background thread with the two standard micro-batching triggers:

* **occupancy**: flush as soon as ``min_batch`` requests are pending — the
  batch is worth a launch;
* **deadline**: flush at most ``max_delay_ms`` after the OLDEST pending
  request was submitted — a lone request is never parked longer than the
  latency budget.

``submit_async()`` returns a :class:`CountFuture`; the result arrives when
some flush (background-triggered, an explicit synchronous ``flush()``, or
the ``close()`` drain) answers the ticket.  Correctness is untouched: the
async loop only decides WHEN the existing synchronous flush runs — every
count is still the exact composed sweep at flush-time version.

Failure discipline matches the synchronous path: a failed flush restores the
drained requests to the batcher (tickets stay answerable), the flusher
counts the error and retries at the next deadline.  ``close()`` stops the
trigger thread and then DRAINS the batcher — a submitted ticket is never
orphaned: its future either carries the counts or (when the final drain
itself fails) the error.

Thread safety: the owning ``CountServer`` serializes every state-touching
operation (submit/flush/query/append/mine) behind one re-entrant lock when
``async_flush`` is enabled; the flusher piggybacks on that lock.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Hashable, Optional, Sequence

import numpy as np

from ..obs import REGISTRY, nearest_rank

Item = Hashable

_M_FLUSH_ERRORS = REGISTRY.counter("serve_flush_errors_total")
_H_FLUSH_WAIT = REGISTRY.histogram("serve_flush_wait_ms")


class CountFuture:
    """Future-like handle for one async-submitted request.

    ``result(timeout)`` blocks until some flush answers the ticket and
    returns the (len(itemsets), C) int32 block — or raises the flush error
    if the serving pass ultimately failed, or ``TimeoutError`` on timeout.
    """

    __slots__ = ("ticket", "_event", "_result", "_exc")

    def __init__(self, ticket: int):
        self.ticket = ticket
        self._event = threading.Event()
        self._result: Optional[np.ndarray] = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"ticket {self.ticket} unanswered after {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def _set_result(self, value: np.ndarray) -> None:
        self._result = value
        self._event.set()

    def _set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._event.set()


class AsyncFlusher:
    """Deadline- and occupancy-triggered background flush loop.

    Owns the ticket -> :class:`CountFuture` map; ``CountServer.flush``
    reports every answered batch back through :meth:`_dispatch`, so futures
    are fulfilled no matter WHO ran the flush (background trigger, a
    synchronous caller, or the ``close()`` drain).
    """

    def __init__(self, server, *, max_delay_ms: float = 5.0,
                 min_batch: int = 8, latency_window: int = 4096):
        if max_delay_ms <= 0:
            raise ValueError("max_delay_ms must be positive")
        if min_batch <= 0:
            raise ValueError("min_batch must be positive")
        self._server = server
        self.max_delay_s = max_delay_ms / 1e3
        self.min_batch = min_batch
        self._futures: Dict[int, CountFuture] = {}
        self._unclaimed: Dict[int, np.ndarray] = {}   # sync tickets a
        # background flush answered; handed back by the next flush() call
        self._oldest: Optional[float] = None   # submit time of oldest pending
        self._backoff_until = 0.0              # no trigger before this time
        self._reason: Optional[str] = None     # consumed by _dispatch
        self._wake = threading.Event()
        self._closed = False
        self.n_flushes = 0
        self.n_flush_errors = 0
        self.last_flush_error: Optional[str] = None
        self.flushes_by_trigger = {"occupancy": 0, "deadline": 0,
                                   "manual": 0, "drain": 0}
        # _lat_lock guards the latency window: appends run inside _dispatch
        # (under the SERVER lock), but stats() is a monitoring call that must
        # not contend for — or wait on — an in-flight flush, so it cannot
        # take the server lock; sorting the deque while _dispatch appends
        # would raise "deque mutated during iteration" without this
        self._lat_lock = threading.Lock()
        self.latencies_ms = deque(maxlen=latency_window)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="count-server-flush")
        self._thread.start()

    # -- client side ----------------------------------------------------------
    def submit(self, client_id: str,
               itemsets: Sequence[Sequence[Item]]) -> CountFuture:
        """Queue one request; returns its future.  Wakes the trigger thread
        when this submit starts the deadline clock or fills the batch."""
        with self._server._lock:
            if self._closed:
                raise RuntimeError("AsyncFlusher is closed")
            ticket = self._server.batcher.submit(client_id, itemsets)
            fut = CountFuture(ticket)
            self._futures[ticket] = fut
            first = self._oldest is None
            if first:
                self._oldest = time.monotonic()
            # wake when this submit STARTS the deadline clock (the thread may
            # be parked with no timeout) or fills the batch
            wake = first or self._server.batcher.pending >= self.min_batch
        if wake:
            self._wake.set()
        return fut

    # -- flush plumbing -------------------------------------------------------
    def _dispatch(self, out: Dict[int, np.ndarray],
                  started: Optional[float] = None) -> None:
        """Fulfill futures for an answered batch (called by
        ``CountServer.flush`` under the server lock).  ``started`` is the
        flush START time: the recorded latency is the queue wait of the
        batch's oldest request — the quantity ``max_delay_ms`` bounds —
        not the wait plus the counting pass itself."""
        if out:
            now = started if started is not None else time.monotonic()
            if self._oldest is not None:
                wait_ms = (now - self._oldest) * 1e3
                with self._lat_lock:
                    self.latencies_ms.append(wait_ms)
                _H_FLUSH_WAIT.observe(wait_ms)
            self.n_flushes += 1
            reason = self._reason or "manual"
            self.flushes_by_trigger[reason] = \
                self.flushes_by_trigger.get(reason, 0) + 1
            REGISTRY.counter("serve_flushes_total", trigger=reason).inc()
            for ticket, block in out.items():
                fut = self._futures.pop(ticket, None)
                if fut is not None:
                    # a manual flush() caller receives the same blocks in its
                    # return dict — the future gets its OWN copy, so neither
                    # consumer can mutate the other's "exact" rows (the same
                    # immutability contract the cache's defensive copy keeps)
                    fut._set_result(np.array(block, np.int32, copy=True))
                elif reason != "manual":
                    # a synchronously submitted ticket drained by a
                    # background (or drain) flush: its result must not
                    # vanish — the next explicit flush() hands it back
                    self._unclaimed[ticket] = block
        # CountServer.flush calls _dispatch under the server lock (see the
        # docstring): the lock IS held here, just not lexically visible
        self._reason = None          # repro-lint: disable=CONC002
        # repro-lint: disable=CONC002 -- caller holds the server lock
        self._oldest = (None if self._server.batcher.pending == 0
                        else time.monotonic())

    def claim_unclaimed(self) -> Dict[int, np.ndarray]:
        """Hand back (and forget) results of sync tickets that a background
        flush answered (called by ``CountServer.flush`` under the lock)."""
        out, self._unclaimed = self._unclaimed, {}
        return out

    def _try_flush(self, reason: str) -> None:
        # ONE lock scope around trigger + failure handling: releasing the
        # lock between an escaping flush error and the handler would let a
        # concurrent manual flush() observe the stale _reason and
        # misclassify itself as a background trigger
        with self._server._lock:
            if not self._server.batcher.pending:
                return
            self._reason = reason
            try:
                self._server.flush()       # _dispatch runs inside
            except Exception as e:
                # requests were restored to the batcher (tickets stay
                # pending); back off one deadline period before retrying —
                # an occupancy trigger would otherwise busy-spin on a
                # persistent failure
                self.n_flush_errors += 1
                self.last_flush_error = f"{type(e).__name__}: {e}"
                _M_FLUSH_ERRORS.inc()
                self._reason = None
                now = time.monotonic()
                self._oldest = now
                self._backoff_until = now + self.max_delay_s

    def _run(self) -> None:
        while True:
            with self._server._lock:
                if self._closed:
                    return
                pending = self._server.batcher.pending
                oldest = self._oldest
            now = time.monotonic()
            if now < self._backoff_until:
                self._wake.wait(self._backoff_until - now)
                self._wake.clear()
                continue
            if pending >= self.min_batch:
                self._try_flush("occupancy")
                continue
            if pending and oldest is not None \
                    and now - oldest >= self.max_delay_s:
                self._try_flush("deadline")
                continue
            timeout = (None if oldest is None
                       else max(1e-4, oldest + self.max_delay_s - now))
            self._wake.wait(timeout)
            self._wake.clear()

    # -- shutdown -------------------------------------------------------------
    def close(self) -> None:
        """Stop the trigger thread, then drain: every submitted ticket's
        future is fulfilled — with counts, or with the drain error."""
        with self._server._lock:
            if self._closed:
                return
            self._closed = True
        self._wake.set()
        self._thread.join()
        try:
            with self._server._lock:
                if self._server.batcher.pending:
                    self._reason = "drain"
                    self._server.flush()
        except BaseException as e:
            with self._server._lock:
                orphans = list(self._futures.values())
                self._futures.clear()
            for fut in orphans:
                fut._set_exception(e)
            raise

    @property
    def closed(self) -> bool:
        return self._closed

    def stats(self) -> dict:
        # snapshot under _lat_lock: _dispatch may be appending mid-flush, and
        # iterating a deque during a concurrent append raises.  The copy is
        # O(window), bounded by latency_window.
        with self._lat_lock:
            lat = sorted(self.latencies_ms)

        def pct(p: float) -> Optional[float]:
            # exact nearest-rank (ceil(p*n)-th order statistic): the old
            # ``lat[int(p * n)]`` form over-shot one rank on small samples
            # (p50 of [1, 2] read 2; of a single sample, p95 indexed past
            # the data but for the min() clamp).  See obs.nearest_rank.
            if not lat:
                return None
            return nearest_rank(lat, p)

        return {
            "closed": self._closed,
            "max_delay_ms": self.max_delay_s * 1e3,
            "min_batch": self.min_batch,
            "pending_tickets": len(self._futures),
            "unclaimed_sync_tickets": len(self._unclaimed),
            "flushes": self.n_flushes,
            "flush_errors": self.n_flush_errors,
            "last_flush_error": self.last_flush_error,
            "by_trigger": dict(self.flushes_by_trigger),
            "flush_latency_ms": {
                "p50": pct(0.50), "p95": pct(0.95),
                "max": lat[-1] if lat else None,
            },
        }
