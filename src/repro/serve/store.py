"""Versioned resident encoded DB — the serving-side state of the count server.

The paper frames multitude-targeted mining as answering "the count of a given
large list of itemsets" — a query workload.  ``VersionedDB`` keeps one encoded
bitmap RESIDENT between queries (the serving analogue of the encoded-DB
technique of Danessh et al. 2010) instead of re-encoding per call:

  * the **base** segment is a device ``DenseDB``, host ``StreamingDB``, or
    disk ``SpilledDB`` (``mining/spill.py``: mmap segment files + async
    prefetch), selected by encoded size (same threshold discipline as the
    mining stack; the spill tier needs a configured ``spill_dir`` and engages
    past ``spill_threshold_bytes`` of host RAM);
  * ``append(transactions)`` encodes a new batch under a TAIL-EXTENDED vocab
    (existing bit columns never move, so resident rows stay valid without
    re-encoding), dedups it against the current tail **delta** segment, and
    bumps the monotonically increasing ``version``;
  * the delta is folded into the base (full re-dedup + residency reselection)
    once it grows past ``merge_ratio`` of the base AND the ``min_compact_rows``
    floor (a cold store must not pay a full rebuild per tiny append) — until
    then every counting sweep COMPOSES base + delta: counts are int32 sums, so
    the composition is bit-identical to a fresh encode of the concatenated
    history.  With ``background_compaction=True`` the fold runs on an
    :class:`~repro.serve.compactor.AsyncCompactor` thread (snapshot under
    ``_store_lock``, build off-lock, epoch-checked commit), so ``append``
    returns without paying it;
  * ``counts`` / ``counts_masks`` answer a (K, W) target block with (K, C)
    per-class counts, exact at the current version.

``version`` is the cache key half of the serving cache (``serve.cache``): any
append invalidates by construction, and pure compaction does NOT bump the
version because it cannot change any count.

``serve.shard.ShardedDB`` scales this store past one device: row-partitioned
``VersionedDB`` shards behind one logical version, counts all-reduced — the
same additivity argument that makes the base+delta composition below exact.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Hashable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..kernels.itemset_count import itemset_counts
from ..mining.backend import CountBackend
from ..obs import REGISTRY, TRACER
from ..mining.dense import DenseDB
from ..mining.encode import (ItemVocab, class_weights, dedup_rows,
                             encode_bitmap, extend_vocab, pad_words)
from ..mining.spill import (DEFAULT_SPILL_THRESHOLD_BYTES, SpilledDB,
                            spilled_counts)
from ..mining.stream import StreamingDB, streaming_counts
from .compactor import AsyncCompactor

Item = Hashable

# Auto-compaction floor: below this many delta rows an append never triggers
# the fold, whatever merge_ratio says — a cold/tiny base would otherwise pay
# a full re-dedup + residency rebuild on EVERY append (bootstrap thrash).
# Explicit compact() calls ignore the floor.
DEFAULT_MIN_COMPACT_ROWS = 1024

_M_APPENDS = REGISTRY.counter("store_appends_total")
_M_APPEND_ROWS = REGISTRY.counter("store_appended_rows_total")
_M_COMPACTIONS = REGISTRY.counter("store_compactions_total")
_M_FAILED_COMPACTIONS = REGISTRY.counter("store_failed_compactions_total")
_H_APPEND_MS = REGISTRY.histogram("store_append_ms")


def check_class_labels(classes: Optional[Sequence[int]],
                       n_classes: Optional[int]) -> int:
    """Validate class labels BEFORE any store state is touched; returns the
    resolved ``n_classes``.

    A negative label (or a label ≥ an explicitly passed ``n_classes``) must
    raise the documented no-trace ``ValueError`` here, at the store boundary —
    not deep inside ``class_weights`` after vocab/total bookkeeping has begun,
    and never by scattering out of bounds or silently truncating a
    non-integral label."""
    if n_classes is not None and n_classes <= 0:
        raise ValueError(f"n_classes must be positive, got {n_classes}")
    if classes is not None and len(classes):
        y = np.asarray(classes)
        yi = y.astype(np.int64)
        if not np.array_equal(yi, y):
            raise ValueError("class labels must be integers")
        lo, hi = int(yi.min()), int(yi.max())
        if lo < 0:
            raise ValueError(f"negative class label {lo}")
        if n_classes is None:
            n_classes = hi + 1
        elif hi >= n_classes:
            raise ValueError(
                f"class label {hi} out of range for n_classes={n_classes}")
    return n_classes or 1


class VersionedDB:
    """Resident encoded bitmap + vocab with versioned incremental appends."""

    def __init__(
        self,
        transactions: Sequence[Sequence[Item]] = (),
        classes: Optional[Sequence[int]] = None,
        n_classes: Optional[int] = None,
        vocab: Optional[ItemVocab] = None,
        *,
        use_kernel: bool = True,
        streaming: Optional[bool] = None,
        chunk_rows: Optional[int] = None,
        stream_threshold_bytes: Optional[int] = None,
        merge_ratio: float = 0.25,
        min_compact_rows: Optional[int] = None,
        spill: Optional[bool] = None,
        spill_dir: Optional[str] = None,
        spill_threshold_bytes: Optional[int] = None,
        background_compaction: bool = False,
    ):
        self.n_classes = check_class_labels(classes, n_classes)
        self.use_kernel = use_kernel
        self.chunk_rows = chunk_rows
        self.merge_ratio = merge_ratio
        self.min_compact_rows = (DEFAULT_MIN_COMPACT_ROWS
                                 if min_compact_rows is None
                                 else int(min_compact_rows))
        self._streaming = streaming
        self._stream_threshold = stream_threshold_bytes
        # disk tier: spill=None engages past spill_threshold_bytes when a
        # directory is configured; True forces it; False disables it
        self._spill = spill
        self._spill_dir = (spill_dir if spill_dir is not None
                           else os.environ.get("REPRO_SPILL_DIR"))
        self._spill_threshold = spill_threshold_bytes
        self._spill_gen = 0
        # one re-entrant lock over base/delta/counter state: cheap when
        # uncontended, required once the background compactor can race an
        # append or a composed sweep
        self._store_lock = threading.RLock()
        self.version = 0
        self.n_rows = 0
        self.kernel_launches = 0
        self.n_appends = 0
        self.n_compactions = 0
        self.n_failed_compactions = 0
        self.last_compaction_error: Optional[str] = None
        self._delta_bits: Optional[np.ndarray] = None   # (D, W) uint32, host
        self._delta_weights: Optional[np.ndarray] = None  # (D, C) int32
        self._delta_device = None   # (bits, weights) device mirror, lazy
        self._class_totals = np.zeros(self.n_classes, np.int64)
        # the adaptive chooser's residency decision for the CURRENT base
        # (None when residency was explicitly forced by the caller)
        self.backend_choice = None

        transactions = [list(t) for t in transactions]
        self.vocab = vocab if vocab is not None else \
            ItemVocab.from_transactions(transactions)
        ub, uw = self._encode_batch(transactions, classes)
        self._class_totals = self._guard_totals(
            self._class_totals + uw.sum(axis=0, dtype=np.int64))
        self.n_rows = len(transactions)
        self.base = self._make_base(ub, uw)
        self._compactor: Optional[AsyncCompactor] = (
            AsyncCompactor(self) if background_compaction else None)

    def close(self) -> None:
        """Drain and stop the background compactor (if any).  The store
        stays fully usable afterwards (compaction reverts to inline)."""
        if self._compactor is not None:
            self._compactor.close()
            self._compactor = None

    @staticmethod
    def _guard_totals(totals: np.ndarray) -> np.ndarray:
        # largest possible count = per-class weight-column total; the int32
        # accumulator must hold it (construction AND every append)
        if np.any(totals > np.iinfo(np.int32).max):
            raise OverflowError(
                "per-class row totals would exceed int32; served counts "
                "could wrap — shard the store instead")
        return totals

    # -- encode ---------------------------------------------------------------
    def _encode_batch(self, transactions, classes, vocab=None):
        if classes is None or len(transactions) == 0:
            if self.n_classes != 1 and len(transactions):
                # ones in EVERY class column would count each row per class
                raise ValueError(
                    "classes are required on a multi-class store "
                    f"(n_classes={self.n_classes})")
            w = np.ones((len(transactions), self.n_classes), np.int32)
        else:
            if len(classes) != len(transactions):
                raise ValueError("classes length != transactions length")
            w = class_weights(classes, self.n_classes)
        bits = encode_bitmap(transactions,
                             self.vocab if vocab is None else vocab)
        return dedup_rows(bits, w)

    def _spill_threshold_resolved(self) -> Optional[int]:
        """The host-RAM budget past which the base spills, or ``None`` when
        the disk tier is unavailable (no directory configured / disabled)."""
        if self._spill is False or self._spill_dir is None:
            return None
        return (DEFAULT_SPILL_THRESHOLD_BYTES if self._spill_threshold is None
                else int(self._spill_threshold))

    def _residency_for(self, bits, weights, vocab) -> str:
        """Pick ``"dense"`` / ``"streaming"`` / ``"spilled"`` for a candidate
        base.  Explicit ``spill=True`` wins; otherwise a configured spill
        budget caps host residency (even forced-streaming bases), and with
        nothing explicit the adaptive chooser decides from measured traits."""
        if self._spill is True:
            if self._spill_dir is None:
                raise ValueError(
                    "spill=True requires spill_dir= (or $REPRO_SPILL_DIR)")
            self.backend_choice = None
            return "spilled"
        spill_thr = self._spill_threshold_resolved()
        stream = self._streaming
        if stream is None and self.chunk_rows is not None:
            # explicit chunk_rows opts in, mirroring _resolve_streaming in
            # the mining stack
            stream = True
        if stream is None:
            # adaptive residency: the chooser measures the encoded rows
            # (footprint, density, skew, compressibility) instead of the old
            # bare size threshold.  Non-residency verdicts (gfp/dense) keep
            # the base device-dense — the measured choice itself is kept
            # (stats + CountServer.mine consult it for the engine pick)
            from ..mining.chooser import DatasetTraits, choose_backend
            traits = DatasetTraits.measure(bits, weights, vocab, self.n_rows)
            self.backend_choice = choose_backend(
                traits, stream_threshold_bytes=self._stream_threshold,
                spill_threshold_bytes=spill_thr)
            if self.backend_choice.name in ("streaming", "spilled"):
                return self.backend_choice.name
            return "dense"
        self.backend_choice = None
        if spill_thr is not None and \
                int(bits.nbytes + weights.nbytes) > spill_thr:
            return "spilled"
        return "streaming" if stream else "dense"

    def _make_base(self, bits: np.ndarray, weights: np.ndarray, vocab=None):
        vocab = self.vocab if vocab is None else vocab
        residency = self._residency_for(bits, weights, vocab)
        if residency == "spilled":
            # generation directories: the new base lands in a fresh gen, the
            # replaced one is deleted AFTER the swap (build-before-drop on
            # disk too); the counter bump is atomic so a background build
            # and an explicit compact() never share a directory
            with self._store_lock:
                gen = self._spill_gen
                self._spill_gen += 1
            gen_dir = os.path.join(self._spill_dir, f"gen{gen:05d}")
            return SpilledDB.spill(vocab, bits, weights, self.n_rows,
                                   self.n_classes, gen_dir,
                                   chunk_rows=self.chunk_rows)
        if residency == "streaming":
            return StreamingDB.from_arrays(vocab, bits, weights,
                                           self.n_rows, self.n_classes,
                                           chunk_rows=self.chunk_rows)
        return DenseDB.from_arrays(vocab, bits, weights,
                                   self.n_rows, self.n_classes)

    # -- introspection --------------------------------------------------------
    @property
    def resident(self) -> str:
        if isinstance(self.base, SpilledDB):
            return "spilled"
        return "streaming" if isinstance(self.base, StreamingDB) else "dense"

    @property
    def base_rows(self) -> int:
        # a spilled base answers from its manifest — never touch the disk
        # just to report a row count
        u = getattr(self.base, "n_unique", None)
        return int(u) if u is not None else int(self.base.bits.shape[0])

    def _base_width(self) -> int:
        w = getattr(self.base, "n_words", None)
        return int(w) if w is not None else int(self.base.bits.shape[1])

    @property
    def delta_rows(self) -> int:
        return 0 if self._delta_bits is None else int(self._delta_bits.shape[0])

    @property
    def nbytes(self) -> int:
        # .nbytes is metadata on numpy/jax arrays — and a manifest fact on a
        # spilled base: no D2H copy or disk read just to report a size
        if isinstance(self.base, SpilledDB):
            base = int(self.base.nbytes)
        else:
            base = int(self.base.bits.nbytes + self.base.weights.nbytes)
        if self._delta_bits is not None:
            base += self._delta_bits.nbytes + self._delta_weights.nbytes
        return base

    def stats(self) -> dict:
        # compactor stats are read BEFORE taking the store lock: its own _mu
        # orders after _store_lock (request() under append), and a
        # stats-name-resolved call under the held lock would hand repro-lint
        # a reversed edge
        comp = None if self._compactor is None else self._compactor.stats()
        with self._store_lock:
            out = {
                "version": self.version, "n_rows": self.n_rows,
                "n_classes": self.n_classes, "vocab_size": self.vocab.size,
                "resident": self.resident, "base_rows": self.base_rows,
                "delta_rows": self.delta_rows, "nbytes": self.nbytes,
                "kernel_launches": self.kernel_launches,
                "appends": self.n_appends, "compactions": self.n_compactions,
                "failed_compactions": self.n_failed_compactions,
                "last_compaction_error": self.last_compaction_error,
                "min_compact_rows": self.min_compact_rows,
                "backend_choice": (None if self.backend_choice is None
                                   else self.backend_choice.name),
                "spill": (None if not isinstance(self.base, SpilledDB) else {
                    "directory": self.base.directory,
                    "segments": self.base.n_chunks,
                    "chunk_rows": self.base.chunk_rows,
                    "disk_bytes": self.base.nbytes,
                }),
                "compactor": comp,
            }
        return out

    # -- append ---------------------------------------------------------------
    def append(
        self,
        transactions: Sequence[Sequence[Item]],
        classes: Optional[Sequence[int]] = None,
    ) -> int:
        """Fold a new batch in; returns the new (bumped) ``version``.

        The batch is encoded under the tail-extended vocab, deduped against
        the current delta tail, and kept as the delta segment until the
        ``merge_ratio`` compaction threshold folds it into the base.
        An empty batch is a no-op (version unchanged: no count can differ).
        """
        transactions = [list(t) for t in transactions]
        if not transactions:
            return self.version
        t0 = time.perf_counter()
        # validate + encode BEFORE touching any store state: a rejected batch
        # must leave no trace (no vocab tail, no totals, no version bump).
        # Label-range validation comes first — the store's n_classes is fixed,
        # so an out-of-range label can never be folded in
        check_class_labels(classes, self.n_classes)
        vocab = extend_vocab(transactions, self.vocab)
        ub, uw = self._encode_batch(transactions, classes, vocab)
        with self._store_lock:
            totals = self._guard_totals(
                self._class_totals + uw.sum(axis=0, dtype=np.int64))
            self.vocab = vocab
            self._class_totals = totals

            w_now = self.vocab.n_words
            if self._delta_bits is not None:
                # dedup against the tail: one growing delta segment
                ub, uw = dedup_rows(
                    np.concatenate([pad_words(self._delta_bits, w_now), ub]),
                    np.concatenate([self._delta_weights, uw]))
            self._delta_bits, self._delta_weights = ub, uw
            self._delta_device = None
            self.n_rows += len(transactions)
            self.n_appends += 1
            self.version += 1
            _M_APPENDS.inc()
            _M_APPEND_ROWS.inc(len(transactions))
            # merge_ratio decides WHEN the fold pays; min_compact_rows keeps
            # a cold/tiny base from re-deduping the world on every append
            if self.delta_rows >= self.min_compact_rows and \
                    self.delta_rows > self.merge_ratio * max(1, self.base_rows):
                if self._compactor is not None:
                    # off the serving path: the append returns now, the
                    # compactor thread snapshots/builds/commits behind
                    # _store_lock (epoch-checked, failure-safe)
                    self._compactor.request()
                else:
                    try:
                        self.compact()
                    except Exception as e:
                        # compaction is a pure optimization and compact() is
                        # failure-safe (the new base is built BEFORE the
                        # delta drops), so the store still serves exact
                        # counts from base+delta.  The batch IS committed at
                        # this point — an escaping compactor error would
                        # masquerade as a rejected append and invite a
                        # double-counting retry.
                        self.n_failed_compactions += 1
                        self.last_compaction_error = f"{type(e).__name__}: {e}"
                        _M_FAILED_COMPACTIONS.inc()
        _H_APPEND_MS.observe((time.perf_counter() - t0) * 1e3)
        return self.version

    def compact(self) -> None:
        """Fold the delta into the base: full re-dedup at the current vocab
        width, then residency reselection (dense vs streaming vs spilled) by
        size.  Pure compaction — counts (and therefore ``version``) are
        unchanged.  Explicit calls ignore the ``min_compact_rows`` floor
        (the floor gates only append-triggered auto-compaction)."""
        with self._store_lock, \
                TRACER.span("store.compact",
                            {"base_rows": self.base_rows,
                             "delta_rows": self.delta_rows}):
            w_now = self.vocab.n_words
            base_bits = pad_words(np.asarray(self.base.bits), w_now)
            base_w = np.asarray(self.base.weights)
            had_delta = self._delta_bits is not None
            if had_delta:
                base_bits = np.concatenate([base_bits, self._delta_bits])
                base_w = np.concatenate([base_w, self._delta_weights])
            ub, uw = dedup_rows(base_bits, base_w)
            # build the new base BEFORE dropping the delta: a failure here
            # (e.g. device OOM at residency reselection) must leave the
            # composed base+delta counts intact, not silently lose the
            # delta rows
            old = self.base
            self.base = self._make_base(ub, uw)
            if had_delta:
                self._delta_bits = self._delta_weights = None
                self._delta_device = None
                self.n_compactions += 1
                _M_COMPACTIONS.inc()
        self._drop_spilled(old)

    def _drop_spilled(self, old_base) -> None:
        """Delete a REPLACED spilled generation's segment directory.  Only
        after the swap (on-disk build-before-drop), and never fatally — a
        leaked directory is recoverable garbage, a crashed serve path is
        not."""
        if old_base is self.base or not isinstance(old_base, SpilledDB):
            return
        try:
            old_base.delete()
        except OSError as e:
            with self._store_lock:
                self.last_compaction_error = f"spill cleanup: {e}"

    def _compact_pass(self) -> bool:
        """One background compaction attempt (the ``AsyncCompactor``'s unit
        of work).  Snapshot under the lock, build off-lock, commit under the
        lock only if no append (or other compaction) landed in between.

        Returns ``True`` when done (committed, nothing to do, or build
        failed — failures are absorbed into ``last_compaction_error`` /
        ``n_failed_compactions``, the delta stays intact) and ``False`` when
        a concurrent append invalidated the build (caller may retry)."""
        with self._store_lock:
            if self._delta_bits is None:
                return True
            epoch = (self.n_appends, self.n_compactions)
            vocab = self.vocab
            base = self.base
            dbits, dw = self._delta_bits, self._delta_weights
        new_base = None
        try:
            with TRACER.span("store.bg_compact",
                             {"delta_rows": int(dbits.shape[0])}):
                w_now = vocab.n_words
                bits = np.concatenate(
                    [pad_words(np.asarray(base.bits), w_now),
                     pad_words(dbits, w_now)])
                w = np.concatenate([np.asarray(base.weights), dw])
                ub, uw = dedup_rows(bits, w)
                new_base = self._make_base(ub, uw, vocab=vocab)
        except Exception as e:
            with self._store_lock:
                self.n_failed_compactions += 1
                self.last_compaction_error = f"{type(e).__name__}: {e}"
            _M_FAILED_COMPACTIONS.inc()
            return True
        with self._store_lock:
            if (self.n_appends, self.n_compactions) != epoch:
                committed = False
            else:
                self.base = new_base
                self._delta_bits = self._delta_weights = None
                self._delta_device = None
                self.n_compactions += 1
                committed = True
        if committed:
            _M_COMPACTIONS.inc()
            self._drop_spilled(base)
            return True
        # a concurrent append won the race: this build counts rows that are
        # no longer the whole story — discard it (and its on-disk gen)
        if isinstance(new_base, SpilledDB):
            new_base.delete()
        return False

    # -- counting -------------------------------------------------------------
    def _narrow(self, masks: np.ndarray, w_seg: int):
        """Truncate (K, W_now) masks to a segment's width.  Targets with bits
        beyond the segment width reference items the segment predates — their
        count over that segment is exactly 0 (returned as ``oob``)."""
        if masks.shape[1] <= w_seg:
            return masks, None
        oob = masks[:, w_seg:].any(axis=1)
        return np.ascontiguousarray(masks[:, :w_seg]), oob

    @staticmethod
    def _zero_oob(got: np.ndarray, oob: Optional[np.ndarray]) -> np.ndarray:
        if oob is None:
            return got
        got = np.array(got)   # np.asarray(device array) can be read-only
        got[oob] = 0
        return got

    def counts_masks(self, masks: np.ndarray,
                     block_k: Optional[int] = None) -> np.ndarray:
        """(K, C) exact per-class counts for a (K, W_now) target block,
        composed over base + delta segments (bit-identical to a fresh encode
        of the full history: int32 sums commute with row partitioning).
        ``block_k`` forwards the caller's K-block size to the kernel so a
        block that was padded for it launches as one K-block."""
        k = int(masks.shape[0])
        if k == 0:
            return np.zeros((0, self.n_classes), np.int32)
        bk = {} if block_k is None else {"block_k": block_k}
        total = np.zeros((k, self.n_classes), np.int32)
        # the whole sweep runs under the store lock so a background commit
        # cannot swap the base mid-composition (base counted pre-compaction
        # + delta counted post-compaction would double-count the fold)
        with self._store_lock:
            # base segment
            if self.base_rows:
                narrow, oob = self._narrow(masks, self._base_width())
                if isinstance(self.base, (StreamingDB, SpilledDB)):
                    got = np.asarray(self.base.counts(
                        narrow, use_kernel=self.use_kernel, **bk))
                    self.kernel_launches += self.base.n_chunks
                else:
                    got = np.asarray(itemset_counts(
                        self.base.bits, jnp.asarray(narrow), self.base.weights,
                        use_kernel=self.use_kernel, **bk))
                    self.kernel_launches += 1
                total += self._zero_oob(got, oob)
            # delta segment (bounded by merge_ratio * base_rows: one launch);
            # its device mirror persists between appends — queries don't pay a
            # fresh H2D upload of identical delta bytes on every flush
            if self._delta_bits is not None:
                narrow, oob = self._narrow(masks, self._delta_bits.shape[1])
                if self._delta_device is None:
                    self._delta_device = (jnp.asarray(self._delta_bits),
                                          jnp.asarray(self._delta_weights))
                d_bits, d_weights = self._delta_device
                got = np.asarray(itemset_counts(
                    d_bits, jnp.asarray(narrow), d_weights,
                    use_kernel=self.use_kernel, **bk))
                self.kernel_launches += 1
                total += self._zero_oob(got, oob)
        return total

    def counts(self, itemsets: Sequence[Sequence[Item]]) -> np.ndarray:
        """(K, C) counts for raw itemsets.  Itemsets naming items absent from
        the vocab count 0 (the paper's note: such targets never appear in the
        FP-tree), matching ``dense_gfp_counts``.  One unknown-target contract,
        shared with the flush path: ``build_masks`` + zeroing."""
        return counts_for_itemsets(self, itemsets)


def counts_for_itemsets(store, itemsets: Sequence[Sequence[Item]]
                        ) -> np.ndarray:
    """The ONE raw-itemset counting contract over any serving store (a
    ``VersionedDB`` or a ``ShardedDB``: anything with ``vocab`` /
    ``n_classes`` / ``counts_masks``): encode under the store vocab, count,
    and zero targets naming never-seen items — whose exact count is 0."""
    from .batcher import build_masks

    if not len(itemsets):
        return np.zeros((0, store.n_classes), np.int32)
    masks, known = build_masks([tuple(s) for s in itemsets], store.vocab,
                               block_k=1)
    out = np.array(store.counts_masks(masks)[:len(itemsets)], np.int32)
    out[~known] = 0
    return out


class VersionedCountBackend(CountBackend):
    """:class:`~repro.mining.backend.CountBackend` over a :class:`VersionedDB`
    — the seam that lets the unified mining driver (``mining/driver.py``) run
    against the serving store's composed base+delta sweep, so it is exact
    mid-append without compaction.

    Chunk layout for mid-level checkpoint resume: the base segment's chunks
    first (the ``StreamingDB`` chunk grid when the base is host-resident, one
    chunk when device-dense), then one chunk for the delta segment.  The
    ``mine_signature`` pins the store ``version``: a checkpoint resumed after
    an ``append`` is discarded wholesale (levels counted at an older version
    are not valid progress), while pure compaction — which changes the chunk
    geometry but no count — only restarts the in-flight level from chunk 0.
    """

    def __init__(self, store: VersionedDB):
        self.store = store

    @property
    def vocab(self) -> ItemVocab:
        return self.store.vocab

    @property
    def n_rows(self) -> int:
        return self.store.n_rows

    @property
    def n_classes(self) -> int:
        return self.store.n_classes

    @property
    def nbytes(self) -> int:
        return self.store.nbytes

    def _base_chunks(self) -> int:
        if not self.store.base_rows:
            return 0
        return (self.store.base.n_chunks
                if isinstance(self.store.base, (StreamingDB, SpilledDB))
                else 1)

    @property
    def n_count_chunks(self) -> int:
        delta = 1 if self.store._delta_bits is not None else 0
        return max(1, self._base_chunks() + delta)

    def chunk_signature(self) -> dict:
        base = self.store.base
        return {
            "backend": "versioned", "version": self.store.version,
            "base_rows": self.store.base_rows,
            "delta_rows": self.store.delta_rows,
            "chunk_rows": (base.chunk_rows
                           if isinstance(base, (StreamingDB, SpilledDB))
                           else None),
        }

    def mine_signature(self) -> dict:
        return {"version": self.store.version}

    def traits(self):
        """Measured traits over the composed base+delta rows (the same rows
        every sweep counts), for the adaptive engine pick in
        ``CountServer.mine``."""
        from dataclasses import replace as _dc_replace

        from ..mining.chooser import TRAIT_SAMPLE_ROWS, DatasetTraits

        store = self.store
        with store._store_lock:
            w_now = store.vocab.n_words
            if isinstance(store.base, SpilledDB):
                # sample the head segment instead of materializing the whole
                # spilled base from disk; patch in the TRUE footprint so the
                # chooser sees real size, not the sample's
                bits, wts = store.base.head(TRAIT_SAMPLE_ROWS)
                bits = pad_words(bits, w_now)
                if store._delta_bits is not None:
                    bits = np.concatenate(
                        [bits, pad_words(store._delta_bits, w_now)])
                    wts = np.concatenate([wts, store._delta_weights])
                t = DatasetTraits.measure(bits, wts, store.vocab,
                                          store.n_rows)
                u = store.base_rows + store.delta_rows
                return _dc_replace(
                    t, nbytes=store.nbytes, n_unique=u,
                    dedup_ratio=(u / store.n_rows if store.n_rows else 1.0))
            bits = pad_words(np.asarray(store.base.bits), w_now)
            wts = np.asarray(store.base.weights)
            if store._delta_bits is not None:
                bits = np.concatenate(
                    [bits, pad_words(store._delta_bits, w_now)])
                wts = np.concatenate([wts, store._delta_weights])
            return DatasetTraits.measure(bits, wts, store.vocab, store.n_rows)

    def counts(self, masks: np.ndarray, *, start_chunk: int = 0,
               init: Optional[np.ndarray] = None, on_chunk=None) -> np.ndarray:
        store = self.store
        k = int(masks.shape[0])
        total = (np.zeros((k, store.n_classes), np.int32) if init is None
                 else np.array(np.asarray(init), np.int32))
        if k == 0:
            return total
        # under the store lock: a background compaction commit mid-sweep
        # would change the chunk grid (and double-count the folded delta)
        with store._store_lock:
            nb = self._base_chunks()
            if nb and start_chunk < nb:
                narrow, oob = store._narrow(masks, store._base_width())
                if isinstance(store.base, (StreamingDB, SpilledDB)):
                    hook = None
                    if on_chunk is not None:
                        def hook(i, acc):
                            a = np.asarray(acc)
                            if i == nb - 1:
                                # the saved boundary accumulator must already
                                # be the finished base block (oob rows
                                # zeroed): a resume at start_chunk == nb adds
                                # delta directly
                                a = store._zero_oob(a, oob)
                            on_chunk(i, a)
                    if isinstance(store.base, SpilledDB):
                        acc = spilled_counts(
                            store.base, narrow, use_kernel=store.use_kernel,
                            start_chunk=start_chunk, init=total,
                            on_chunk=hook)
                    else:
                        acc = streaming_counts(
                            store.base.bits, narrow, store.base.weights,
                            chunk_rows=store.base.chunk_rows,
                            use_kernel=store.use_kernel,
                            start_chunk=start_chunk, init=total,
                            on_chunk=hook)
                    store.kernel_launches += nb - start_chunk
                    total = store._zero_oob(np.asarray(acc), oob)
                else:
                    got = np.asarray(itemset_counts(
                        store.base.bits, jnp.asarray(narrow),
                        store.base.weights, use_kernel=store.use_kernel))
                    store.kernel_launches += 1
                    total = total + store._zero_oob(got, oob)
                    if on_chunk is not None:
                        on_chunk(0, total)
            if store._delta_bits is not None and start_chunk <= nb:
                narrow, oob = store._narrow(masks, store._delta_bits.shape[1])
                if store._delta_device is None:
                    store._delta_device = (jnp.asarray(store._delta_bits),
                                           jnp.asarray(store._delta_weights))
                d_bits, d_weights = store._delta_device
                got = np.asarray(itemset_counts(
                    d_bits, jnp.asarray(narrow), d_weights,
                    use_kernel=store.use_kernel))
                store.kernel_launches += 1
                total = total + store._zero_oob(got, oob)
                if on_chunk is not None:
                    on_chunk(nb, total)
            elif nb == 0 and start_chunk == 0 and on_chunk is not None:
                # empty store: n_count_chunks still claims a 1-chunk grid, so
                # the (trivially exact, all-zero) sweep must COMPLETE that
                # chunk — otherwise a checkpointed mine records zero chunk
                # progress against a claimed chunk and the partial never
                # becomes resumable
                on_chunk(0, total)
        return total
