"""The GFP count server: micro-batched count serving, sync or async/sharded.

``CountServer`` ties the serving subsystem together:

  * :class:`~repro.serve.store.VersionedDB` — the resident encoded DB
    (device-dense or host-streaming by size) with versioned appends — or,
    with ``shards=``, a :class:`~repro.serve.shard.ShardedDB` spanning
    row-partitioned shards (optionally laid out over a device mesh), counts
    all-reduced exactly;
  * :class:`~repro.serve.batcher.MicroBatcher` — ``submit()`` queues
    (client_id, itemsets) requests, ``flush()`` answers them all with ONE
    composed counting pass (cross-client deduped, block_k-padded);
  * :class:`~repro.serve.cache.CountCache` — (itemset, version)-keyed LRU so
    repeated hot queries skip the device entirely; ``append`` invalidates by
    bumping the version;
  * with ``async_flush=True``, an :class:`~repro.serve.async_loop.AsyncFlusher`
    — ``submit_async()`` returns a future, a background thread flushes on
    occupancy (``min_batch``) or deadline (``max_delay_ms``), and ``close()``
    drains every pending ticket.  All state-touching operations then
    serialize behind one re-entrant lock.

Served counts are EXACT: every row equals a fresh ``dense_gfp_counts`` /
brute-force run over the full transaction history at the same version.

Incremental re-mining (paper §5.2): ``mine(theta)`` bootstraps the frequent
set on the resident engine; after each ``append`` the server re-establishes
it from the pigeonhole candidate set (``incremental_candidates`` — the same
pure function the host ``IncrementalMiner`` uses), recounting the candidates
through the dense/streaming engine in one guided batch instead of host
FP-tree walks.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, Hashable, List, Optional, Sequence, Tuple, Union

import numpy as np

from .. import obs
from ..core.fpgrowth import mine_frequent
from ..core.incremental import ceil_count, incremental_candidates
from ..obs import REGISTRY, TRACER
from .async_loop import AsyncFlusher, CountFuture
from .batcher import MicroBatcher, build_masks, canonical_itemset
from .cache import CountCache
from .shard import ShardedCountBackend, ShardedDB
from .store import VersionedDB

Item = Hashable
Key = Tuple[Item, ...]

_H_FLUSH_MS = REGISTRY.histogram("serve_flush_ms")
_M_APPENDS = REGISTRY.counter("serve_appends_total")


class MiningRefreshError(RuntimeError):
    """Raised by ``CountServer.append`` when the batch WAS committed to the
    store (``version`` is the new version) but the §5.2 frequent-set refresh
    failed and incremental maintenance was disarmed.  Distinguishes
    'committed, re-mine needed' from a rejected append (which raises
    ``ValueError``/``OverflowError`` and leaves no trace) — do NOT retry the
    append, the rows would be double-counted."""

    def __init__(self, version: int, cause: BaseException):
        super().__init__(
            f"batch committed at version {version}, but the frequent-set "
            f"refresh failed ({cause!r}); incremental mining disarmed — "
            "call mine() to re-arm, do not retry the append")
        self.version = version


def versioned_mine_frequent(
    store: Union[VersionedDB, ShardedDB],
    min_count: float,
    *,
    class_column: Optional[int] = None,
    max_len: int = 0,
    checkpoint=None,                 # Optional[MiningCheckpoint]
    on_chunk=None,
) -> Dict[Key, int]:
    """Level-synchronous exact mining over a :class:`VersionedDB` (or a
    :class:`~repro.serve.shard.ShardedDB`) — a shim over the unified driver
    (``mining/driver.py``) with the store-composed
    :class:`~repro.serve.store.VersionedCountBackend` (resp.
    :class:`~repro.serve.shard.ShardedCountBackend`): the same contract as
    ``dense_mine_frequent`` but counting through the store's composed
    base+delta sweep, so it is correct mid-append without compaction.

    With a ``checkpoint``, progress is durable at the store's chunk
    granularity (base chunks + delta chunk, or one chunk per shard) and
    PINNED to the store version: a killed mine resumes mid-level at the same
    version, while a resume after an ``append`` discards the stale state and
    restarts cleanly."""
    from ..mining.driver import mine_frequent as _driver_mine
    from .store import VersionedCountBackend

    backend = (ShardedCountBackend(store) if isinstance(store, ShardedDB)
               else VersionedCountBackend(store))
    return _driver_mine(backend, min_count,
                        class_column=class_column, max_len=max_len,
                        checkpoint=checkpoint, on_chunk=on_chunk)


class CountServer:
    """Driver loop: ``submit`` / ``flush`` / ``append`` / ``stats`` — plus
    ``submit_async`` / ``close`` when ``async_flush`` is on."""

    def __init__(
        self,
        transactions: Sequence[Sequence[Item]] = (),
        classes: Optional[Sequence[int]] = None,
        n_classes: Optional[int] = None,
        *,
        use_kernel: bool = True,
        streaming: Optional[bool] = None,
        chunk_rows: Optional[int] = None,
        cache_size: int = 65536,
        cache_bytes: Optional[int] = None,
        cache: bool = True,
        block_k: Optional[int] = None,
        merge_ratio: float = 0.25,
        min_compact_rows: Optional[int] = None,
        spill_dir: Optional[str] = None,
        spill_threshold_bytes: Optional[int] = None,
        background_compaction: bool = False,
        shards: Optional[int] = None,
        mesh=None,
        async_flush: bool = False,
        max_delay_ms: float = 5.0,
        min_batch: int = 8,
    ):
        if shards is not None:
            if spill_dir is not None or spill_threshold_bytes is not None:
                # shards ARE the residency decision: rows too big for one
                # device get partitioned, not spilled per-shard
                raise ValueError("spill_dir/spill_threshold_bytes require "
                                 "an unsharded store (shards=None)")
            self.store: Union[VersionedDB, ShardedDB] = ShardedDB(
                transactions, classes=classes, n_classes=n_classes,
                n_shards=shards, mesh=mesh, use_kernel=use_kernel,
                streaming=streaming, chunk_rows=chunk_rows,
                merge_ratio=merge_ratio, min_compact_rows=min_compact_rows)
        elif mesh is not None:
            raise ValueError("mesh= requires shards=")
        else:
            self.store = VersionedDB(
                transactions, classes=classes, n_classes=n_classes,
                use_kernel=use_kernel, streaming=streaming,
                chunk_rows=chunk_rows, merge_ratio=merge_ratio,
                min_compact_rows=min_compact_rows, spill_dir=spill_dir,
                spill_threshold_bytes=spill_threshold_bytes,
                background_compaction=background_compaction)
        if block_k is None:
            # tune the serve pad size to the resident geometry: the table is
            # keyed on the bucket the store's sweeps will actually launch
            from ..roofline import autotune
            block_k = autotune.resolve_serve_block_k(self.store)
        self.batcher = MicroBatcher(block_k=block_k)
        self.cache: Optional[CountCache] = \
            CountCache(cache_size, max_bytes=cache_bytes) if cache else None
        self.n_flushes = 0
        self.n_queries_served = 0
        self.last_backend_choice = None   # BackendChoice of the last mine()
        self._theta: Optional[float] = None
        self._frequent: Dict[Key, int] = {}
        # every state-touching op serializes behind ONE re-entrant lock when
        # a background flusher can race it; sync-only servers pay nothing
        self._lock = (threading.RLock() if async_flush
                      else contextlib.nullcontext())
        self._flusher: Optional[AsyncFlusher] = (
            AsyncFlusher(self, max_delay_ms=max_delay_ms,
                         min_batch=min_batch) if async_flush else None)

    # -- query path -----------------------------------------------------------
    def submit(self, client_id: str,
               itemsets: Sequence[Sequence[Item]]) -> int:
        """Queue one client request; returns the ticket ``flush()`` keys on."""
        with self._lock:
            return self.batcher.submit(client_id, itemsets)

    def submit_async(self, client_id: str,
                     itemsets: Sequence[Sequence[Item]]) -> CountFuture:
        """Queue one request on the background flush loop; returns a
        :class:`~repro.serve.async_loop.CountFuture` whose ``result()``
        blocks until an occupancy-/deadline-triggered (or explicit) flush
        answers the ticket.  Requires ``async_flush=True``."""
        if self._flusher is None:
            raise RuntimeError(
                "submit_async requires CountServer(async_flush=True)")
        return self._flusher.submit(client_id, itemsets)

    def close(self) -> None:
        """Stop the background flush loop (if any) and drain every pending
        ticket.  The server stays usable synchronously afterwards."""
        if self._flusher is not None:
            self._flusher.close()
        closer = getattr(self.store, "close", None)
        if closer is not None:
            closer()   # drain + stop the store's background compactor

    def __enter__(self) -> "CountServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def flush(self) -> Dict[int, np.ndarray]:
        """Answer every pending request with one composed counting pass.

        Returns {ticket -> (len(itemsets), C) int32}, rows in each request's
        submission order.  Unique uncached targets are counted in ONE
        block_k-padded launch per resident segment; cached targets (same
        itemset, same version) never touch the device.  Async-submitted
        tickets in the batch have their futures fulfilled too, whoever
        triggered the flush — and symmetrically, a synchronously submitted
        ticket that a BACKGROUND flush drained is returned by the next
        ``flush()`` call rather than dropped.
        """
        with self._lock:
            started = time.monotonic()
            # _reason is set => this call IS the background/drain trigger,
            # whose return value is discarded — only a manual caller can
            # claim the stash of background-answered sync tickets
            manual = self._flusher is None or self._flusher._reason is None
            trigger = ("sync" if self._flusher is None
                       else (self._flusher._reason or "manual"))
            t0 = time.perf_counter()
            with TRACER.span("serve.flush", {"trigger": trigger}) as sp:
                out = self._flush_impl()
                sp.set("n_tickets", len(out))
            if out:
                _H_FLUSH_MS.observe((time.perf_counter() - t0) * 1e3)
                if self._flusher is None:
                    # async servers count flushes (by trigger) in _dispatch;
                    # the sync-only path owns its own increment
                    REGISTRY.counter("serve_flushes_total",
                                     trigger="sync").inc()
            if self._flusher is not None:
                self._flusher._dispatch(out, started=started)
                if manual:
                    out.update(self._flusher.claim_unclaimed())
            return out

    def _flush_impl(self) -> Dict[int, np.ndarray]:
        with TRACER.span("serve.dedup") as sp:
            plan = self.batcher.take()
            sp.set("n_requests", len(plan.requests))
            sp.set("n_queries", plan.n_queries)
            sp.set("n_unique", len(plan.unique_keys))
        if not plan.requests:
            return {}
        try:
            resolved = self._resolve(plan.unique_keys)
        except BaseException:
            self.batcher.restore(plan.requests)  # failed flush is retryable
            raise
        out: Dict[int, np.ndarray] = {}
        with TRACER.span("serve.reply", {"n_requests": len(plan.requests)}):
            for req in plan.requests:
                block = (np.stack([resolved[k] for k in req.keys])
                         if req.keys
                         else np.zeros((0, self.store.n_classes), np.int32))
                out[req.request_id] = block.astype(np.int32, copy=False)
        self.n_flushes += 1
        self.n_queries_served += plan.n_queries
        if self.cache is not None:
            # drain point: push the cache's plain-counter deltas into the
            # registry mirrors (the per-key get/put path is registry-free)
            self.cache.publish_metrics()
        return out

    def _resolve(self, keys: Sequence[Key]) -> Dict[Key, np.ndarray]:
        """{key -> (C,) counts} at the CURRENT version: cache hits first, one
        block_k-padded composed counting pass for the rest."""
        version = self.store.version
        resolved: Dict[Key, np.ndarray] = {}
        missing: List[Key] = []
        for key in keys:
            hit = self.cache.get(key, version) if self.cache is not None \
                else None
            if hit is not None:
                resolved[key] = hit
            else:
                missing.append(key)
        if missing:
            with TRACER.span("serve.count",
                             {"n_masks": len(missing), "version": version,
                              "cache_hits": len(keys) - len(missing)}):
                masks, known = build_masks(missing, self.store.vocab,
                                           self.batcher.block_k)
                rows = self.store.counts_masks(
                    masks, block_k=self.batcher.block_k)[:len(missing)]
                rows[~known] = 0     # unknown-item targets count exactly 0
            with TRACER.span("serve.cache_fill", {"n": len(missing)}):
                for key, row in zip(missing, rows):
                    resolved[key] = row
                    if self.cache is not None:
                        self.cache.put(key, version, row)
        elif keys:
            TRACER.instant("serve.count_skipped",
                           {"cache_hits": len(keys), "version": version})
        return resolved

    def query(self, itemsets: Sequence[Sequence[Item]],
              client_id: str = "_local") -> np.ndarray:
        """Answer one request immediately, WITHOUT draining the batcher:
        other clients' pending requests stay queued and are answered by the
        next ``flush()`` at whatever version is current then — an interleaved
        ``query()`` can neither orphan their tickets nor freeze their counts
        at an older version."""
        with self._lock, \
                TRACER.span("serve.query", {"n_itemsets": len(itemsets)}):
            keys = [canonical_itemset(s) for s in itemsets]
            resolved = self._resolve(list(dict.fromkeys(keys)))
            self.n_queries_served += len(keys)
            if not keys:
                return np.zeros((0, self.store.n_classes), np.int32)
            return np.stack([resolved[k] for k in keys]).astype(np.int32,
                                                                copy=False)

    # -- growth path ----------------------------------------------------------
    def append(self, transactions: Sequence[Sequence[Item]],
               classes: Optional[Sequence[int]] = None) -> int:
        """Fold a new batch into the resident DB (version bump ⇒ cache
        invalidation) and, if mining is active, refresh the frequent set via
        the §5.2 guided recount on the engine."""
        with self._lock, \
                TRACER.span("serve.append",
                            {"n_rows": len(transactions)}) as sp:
            transactions = [list(t) for t in transactions]
            old_version = self.store.version
            version = self.store.append(transactions, classes=classes)
            sp.set("version", version)
            _M_APPENDS.inc()
            if version != old_version and self.cache is not None:
                self.cache.purge_stale(version)  # every old-version row dead
            if self._theta is not None and transactions:
                try:
                    self._refresh_frequent(transactions)
                except Exception as e:
                    # §5.2 completeness needs the PREVIOUS exact frequent
                    # set; after a failed refresh that baseline is lost for
                    # the new version — serving the stale set would be
                    # silently wrong, so disarm and require a fresh mine().
                    # The batch itself IS committed; MiningRefreshError tells
                    # the caller not to retry.
                    self._theta = None
                    self._frequent = {}
                    raise MiningRefreshError(version, e) from e
            return version

    def _mining_backend(self, which: str):
        """Resolve the counting backend for ``mine``: the adaptive chooser
        over measured store traits (``which == "auto"``), or an explicit
        engine name.  A sharded store always mines through its own
        all-reduced backend (shards are the residency decision).  Returns
        ``(backend, BackendChoice)``."""
        from ..mining.chooser import BackendChoice, choose_backend
        from .store import VersionedCountBackend

        if isinstance(self.store, ShardedDB):
            return ShardedCountBackend(self.store), BackendChoice(
                "store", "sharded store: mine through the all-reduced "
                "composed sweep")
        composed = VersionedCountBackend(self.store)
        if which == "store":
            return composed, BackendChoice(
                "store", "explicitly requested: composed base+delta sweep")
        if which == "auto":
            choice = choose_backend(composed.traits())
        elif which in ("dense", "streaming", "spilled", "gfp", "distributed"):
            choice = BackendChoice(which, "explicitly requested")
        else:
            raise ValueError(
                f"unknown mining backend {which!r}: expected auto, store, "
                "dense, streaming, spilled, or gfp")
        if choice.name == "gfp":
            from ..mining.gfp_backend import GFPBackend
            return GFPBackend.from_store(
                self.store, use_kernel=self.store.use_kernel), choice
        # dense / streaming / spilled / distributed verdicts all mine through
        # the store's composed sweep: residency is the STORE's decision (its
        # base is already dense, streaming, or spilled by the same traits),
        # and a serving store has no mesh to shard over
        return composed, choice

    def mine(self, theta: float, *, checkpoint=None,
             class_column: Optional[int] = None,
             backend: str = "auto") -> Dict[Key, int]:
        """Bootstrap exact frequent-itemset mining at relative threshold
        ``theta``; subsequent ``append`` calls maintain it incrementally.

        ``checkpoint`` (a ``MiningCheckpoint``) makes the bootstrap RESUMABLE
        through the unified driver: over a disk-sized streaming-backed store
        the mine persists per-chunk progress, so a killed server process can
        restart and finish the bootstrap from the last completed chunk.  The
        durable state is pinned to the store version — a resume after further
        appends restarts the mine cleanly instead of serving stale levels.

        ``class_column`` restricts support to ONE class's count column (the
        MRA antecedent discovery behind ``RuleServer.top_rules``: itemsets
        with C_class >= ceil_count(theta * n_rows)).  A class-guided mine is
        a QUERY, not a baseline: it returns the frequent set without arming
        §5.2 incremental maintenance, whose pigeonhole argument is stated on
        total counts.

        ``backend`` picks the counting engine: ``"auto"`` (default) consults
        the adaptive chooser over measured store traits — the GFP-growth
        hybrid on dense/compressible/skewed data, the store's composed sweep
        otherwise; ``"store"`` forces the composed base+delta sweep;
        ``"gfp"``/``"dense"``/``"streaming"`` force an engine.  Every engine
        is exact, so the choice never changes the result (pinned by
        ``tests/test_chooser.py``); the decision taken is recorded on
        ``last_backend_choice``."""
        if not (0.0 < theta <= 1.0):
            raise ValueError("theta in (0, 1]")
        if class_column is not None and \
                not (0 <= class_column < self.store.n_classes):
            raise ValueError(
                f"class_column {class_column} out of range for "
                f"n_classes={self.store.n_classes}")
        with self._lock, \
                TRACER.span("serve.mine", {"theta": theta}) as sp:
            be, choice = self._mining_backend(backend)
            self.last_backend_choice = choice
            sp.set("backend", choice.name)
            mc = ceil_count(theta * self.store.n_rows)
            if choice.name == "gfp":
                from ..mining.driver import mine_frequent as _driver_mine
                frequent = _driver_mine(be, mc, class_column=class_column,
                                        checkpoint=checkpoint)
            else:
                # every composed verdict mines through the module-level shim
                # (module-level on purpose: it is the failure-injection seam)
                frequent = versioned_mine_frequent(
                    self.store, mc, class_column=class_column,
                    checkpoint=checkpoint)
            if class_column is None:
                # commit only after the mine succeeds: a failed mine must not
                # arm incremental maintenance over an empty/stale baseline
                self._theta, self._frequent = theta, frequent
            return dict(frequent)

    def _refresh_frequent(self, increment: List[List[Item]]) -> None:
        # Pigeonhole candidates (complete: combined-frequent ⇒ frequent in the
        # old data or in the increment), then ONE guided engine recount of all
        # candidates over the full resident history — no host FP-tree walk.
        inc_frequent = mine_frequent(
            increment, ceil_count(self._theta * len(increment)))
        previously, newly = incremental_candidates(self._frequent,
                                                   inc_frequent)
        candidates = previously + newly
        if not candidates:
            self._frequent = {}
            return
        rows = self.store.counts(candidates).sum(axis=1)
        min_total = ceil_count(self._theta * self.store.n_rows)
        self._frequent = {k: int(c) for k, c in zip(candidates, rows)
                          if int(c) >= min_total}

    @property
    def frequent(self) -> Dict[Key, int]:
        if self._theta is None:
            raise RuntimeError("call mine() first")
        return dict(self._frequent)

    # -- introspection --------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "store": self.store.stats(),
                "batcher": self.batcher.stats(),
                "cache": (self.cache.stats() if self.cache is not None
                          else None),
                "async": (self._flusher.stats() if self._flusher is not None
                          else None),
                "flushes": self.n_flushes,
                "queries_served": self.n_queries_served,
                "mining_theta": self._theta,
                "frequent_itemsets": (len(self._frequent)
                                      if self._theta is not None else None),
                # registry-backed process-wide telemetry: the raw metrics
                # snapshot plus the kernel measured-vs-predicted report
                "telemetry": obs.telemetry_section(),
            }
