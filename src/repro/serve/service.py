"""The GFP count server: synchronous micro-batched count serving.

``CountServer`` ties the serving subsystem together:

  * :class:`~repro.serve.store.VersionedDB` — the resident encoded DB
    (device-dense or host-streaming by size) with versioned appends;
  * :class:`~repro.serve.batcher.MicroBatcher` — ``submit()`` queues
    (client_id, itemsets) requests, ``flush()`` answers them all with ONE
    composed counting pass (cross-client deduped, block_k-padded);
  * :class:`~repro.serve.cache.CountCache` — (itemset, version)-keyed LRU so
    repeated hot queries skip the device entirely; ``append`` invalidates by
    bumping the version.

Served counts are EXACT: every row equals a fresh ``dense_gfp_counts`` /
brute-force run over the full transaction history at the same version.

Incremental re-mining (paper §5.2): ``mine(theta)`` bootstraps the frequent
set on the resident engine; after each ``append`` the server re-establishes
it from the pigeonhole candidate set (``incremental_candidates`` — the same
pure function the host ``IncrementalMiner`` uses), recounting the candidates
through the dense/streaming engine in one guided batch instead of host
FP-tree walks.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.fpgrowth import mine_frequent
from ..core.incremental import ceil_count, incremental_candidates
from .batcher import MicroBatcher, build_masks, canonical_itemset
from .cache import CountCache
from .store import VersionedDB

Item = Hashable
Key = Tuple[Item, ...]


class MiningRefreshError(RuntimeError):
    """Raised by ``CountServer.append`` when the batch WAS committed to the
    store (``version`` is the new version) but the §5.2 frequent-set refresh
    failed and incremental maintenance was disarmed.  Distinguishes
    'committed, re-mine needed' from a rejected append (which raises
    ``ValueError``/``OverflowError`` and leaves no trace) — do NOT retry the
    append, the rows would be double-counted."""

    def __init__(self, version: int, cause: BaseException):
        super().__init__(
            f"batch committed at version {version}, but the frequent-set "
            f"refresh failed ({cause!r}); incremental mining disarmed — "
            "call mine() to re-arm, do not retry the append")
        self.version = version


def versioned_mine_frequent(
    store: VersionedDB,
    min_count: float,
    *,
    class_column: Optional[int] = None,
    max_len: int = 0,
    checkpoint=None,                 # Optional[MiningCheckpoint]
    on_chunk=None,
) -> Dict[Key, int]:
    """Level-synchronous exact mining over a :class:`VersionedDB` — a shim
    over the unified driver (``mining/driver.py``) with the store-composed
    :class:`~repro.serve.store.VersionedCountBackend`: the same contract as
    ``dense_mine_frequent`` but counting through the store's composed
    base+delta sweep, so it is correct mid-append without compaction.

    With a ``checkpoint``, progress is durable at the store's chunk
    granularity (base chunks + delta chunk) and PINNED to the store version:
    a killed mine resumes mid-level at the same version, while a resume after
    an ``append`` discards the stale state and restarts cleanly."""
    from ..mining.driver import mine_frequent as _driver_mine
    from .store import VersionedCountBackend

    return _driver_mine(VersionedCountBackend(store), min_count,
                        class_column=class_column, max_len=max_len,
                        checkpoint=checkpoint, on_chunk=on_chunk)


class CountServer:
    """Synchronous driver loop: ``submit`` / ``flush`` / ``append`` / ``stats``."""

    def __init__(
        self,
        transactions: Sequence[Sequence[Item]] = (),
        classes: Optional[Sequence[int]] = None,
        n_classes: Optional[int] = None,
        *,
        use_kernel: bool = True,
        streaming: Optional[bool] = None,
        chunk_rows: Optional[int] = None,
        cache_size: int = 65536,
        cache_bytes: Optional[int] = None,
        cache: bool = True,
        block_k: int = 256,
        merge_ratio: float = 0.25,
    ):
        self.store = VersionedDB(
            transactions, classes=classes, n_classes=n_classes,
            use_kernel=use_kernel, streaming=streaming, chunk_rows=chunk_rows,
            merge_ratio=merge_ratio)
        self.batcher = MicroBatcher(block_k=block_k)
        self.cache: Optional[CountCache] = \
            CountCache(cache_size, max_bytes=cache_bytes) if cache else None
        self.n_flushes = 0
        self.n_queries_served = 0
        self._theta: Optional[float] = None
        self._frequent: Dict[Key, int] = {}

    # -- query path -----------------------------------------------------------
    def submit(self, client_id: str,
               itemsets: Sequence[Sequence[Item]]) -> int:
        """Queue one client request; returns the ticket ``flush()`` keys on."""
        return self.batcher.submit(client_id, itemsets)

    def flush(self) -> Dict[int, np.ndarray]:
        """Answer every pending request with one composed counting pass.

        Returns {ticket -> (len(itemsets), C) int32}, rows in each request's
        submission order.  Unique uncached targets are counted in ONE
        block_k-padded launch per resident segment; cached targets (same
        itemset, same version) never touch the device.
        """
        plan = self.batcher.take()
        if not plan.requests:
            return {}
        try:
            resolved = self._resolve(plan.unique_keys)
        except BaseException:
            self.batcher.restore(plan.requests)  # failed flush is retryable
            raise
        out: Dict[int, np.ndarray] = {}
        for req in plan.requests:
            block = (np.stack([resolved[k] for k in req.keys])
                     if req.keys
                     else np.zeros((0, self.store.n_classes), np.int32))
            out[req.request_id] = block.astype(np.int32, copy=False)
        self.n_flushes += 1
        self.n_queries_served += plan.n_queries
        return out

    def _resolve(self, keys: Sequence[Key]) -> Dict[Key, np.ndarray]:
        """{key -> (C,) counts} at the CURRENT version: cache hits first, one
        block_k-padded composed counting pass for the rest."""
        version = self.store.version
        resolved: Dict[Key, np.ndarray] = {}
        missing: List[Key] = []
        for key in keys:
            hit = self.cache.get(key, version) if self.cache is not None \
                else None
            if hit is not None:
                resolved[key] = hit
            else:
                missing.append(key)
        if missing:
            masks, known = build_masks(missing, self.store.vocab,
                                       self.batcher.block_k)
            rows = self.store.counts_masks(
                masks, block_k=self.batcher.block_k)[:len(missing)]
            rows[~known] = 0     # unknown-item targets count exactly 0
            for key, row in zip(missing, rows):
                resolved[key] = row
                if self.cache is not None:
                    self.cache.put(key, version, row)
        return resolved

    def query(self, itemsets: Sequence[Sequence[Item]],
              client_id: str = "_local") -> np.ndarray:
        """Answer one request immediately, WITHOUT draining the batcher:
        other clients' pending requests stay queued and are answered by the
        next ``flush()`` at whatever version is current then — an interleaved
        ``query()`` can neither orphan their tickets nor freeze their counts
        at an older version."""
        keys = [canonical_itemset(s) for s in itemsets]
        resolved = self._resolve(list(dict.fromkeys(keys)))
        self.n_queries_served += len(keys)
        if not keys:
            return np.zeros((0, self.store.n_classes), np.int32)
        return np.stack([resolved[k] for k in keys]).astype(np.int32,
                                                            copy=False)

    # -- growth path ----------------------------------------------------------
    def append(self, transactions: Sequence[Sequence[Item]],
               classes: Optional[Sequence[int]] = None) -> int:
        """Fold a new batch into the resident DB (version bump ⇒ cache
        invalidation) and, if mining is active, refresh the frequent set via
        the §5.2 guided recount on the engine."""
        transactions = [list(t) for t in transactions]
        old_version = self.store.version
        version = self.store.append(transactions, classes=classes)
        if version != old_version and self.cache is not None:
            self.cache.purge_stale(version)   # every old-version row is dead
        if self._theta is not None and transactions:
            try:
                self._refresh_frequent(transactions)
            except Exception as e:
                # §5.2 completeness needs the PREVIOUS exact frequent set;
                # after a failed refresh that baseline is lost for the new
                # version — serving the stale set would be silently wrong,
                # so disarm and require a fresh mine().  The batch itself IS
                # committed; MiningRefreshError tells the caller not to retry.
                self._theta = None
                self._frequent = {}
                raise MiningRefreshError(version, e) from e
        return version

    def mine(self, theta: float, *, checkpoint=None) -> Dict[Key, int]:
        """Bootstrap exact frequent-itemset mining at relative threshold
        ``theta``; subsequent ``append`` calls maintain it incrementally.

        ``checkpoint`` (a ``MiningCheckpoint``) makes the bootstrap RESUMABLE
        through the unified driver: over a disk-sized streaming-backed store
        the mine persists per-chunk progress, so a killed server process can
        restart and finish the bootstrap from the last completed chunk.  The
        durable state is pinned to the store version — a resume after further
        appends restarts the mine cleanly instead of serving stale levels."""
        if not (0.0 < theta <= 1.0):
            raise ValueError("theta in (0, 1]")
        frequent = versioned_mine_frequent(
            self.store, ceil_count(theta * self.store.n_rows),
            checkpoint=checkpoint)
        # commit only after the mine succeeds: a failed mine must not arm
        # incremental maintenance over an empty/stale baseline
        self._theta, self._frequent = theta, frequent
        return dict(frequent)

    def _refresh_frequent(self, increment: List[List[Item]]) -> None:
        # Pigeonhole candidates (complete: combined-frequent ⇒ frequent in the
        # old data or in the increment), then ONE guided engine recount of all
        # candidates over the full resident history — no host FP-tree walk.
        inc_frequent = mine_frequent(
            increment, ceil_count(self._theta * len(increment)))
        previously, newly = incremental_candidates(self._frequent,
                                                   inc_frequent)
        candidates = previously + newly
        if not candidates:
            self._frequent = {}
            return
        rows = self.store.counts(candidates).sum(axis=1)
        min_total = ceil_count(self._theta * self.store.n_rows)
        self._frequent = {k: int(c) for k, c in zip(candidates, rows)
                          if int(c) >= min_total}

    @property
    def frequent(self) -> Dict[Key, int]:
        if self._theta is None:
            raise RuntimeError("call mine() first")
        return dict(self._frequent)

    # -- introspection --------------------------------------------------------
    def stats(self) -> dict:
        return {
            "store": self.store.stats(),
            "batcher": self.batcher.stats(),
            "cache": self.cache.stats() if self.cache is not None else None,
            "flushes": self.n_flushes,
            "queries_served": self.n_queries_served,
            "mining_theta": self._theta,
            "frequent_itemsets": (len(self._frequent)
                                  if self._theta is not None else None),
        }
