from .config import (ALL_SHAPES, DECODE_32K, LONG_500K, ModelConfig,
                     PREFILL_32K, ShapeSpec, TRAIN_4K, shape_by_name)
from .registry import Model, get_model
