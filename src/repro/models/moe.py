"""Mixture-of-Experts MLP: top-k routing, capacity-bounded dispatch, EP over
the 'model' mesh axis; optional dense-residual branch (arctic).

Two dispatch implementations (config.moe_impl):
  * 'einsum' — GShard-style one-hot dispatch/combine einsums over
    (groups, tokens, experts, capacity).  Robust under GSPMD (the g<->e
    resharding lowers to all-to-all); costs extra dispatch FLOPs that show up
    honestly in the roofline's MODEL/HLO ratio.
  * 'gather' — index-based dispatch: tokens sorted by expert, gathered into
    (groups, experts, capacity, d) buffers, combined by scatter-gather.  Fewer
    FLOPs; sharding is more delicate (a §Perf hillclimb lever).
Both are exact-capacity-drop equivalents and are cross-checked in tests.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from ..parallel import sharding as shd
from .common import ParamSpec


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", None)),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "w_down": ParamSpec((e, f, d), ("experts", "ffn", "embed")),
    }


def _capacity(tokens_per_group: int, cfg) -> int:
    cap = int(math.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                        / cfg.n_experts))
    return max(cap, 1)


def _group_count(n_tokens: int, cfg) -> int:
    dp = 1
    if shd.active():
        mesh = shd._CTX.mesh
        dp = int(mesh.shape.get("data", 1) * mesh.shape.get("pod", 1))
    g = dp * cfg.moe_groups_per_dp
    while g > 1 and n_tokens % g != 0:
        g //= 2
    return max(g, 1)


def _route(params, xg: jax.Array, cfg):
    """xg (G,T,D) -> (gate weights (G,T,k), expert ids (G,T,k))."""
    logits = jnp.einsum("gtd,de->gte", xg, params["router"]).astype(jnp.float32)
    weights, ids = jax.lax.top_k(logits, cfg.top_k)          # (G,T,k)
    weights = jax.nn.softmax(weights, axis=-1)
    return weights.astype(xg.dtype), ids


def _expert_ffn(params, inp: jax.Array) -> jax.Array:
    """inp (G,E,C,D) -> (G,E,C,D), experts sharded over 'model'."""
    inp = shd.constrain(inp, "act_groups", "act_experts", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", inp, params["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", inp, params["w_up"])
    h = shd.constrain(h, "act_groups", "act_experts", None, "act_ffn")
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    return shd.constrain(out, "act_groups", "act_experts", None, None)


def moe_forward(params, x: jax.Array, cfg) -> jax.Array:
    """x (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    n_tokens = b * s
    g = _group_count(n_tokens, cfg)
    t = n_tokens // g
    cap = _capacity(t, cfg)
    xg = x.reshape(g, t, d)
    xg = shd.constrain(xg, "act_groups", None, None)

    weights, ids = _route(params, xg, cfg)

    if cfg.moe_impl == "gather":
        yg = _dispatch_gather(params, xg, weights, ids, cfg, cap)
    else:
        yg = _dispatch_einsum(params, xg, weights, ids, cfg, cap)
    return yg.reshape(b, s, d)


def _positions_in_expert(ids: jax.Array, e: int, k: int) -> jax.Array:
    """(G,T,k) expert ids -> (G,T,k) position of each (token,choice) within its
    expert's capacity buffer (cumulative count order)."""
    g, t, _ = ids.shape
    flat = ids.reshape(g, t * k)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)           # (G, T*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                         # (G, T*k, E)
    sel = jnp.take_along_axis(pos, flat[..., None], axis=-1)[..., 0]
    return sel.reshape(g, t, k)


def _dispatch_einsum(params, xg, weights, ids, cfg, cap):
    g, t, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    pos = _positions_in_expert(ids, e, k)                        # (G,T,k)
    keep = pos < cap                                             # capacity drop
    # dispatch (G,T,E,C) = sum_k onehot(e)*onehot(c)*keep
    oe = jax.nn.one_hot(ids, e, dtype=xg.dtype)                  # (G,T,k,E)
    oc = jax.nn.one_hot(pos, cap, dtype=xg.dtype)                # (G,T,k,C)
    keepf = keep.astype(xg.dtype)[..., None, None]
    dispatch = jnp.einsum("gtke,gtkc->gtec", oe * keep.astype(xg.dtype)[..., None], oc)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oe, oc,
                         weights * keep.astype(weights.dtype))
    del keepf
    inp = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    out = _expert_ffn(params, inp)
    y = jnp.einsum("gtec,gecd->gtd", combine, out)
    return shd.constrain(y, "act_groups", None, None)


def _dispatch_gather(params, xg, weights, ids, cfg, cap):
    g, t, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    pos = _positions_in_expert(ids, e, k)
    keep = pos < cap
    slot = jnp.where(keep, ids * cap + pos, e * cap)             # overflow slot
    # scatter tokens into (G, E*C+1, D)
    buf = jnp.zeros((g, e * cap + 1, d), xg.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[None, :, None], (g, t, k))
    gathered_x = jnp.take_along_axis(xg, tok_idx.reshape(g, t * k)[..., None], axis=1)
    buf = buf.at[jnp.arange(g)[:, None], slot.reshape(g, t * k)].add(gathered_x)
    inp = buf[:, : e * cap].reshape(g, e, cap, d)
    out = _expert_ffn(params, inp).reshape(g, e * cap, d)
    out = jnp.concatenate([out, jnp.zeros((g, 1, d), out.dtype)], axis=1)
    # gather back per (token, choice) and weight
    yk = jnp.take_along_axis(out, slot.reshape(g, t * k)[..., None], axis=1)
    yk = yk.reshape(g, t, k, d) * weights[..., None]
    y = yk.sum(axis=2)
    return shd.constrain(y, "act_groups", None, None)
