"""Model configuration for the architecture zoo.

One frozen dataclass covers all 10 assigned families (dense / MoE / hybrid /
SSM / enc-dec); per-arch files in repro/configs instantiate it with the exact
published numbers.  ``reduced()`` derives the same-family tiny config used by
CPU smoke tests (the full configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # expert hidden dim (defaults to d_ff)
    dense_residual: bool = False # arctic: dense MLP in parallel with MoE
    moe_every: int = 1           # MoE MLP on layers with i % moe_every == moe_every-1
    moe_groups_per_dp: int = 8   # dispatch groups per data shard
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"     # einsum | gather  (dispatch implementation)
    # --- attention ---
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_block_q: int = 512      # q-block size for chunked attention
    force_kv_seq_attn: bool = False  # use split-KV sharding even when heads divide
    # --- hybrid / ssm ---
    ssm: bool = False            # pure-SSM stack (attention-free)
    superblock: int = 0          # hybrid: scan unit of this many layers
    attn_every: int = 0          # hybrid: attention at i % attn_every == attn_offset
    attn_offset: int = 0
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- encoder-decoder (audio) ---
    encdec: bool = False
    n_enc_layers: int = 0
    frontend_dim: int = 0        # stubbed modality frontend embedding dim
    # --- numerics / memory ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"   # bf16 for >=100B models (HBM budget)
    remat: bool = True
    train_microbatches: int = 1  # grad-accumulation chunks (activation HBM / n)
    unroll_stack: bool = False   # Python-loop the unit stack instead of scan
                                 # (analysis variants: exposes per-layer cost)
    # --- notes ---
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i."""
        if self.ssm:
            return "ssm"
        if self.attn_every:
            return "attn" if i % self.attn_every == self.attn_offset else "ssm"
        return "attn"

    def mlp_kind(self, i: int) -> str:
        """'moe' or 'dense' for layer i."""
        if self.is_moe and (i % self.moe_every == self.moe_every - 1):
            return "moe"
        return "dense"

    def has_subquadratic_decode(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid archs)."""
        return self.ssm or self.attn_every > 0

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_layers = max(2, (self.superblock or 2))
        if self.superblock:
            n_layers = self.superblock  # one superblock
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=128,
            moe_d_ff=64 if self.is_moe else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            vocab_size=256,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if (self.ssm or self.attn_every) else self.ssm_headdim,
            ssm_chunk=8,
            attn_block_q=16,
            frontend_dim=32 if self.frontend_dim else 0,
            moe_groups_per_dp=1,
            capacity_factor=8.0,  # no capacity drops: decode == forward exactly
            opt_state_dtype="float32",
            dtype="float32",  # CPU smoke tests compare prefill/decode paths
        )

    def n_params(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs and HBM budgeting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, dh = self.n_heads, self.n_kv_heads, self.d_head
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d       # q,k,v,o
        dense_mlp = 3 * d * f
        moe_mlp = self.n_experts * 3 * d * self.expert_d_ff + d * self.n_experts
        ssm = 0
        if self.ssm or self.attn_every:
            di, ns, g = self.d_inner, self.ssm_state, self.ssm_groups
            nh = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * ns + nh)
            ssm = in_proj + di * d + (di + 2 * g * ns) * self.conv_width + 3 * nh + di

        total = 0
        n_stack = self.n_layers + (self.n_enc_layers if self.encdec else 0)
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            total += attn if kind == "attn" else ssm
            mk = self.mlp_kind(i)
            if mk == "moe":
                total += moe_mlp + (dense_mlp if self.dense_residual else 0)
            else:
                total += dense_mlp
            total += 2 * d  # norms
        if self.encdec:
            for _ in range(self.n_enc_layers):
                total += attn + dense_mlp + 2 * d
            total += self.n_layers * (attn + d)  # cross-attention + norm
        total += v * d  # embedding
        total += v * d  # lm head (untied)
        total += d
        return total

    def n_active_params(self) -> int:
        """Active (per-token) params — MoE uses top_k of n_experts."""
        if not self.is_moe:
            return self.n_params()
        full = self.n_params()
        d = self.d_model
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.mlp_kind(i) == "moe")
        per_layer_all = self.n_experts * 3 * d * self.expert_d_ff
        per_layer_active = self.top_k * 3 * d * self.expert_d_ff
        return full - n_moe_layers * (per_layer_all - per_layer_active)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
