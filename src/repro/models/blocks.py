"""Transformer/SSM block assembly: pre-norm residual blocks with attention or
SSD mixers and dense / MoE (+dense-residual) MLPs; scan-compatible stacking,
including heterogeneous 'superblocks' (jamba's 1-attention-per-8-layers)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel import sharding as shd
from .attention import (attn_decode, attn_forward, attn_specs, cross_attn_forward,
                        cross_kv, init_cache_specs)
from .common import ParamSpec, rmsnorm, stack_specs
from .mlp import mlp_forward, mlp_specs
from .moe import moe_forward, moe_specs
from .ssm import init_ssm_state_specs, ssm_forward, ssm_specs


def _norm_spec(cfg) -> ParamSpec:
    return ParamSpec((cfg.d_model,), ("norm",), init="ones")


def layer_specs(cfg, kind: str, mlp_kind: str, cross: bool = False) -> Dict[str, Any]:
    specs: Dict[str, Any] = {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg)}
    if kind == "attn":
        specs["attn"] = attn_specs(cfg)
    else:
        specs["ssm"] = ssm_specs(cfg)
    if mlp_kind == "moe":
        specs["moe"] = moe_specs(cfg)
        if cfg.dense_residual:
            specs["mlp"] = mlp_specs(cfg)
    else:
        specs["mlp"] = mlp_specs(cfg)
    if cross:
        specs["ln_cross"] = _norm_spec(cfg)
        specs["cross"] = attn_specs(cfg, cross=True)
    return specs


def layer_forward(p, x, cfg, kind: str, mlp_kind: str, positions,
                  causal: bool = True,
                  enc_kv: Optional[Tuple] = None,
                  enc_positions=None) -> jax.Array:
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        a = attn_forward(p["attn"], h, cfg, positions, causal=causal)
    else:
        a = ssm_forward(p["ssm"], h, cfg)
    x = x + a
    if enc_kv is not None:
        h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        x = x + cross_attn_forward(p["cross"], h, enc_kv, cfg, enc_positions)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if mlp_kind == "moe":
        m = moe_forward(p["moe"], h, cfg)
        if cfg.dense_residual:
            m = m + mlp_forward(p["mlp"], h)
    else:
        m = mlp_forward(p["mlp"], h)
    x = x + m
    return shd.constrain(x, "act_batch", "act_seq", "act_embed")


def layer_decode(p, x, cfg, kind: str, mlp_kind: str, cache, pos,
                 enc_kv: Optional[Tuple] = None, enc_positions=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind == "attn":
        a, cache = attn_decode(p["attn"], h, cache, cfg, pos)
    else:
        a, cache = ssm_forward(p["ssm"], h, cfg, state=cache, pos=pos)
    x = x + a
    if enc_kv is not None:
        h = rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        x = x + cross_attn_forward(p["cross"], h, enc_kv, cfg, enc_positions)
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if mlp_kind == "moe":
        m = moe_forward(p["moe"], h, cfg)
        if cfg.dense_residual:
            m = m + mlp_forward(p["mlp"], h)
    else:
        m = mlp_forward(p["mlp"], h)
    return x + m, cache


# ---------------------------------------------------------------------------
# scan units: a 'unit' is either one layer or one superblock of layers
# ---------------------------------------------------------------------------

def unit_layout(cfg) -> Tuple[int, Tuple[Tuple[str, str], ...]]:
    """-> (n_units, ((kind, mlp_kind) per layer inside a unit))."""
    sb = cfg.superblock or (cfg.moe_every if cfg.is_moe and cfg.moe_every > 1 else 1)
    assert cfg.n_layers % sb == 0, (cfg.n_layers, sb)
    layout = tuple((cfg.layer_kind(i), cfg.mlp_kind(i)) for i in range(sb))
    return cfg.n_layers // sb, layout


def unit_specs(cfg, cross: bool = False) -> Dict[str, Any]:
    _, layout = unit_layout(cfg)
    if len(layout) == 1:
        kind, mlp_kind = layout[0]
        return layer_specs(cfg, kind, mlp_kind, cross=cross)
    return {f"layer{i}": layer_specs(cfg, k, m, cross=cross)
            for i, (k, m) in enumerate(layout)}


def stack_unit_specs(cfg, cross: bool = False) -> Dict[str, Any]:
    n_units, _ = unit_layout(cfg)
    return stack_specs(unit_specs(cfg, cross=cross), n_units)


def unit_forward(p, x, cfg, positions, causal=True, enc_kv=None,
                 enc_positions=None) -> jax.Array:
    _, layout = unit_layout(cfg)
    if len(layout) == 1:
        kind, mlp_kind = layout[0]
        return layer_forward(p, x, cfg, kind, mlp_kind, positions, causal,
                             enc_kv, enc_positions)
    for i, (kind, mlp_kind) in enumerate(layout):
        def one(pp, hh, kind=kind, mlp_kind=mlp_kind):
            return layer_forward(pp, hh, cfg, kind, mlp_kind, positions,
                                 causal, enc_kv, enc_positions)
        if cfg.remat:
            # per-LAYER remat inside heterogeneous superblocks: a superblock-
            # level checkpoint keeps all 8 layers' SSD Q^2 tensors live during
            # the unit's backward (~150 GiB/device for jamba train_4k).
            one = jax.checkpoint(one)
        x = one(p[f"layer{i}"], x)
    return x


def unit_decode(p, x, cfg, cache, pos, enc_kv=None, enc_positions=None):
    _, layout = unit_layout(cfg)
    if len(layout) == 1:
        kind, mlp_kind = layout[0]
        return layer_decode(p, x, cfg, kind, mlp_kind, cache, pos,
                            enc_kv, enc_positions)
    new_cache = {}
    for i, (kind, mlp_kind) in enumerate(layout):
        key = f"layer{i}"
        x, new_cache[key] = layer_decode(p[key], x, cfg, kind, mlp_kind,
                                         cache[key], pos, enc_kv, enc_positions)
    return x, new_cache


def unit_cache_specs(cfg, batch: int, max_len: int, dp_size: int):
    """Cache structure for one scan unit (pre-stacking)."""
    _, layout = unit_layout(cfg)

    def one(kind: str):
        if kind == "attn":
            return init_cache_specs(cfg, batch, max_len, dp_size)
        return init_ssm_state_specs(cfg, batch)

    if len(layout) == 1:
        return one(layout[0][0])
    return {f"layer{i}": one(k) for i, (k, _) in enumerate(layout)}
