"""GQA attention: qk-norm, RoPE, chunked (sub-quadratic-memory) softmax,
KV caches with split-KV decode, cross-attention for the enc-dec arch.

Sharding strategies (selected per shape, no head padding ever):
  * 'heads'  — classic Megatron TP: q-heads over 'model' (requires
    n_heads % model_axis == 0); KV is repeated to full heads (cheap at
    train/prefill block sizes).
  * 'kv_seq' — split-KV: the key/value sequence axis over 'model'
    (flash-decoding style).  Used for all decode steps and for archs whose
    head counts don't divide the mesh (56, 40, 36 on a 16-way axis) —
    this keeps MODEL/HLO FLOPs ratio at 1.0 instead of padding heads.
Chunked attention scans over q blocks so the score tile is
(B, H, q_block, S_kv) — never the full S×S matrix.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel import sharding as shd
from .common import ParamSpec, apply_rope, rmsnorm


def attn_specs(cfg, cross: bool = False) -> Dict[str, ParamSpec]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    specs = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm and not cross:
        specs["q_norm"] = ParamSpec((dh,), ("norm",), init="ones")
        specs["k_norm"] = ParamSpec((dh,), ("norm",), init="ones")
    return specs


def _heads_shardable(cfg) -> bool:
    if not shd.active() or cfg.force_kv_seq_attn:
        return False
    mesh = shd._CTX.mesh
    ms = mesh.shape.get("model", 1)
    return cfg.n_heads % ms == 0


def _project_qkv(params, xq, xkv, cfg, q_positions, kv_positions,
                 rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", xkv, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", xkv, params["wv"])
    if "q_norm" in params:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa_block(qb, k, v, q_pos_b, kv_pos, causal: bool, scale: float,
                kv_seq_axis: Optional[str]):
    """One q-block of grouped attention.  qb (B,Q,KV,G,dh); k/v (B,S,KV,dh)."""
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qb, k) * scale
    if kv_seq_axis is not None:
        scores = shd.constrain(scores, "act_batch", None, None, None, kv_seq_axis)
    scores = scores.astype(jnp.float32)
    if causal:
        mask = q_pos_b[:, None] >= kv_pos[None, :]             # (Q, S)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    else:
        mask = kv_pos >= 0                                      # padding mask
        scores = jnp.where(mask[None, None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out


def grouped_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                      cfg, kv_seq_axis: Optional[str] = None) -> jax.Array:
    """q (B,Sq,H,dh), k/v (B,Skv,KV,dh) -> (B,Sq,H,dh); scans q blocks."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = dh ** -0.5
    qg = q.reshape(b, sq, kvh, g, dh)

    blk = min(cfg.attn_block_q, sq)
    if sq % blk != 0:
        blk = sq  # tiny/ragged: single block
    nblk = sq // blk

    if nblk == 1:
        out = _sdpa_block(qg, k, v, q_positions[0] if q_positions.ndim > 1 else q_positions,
                          kv_positions, causal, scale, kv_seq_axis)
        return out.reshape(b, sq, h, dh)

    qg = qg.reshape(b, nblk, blk, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpos = q_positions.reshape(nblk, blk)

    def step(_, inp):
        qb, qp = inp
        ob = _sdpa_block(qb, k, v, qp, kv_positions, causal, scale, kv_seq_axis)
        return None, ob

    _, outs = jax.lax.scan(step, None, (qg, qpos))     # (nblk, B, blk, KV, G, dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dh)
    return out


def repeated_heads_attention(q, k, v, *, q_positions, kv_positions,
                             causal: bool, cfg) -> jax.Array:
    """'heads' strategy: repeat KV to H and shard heads over 'model'."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    # Gather the seq axis BEFORE fanning out to heads: S-sharded -> replicated
    # is one all-gather; S-sharded -> heads-sharded directly makes GSPMD fall
    # back to "involuntary full rematerialization" (replicate via copy).
    k = shd.constrain(k, "act_batch", None, None, None)
    v = shd.constrain(v, "act_batch", None, None, None)
    q = shd.constrain(q, "act_batch", None, "act_heads", None)
    k = jnp.repeat(k, h // kvh, axis=2)
    v = jnp.repeat(v, h // kvh, axis=2)
    k = shd.constrain(k, "act_batch", None, "act_heads", None)
    v = shd.constrain(v, "act_batch", None, "act_heads", None)
    scale = dh ** -0.5

    blk = min(cfg.attn_block_q, sq)
    if sq % blk != 0:
        blk = sq
    nblk = sq // blk

    def block(qb, qp):
        scores = (jnp.einsum("bqhd,bshd->bhqs", qb, k) * scale).astype(jnp.float32)
        if causal:
            mask = qp[:, None] >= kv_positions[None, :]
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", probs, v)

    if nblk == 1:
        return block(q, q_positions)
    qb = q.reshape(b, nblk, blk, h, dh).transpose(1, 0, 2, 3, 4)
    qpos = q_positions.reshape(nblk, blk)
    _, outs = jax.lax.scan(lambda _, inp: (None, block(*inp)), None, (qb, qpos))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def attn_forward(params, x: jax.Array, cfg, positions: jax.Array,
                 causal: bool = True) -> jax.Array:
    """Full-sequence self-attention (train / prefill)."""
    if _heads_shardable(cfg):
        # heads strategy: ONE sequence-parallel all-gather feeds q, k and v
        # projections (kv_seq strategy keeps x seq-sharded: k/v inherit the
        # shard, only q is gathered inside the blockwise attention).
        x = shd.constrain(x, "act_batch", None, "act_embed")
    q, k, v = _project_qkv(params, x, x, cfg, positions, positions)
    if _heads_shardable(cfg):
        out = repeated_heads_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=causal, cfg=cfg)
    else:
        out = grouped_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=causal, cfg=cfg, kv_seq_axis="act_kv_seq")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shd.constrain(y, "act_batch", "act_seq", "act_embed")


def cross_attn_forward(params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                       cfg, enc_positions: jax.Array) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (no RoPE)."""
    b, sq, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k, v = enc_kv
    out = grouped_attention(
        q, k, v, q_positions=jnp.arange(sq), kv_positions=enc_positions,
        causal=False, cfg=cfg, kv_seq_axis="act_kv_seq")
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shd.constrain(y, "act_batch", "act_seq", "act_embed")


def cross_kv(params, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dnk->bsnk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", enc_out, params["wv"])
    return k, v


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def kv_cache_logical(batch: int, dp_size: int) -> str:
    """Cache seq axis: split-KV over 'model'; the 524k batch=1 cell also folds
    'data' in (the batch axis is idle there)."""
    return "act_kv_seq_long" if batch < dp_size else "act_kv_seq"


def init_cache_specs(cfg, batch: int, max_len: int, dp_size: int):
    """ShapeDtypeStruct specs for one layer's KV cache."""
    kv_ax = kv_cache_logical(batch, dp_size)
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    logical = ("act_batch", kv_ax, None, None)
    return {"k": (shape, logical), "v": (shape, logical)}


def attn_decode(params, x: jax.Array, cache: Dict[str, jax.Array], cfg,
                pos: jax.Array):
    """One-token decode.  x (B,1,D); cache k/v (B,Smax,KV,dh); pos () int32.
    Returns (y, new_cache)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(
        params, x, x, cfg,
        q_positions=jnp.full((1,), pos, jnp.int32),
        kv_positions=jnp.full((1,), pos, jnp.int32))
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, pos, 0, 0))
    kv_ax = kv_cache_logical(b, _dp_size())
    k = shd.constrain(k, "act_batch", kv_ax, None, None)
    v = shd.constrain(v, "act_batch", kv_ax, None, None)
    smax = k.shape[1]
    kv_positions = jnp.where(jnp.arange(smax) <= pos, jnp.arange(smax), -1)
    out = grouped_attention(
        q, k, v, q_positions=jnp.full((1,), pos, jnp.int32),
        kv_positions=kv_positions, causal=False, cfg=cfg, kv_seq_axis=kv_ax)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}


def _dp_size() -> int:
    if not shd.active():
        return 1
    mesh = shd._CTX.mesh
    return int(mesh.shape.get("data", 1) * mesh.shape.get("pod", 1))
