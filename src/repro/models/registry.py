"""Model API facade: everything launchers/tests need for one architecture."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import transformer
from .common import abstract_params, init_params, param_count, param_shardings
from .config import ModelConfig, ShapeSpec


class Model:
    """Thin functional wrapper binding a ModelConfig to the assembly fns."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.specs = transformer.model_specs(cfg)

    # -- params ----------------------------------------------------------
    def init(self, key: jax.Array):
        return init_params(self.specs, key, jnp.dtype(self.cfg.dtype))

    def abstract(self, mesh=None):
        return abstract_params(self.specs, jnp.dtype(self.cfg.dtype), mesh=mesh)

    def shardings(self, mesh):
        return param_shardings(self.specs, mesh)

    def n_params(self) -> int:
        return param_count(self.specs)

    # -- compute ----------------------------------------------------------
    def loss(self, params, batch):
        return transformer.train_loss(params, batch, self.cfg)

    def forward(self, params, tokens, frames=None):
        return transformer.forward(params, tokens, self.cfg, frames=frames)

    def prefill(self, params, tokens, max_len: int, frames=None, dp_size: int = 1):
        return transformer.prefill(params, tokens, self.cfg, max_len,
                                   frames=frames, dp_size=dp_size)

    def decode_step(self, params, cache, token, pos):
        return transformer.decode_step(params, cache, token, pos, self.cfg)

    def cache_specs(self, batch: int, max_len: int, dp_size: int = 1):
        return transformer.cache_specs(self.cfg, batch, max_len, dp_size)

    def init_cache(self, batch: int, max_len: int, dp_size: int = 1):
        return transformer.init_cache(self.cfg, batch, max_len,
                                      jnp.dtype(self.cfg.dtype), dp_size)


@functools.lru_cache(maxsize=None)
def get_model(arch: str, reduced: bool = False) -> Model:
    from ..configs import get_config
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    return Model(cfg)
