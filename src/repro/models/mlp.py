"""Dense SwiGLU MLP."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import jax

from ..parallel import sharding as shd
from .common import ParamSpec


def mlp_specs(cfg, d_ff: int = 0) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn")),
        "w_up": ParamSpec((d, f), ("embed", "ffn")),
        "w_down": ParamSpec((f, d), ("ffn", "embed")),
    }


def mlp_forward(params, x: jnp.ndarray) -> jnp.ndarray:
    # ONE sequence-parallel all-gather feeds both gate and up matmuls.
    x = shd.constrain(x, "act_batch", None, "act_embed")
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = shd.constrain(h, "act_batch", None, "act_ffn")
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"])
    # model-sharded contraction + seq-sharded output => reduce-scatter
    return shd.constrain(y, "act_batch", "act_seq", "act_embed")
