"""Mamba-2 SSD (state-space duality) layer — chunked quadratic-within-chunk /
linear-across-chunks algorithm (arXiv:2405.21060), plus O(1)-state decode.

Shapes: d_inner = expand*d_model, nh = d_inner/headdim heads, state N,
g groups for B/C (expanded to heads).  TPU mapping: heads over 'model' (TP),
batch over ('pod','data'); the inter-chunk recurrence is a lax.scan (HLO stays
small); the intra-chunk part is dense matmuls (MXU-friendly) — this is the
TPU-native answer to the paper family's "selective scan" CUDA kernels.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel import sharding as shd
from .common import ParamSpec, rmsnorm


def ssm_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di, n, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    w = cfg.conv_width
    return {
        "wz": ParamSpec((d, di), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, g, n), ("embed", None, "ssm_state")),
        "wC": ParamSpec((d, g, n), ("embed", None, "ssm_state")),
        "wdt": ParamSpec((d, nh), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((w, di), (None, "conv_chan")),
        "conv_B": ParamSpec((w, g, n), (None, None, "ssm_state")),
        "conv_C": ParamSpec((w, g, n), (None, None, "ssm_state")),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "gate_norm": ParamSpec((di,), ("norm",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along axis 1.  x (B,S,C...), w (W,C...)."""
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, [(0, 0), (i, 0)] + [(0, 0)] * (x.ndim - 2))[:, : x.shape[1]]
        out = out + shifted * w[width - 1 - i]
    return out


def _conv_step(state: jax.Array, xt: jax.Array, w: jax.Array):
    """Single-token causal conv.  state (B,W-1,C...), xt (B,C...)."""
    hist = jnp.concatenate([state, xt[:, None]], axis=1)       # (B,W,C..)
    y = jnp.einsum("bw...,w...->b...", hist, w)
    return hist[:, 1:], y


def _segsum(dA: jax.Array) -> jax.Array:
    """dA (..., Q, nh) -> decay matrix (..., nh, Q, Q): exp(sum_{j<i<=q} dA)."""
    q = dA.shape[-2]
    cs = jnp.cumsum(dA, axis=-2)                               # (..., Q, nh)
    diff = cs[..., :, None, :] - cs[..., None, :, :]           # (..., Q, Q, nh)
    mask = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.moveaxis(diff, -1, -3)                          # (..., nh, Q, Q)
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_scan(x, dt, A, B, C, chunk: int):
    """Chunked SSD.  x (B,L,nh,P); dt (B,L,nh); A (nh,);
    B/C (B,L,nh,N) (already head-expanded).  Returns y (B,L,nh,P) and the
    final state (B,nh,N,P)."""
    b, l, nh, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l)
    if l % q != 0:
        q = l
    nc = l // q

    xr = x.reshape(b, nc, q, nh, p)
    dtr = dt.reshape(b, nc, q, nh)
    Br = B.reshape(b, nc, q, nh, n)
    Cr = C.reshape(b, nc, q, nh, n)
    dA = dtr * A[None, None, None, :]                          # (b,nc,q,nh)

    xdt = xr * dtr[..., None]
    Lmat = _segsum(dA.astype(jnp.float32)).astype(x.dtype)     # (b,nc,nh,q,q)
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Cr, Br)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", cb * Lmat, xdt)

    cs = jnp.cumsum(dA.astype(jnp.float32), axis=2)            # (b,nc,q,nh)
    decay_out = jnp.exp(cs[:, :, -1:, :] - cs).astype(x.dtype) # (b,nc,q,nh)
    states = jnp.einsum("bcqhn,bcqhp->bchnp", Br * decay_out[..., None], xdt)
    chunk_decay = jnp.exp(cs[:, :, -1, :]).astype(x.dtype)     # (b,nc,nh)

    def step(s, inp):
        st_c, dec_c = inp                                      # (b,nh,n,p), (b,nh)
        s_new = s * dec_c[..., None, None] + st_c
        return s_new, s                                        # emit state ENTERING chunk

    s0 = jnp.zeros((b, nh, n, p), x.dtype)
    s_final, s_in = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_in = s_in.transpose(1, 0, 2, 3, 4)                       # (b,nc,nh,n,p)

    decay_in = jnp.exp(cs).astype(x.dtype)                     # (b,nc,q,nh)
    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", Cr * decay_in[..., None], s_in)
    y = (y_diag + y_off).reshape(b, l, nh, p)
    return y, s_final


def _head_expand(t: jax.Array, nh: int) -> jax.Array:
    """(B,L,G,N) group tensor -> (B,L,nh,N) head tensor."""
    g = t.shape[2]
    return jnp.repeat(t, nh // g, axis=2)


def ssm_forward(params, xin: jax.Array, cfg,
                state: Optional[Dict[str, jax.Array]] = None,
                pos: Optional[jax.Array] = None):
    """Full-sequence SSD (train/prefill).  xin (B,S,D) -> (B,S,D).
    If ``state`` is given, behaves as a single-step decode (S==1)."""
    if state is not None:
        return _ssm_decode(params, xin, cfg, state, pos)
    b, s, d = xin.shape
    nh, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state

    # ONE sequence-parallel all-gather feeds all five projections (z, x, B,
    # C, dt) — per-matmul reshards were the dominant collective in the
    # mamba2 train_4k baseline (t_coll 10x t_compute).
    xin = shd.constrain(xin, "act_batch", None, "act_embed")
    z = jnp.einsum("bsd,de->bse", xin, params["wz"])
    x = jnp.einsum("bsd,de->bse", xin, params["wx"])
    Bm = jnp.einsum("bsd,dgn->bsgn", xin, params["wB"])
    Cm = jnp.einsum("bsd,dgn->bsgn", xin, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", xin, params["wdt"])

    x = jax.nn.silu(_causal_conv(x, params["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, params["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cm, params["conv_C"]))
    x = shd.constrain(x, "act_batch", None, "act_ffn")

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32)).astype(xin.dtype)
    A = -jnp.exp(params["A_log"].astype(jnp.float32)).astype(xin.dtype)

    xh = x.reshape(b, s, nh, p)
    xh = shd.constrain(xh, "act_batch", None, "act_ssm_heads", None)
    Bh = _head_expand(Bm, nh)
    Ch = _head_expand(Cm, nh)

    y, _ = ssd_scan(xh, dt, A, Bh, Ch, cfg.ssm_chunk)
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(b, s, nh * p)
    y = rmsnorm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y.reshape(b * s, nh * p),
                     params["out_proj"]).reshape(b, s, d)
    return shd.constrain(out, "act_batch", "act_seq", "act_embed")


def init_ssm_state_specs(cfg, batch: int):
    """Decode-state specs for one SSM layer."""
    nh, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    g, w, di = cfg.ssm_groups, cfg.conv_width, cfg.d_inner
    return {
        "ssd": ((batch, nh, n, p), ("act_batch", "act_ssm_heads", None, None)),
        "conv_x": ((batch, w - 1, di), ("act_batch", None, "conv_chan")),
        "conv_B": ((batch, w - 1, g, n), ("act_batch", None, None, None)),
        "conv_C": ((batch, w - 1, g, n), ("act_batch", None, None, None)),
    }


def _ssm_decode(params, xin, cfg, state, pos):
    """Single-token SSD decode.  xin (B,1,D)."""
    b = xin.shape[0]
    nh, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xt = xin[:, 0]
    z = xt @ params["wz"]
    x = xt @ params["wx"]
    Bm = jnp.einsum("bd,dgn->bgn", xt, params["wB"])
    Cm = jnp.einsum("bd,dgn->bgn", xt, params["wC"])
    dt = xt @ params["wdt"]

    cx, x = _conv_step(state["conv_x"], x, params["conv_x"])
    cB, Bm = _conv_step(state["conv_B"], Bm, params["conv_B"])
    cC, Cm = _conv_step(state["conv_C"], Cm, params["conv_C"])
    x, Bm, Cm = jax.nn.silu(x), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32)).astype(xin.dtype)
    A = -jnp.exp(params["A_log"].astype(jnp.float32)).astype(xin.dtype)

    xh = x.reshape(b, nh, p)
    Bh = jnp.repeat(Bm, nh // cfg.ssm_groups, axis=1)          # (B,nh,N)
    Ch = jnp.repeat(Cm, nh // cfg.ssm_groups, axis=1)
    decay = jnp.exp(dt * A[None, :])                            # (B,nh)
    s_new = (state["ssd"] * decay[..., None, None] +
             jnp.einsum("bhn,bhp->bhnp", Bh, xh * dt[..., None]))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, s_new) + params["D"][None, :, None] * xh
    y = y.reshape(b, nh * p)
    y = rmsnorm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]
    new_state = {"ssd": s_new, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return shd.constrain(out, "act_batch", None, "act_embed"), new_state
