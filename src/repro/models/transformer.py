"""Model assembly: decoder-only LMs (dense/MoE/SSM/hybrid) and the
encoder-decoder (audio) variant; train loss, prefill and decode entry points.

The layer stack is a ``lax.scan`` over scan-units (single layers, or jamba's
8-layer superblocks) — compile time and HLO size stay O(1) in depth.  Each
unit body is rematerialized (``jax.checkpoint``) when cfg.remat.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel import sharding as shd
from .blocks import (stack_unit_specs, unit_cache_specs, unit_decode,
                     unit_forward, unit_layout)
from .common import (ParamSpec, embed_specs, embed_tokens, lm_logits,
                     rmsnorm, softmax_xent)
from .config import ModelConfig


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = dict(embed_specs(cfg))
    specs["decoder"] = stack_unit_specs(cfg, cross=cfg.encdec)
    if cfg.encdec:
        specs["enc_in_proj"] = ParamSpec((cfg.frontend_dim, cfg.d_model),
                                         (None, "embed"))
        enc_cfg = _enc_cfg(cfg)
        specs["encoder"] = stack_unit_specs(enc_cfg)
        specs["enc_norm"] = ParamSpec((cfg.d_model,), ("norm",), init="ones")
    return specs


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, n_layers=cfg.n_enc_layers, encdec=False,
                               superblock=0, attn_every=0, n_experts=0)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def scan_units(cfg, step, carry, xs):
    """lax.scan over stacked units, or an unrolled Python loop when
    cfg.unroll_stack (cost-analysis variants — a while-loop body is counted
    once by XLA's cost model, hiding depth)."""
    if not cfg.unroll_stack:
        return jax.lax.scan(step, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = step(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def _scan_stack(cfg, params_stacked, x, body):
    def step(carry, unit_params):
        return body(carry, unit_params), None
    x, _ = scan_units(cfg, step, x, params_stacked)
    return x


def _encode(params, frames: jax.Array, cfg) -> jax.Array:
    """Stubbed modality frontend: precomputed frame/patch embeddings in,
    encoder hidden states out."""
    enc_cfg = _enc_cfg(cfg)
    x = jnp.einsum("bsf,fd->bsd", frames.astype(params["enc_in_proj"].dtype),
                   params["enc_in_proj"])
    x = shd.constrain(x, "act_batch", "act_seq", "act_embed")
    s = frames.shape[1]
    positions = jnp.arange(s)

    def body(h, p):
        fwd = functools.partial(unit_forward, cfg=enc_cfg, positions=positions,
                                causal=False)
        if cfg.remat:
            fwd = jax.checkpoint(lambda pp, hh: unit_forward(
                pp, hh, enc_cfg, positions, causal=False))
            return fwd(p, h)
        return unit_forward(p, h, enc_cfg, positions, causal=False)

    x = _scan_stack(enc_cfg, params["encoder"], x, lambda h, p: body(h, p))
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, tokens: jax.Array, cfg,
            frames: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B,S) -> logits (B,S,Vpad).  ``frames`` feeds the encoder for
    the enc-dec arch (stub frontend)."""
    x = embed_tokens(params, tokens, cfg)
    s = tokens.shape[1]
    positions = jnp.arange(s)

    enc_kv_args: Dict[str, Any] = {}
    if cfg.encdec:
        assert frames is not None
        enc_out = _encode(params, frames, cfg)
        enc_positions = jnp.arange(enc_out.shape[1])
    else:
        enc_out = None
        enc_positions = None

    from .blocks import unit_layout as _ul
    _multi_layer_unit = len(_ul(cfg)[1]) > 1

    def body(h, p):
        def fwd(pp, hh):
            enc_kv = None
            if enc_out is not None:
                from .attention import cross_kv
                enc_kv = cross_kv(pp["cross"], enc_out)
            return unit_forward(pp, hh, cfg, positions, causal=True,
                                enc_kv=enc_kv, enc_positions=enc_positions)
        if cfg.remat and not _multi_layer_unit:
            # single-layer units checkpoint here; multi-layer superblocks
            # checkpoint per-layer inside unit_forward (memory, see blocks.py)
            return jax.checkpoint(fwd)(p, h)
        return fwd(p, h)

    x = _scan_stack(cfg, params["decoder"], x, body)
    return lm_logits(params, x, cfg)


def train_loss(params, batch: Dict[str, jax.Array], cfg) -> jax.Array:
    logits = forward(params, batch["tokens"], cfg, frames=batch.get("frames"))
    return softmax_xent(logits, batch["labels"], cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_specs(cfg, batch: int, max_len: int, dp_size: int = 1):
    """Pytree of (shape, logical_axes) for the stacked decode state."""
    n_units, _ = unit_layout(cfg)
    unit = unit_cache_specs(cfg, batch, max_len, dp_size)

    def stack(leaf):
        shape, logical = leaf
        return ((n_units,) + shape, ("layers",) + logical)

    out = jax.tree.map(stack, unit,
                       is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                       and isinstance(x[0], tuple))
    extra = {}
    if cfg.encdec:
        kvshape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
        extra["enc_k"] = ((n_units,) + kvshape,
                          ("layers", "act_batch", "act_kv_seq", None, None))
        extra["enc_v"] = ((n_units,) + kvshape,
                          ("layers", "act_batch", "act_kv_seq", None, None))
        extra["enc_len"] = ((), ())
    return {"units": out, **extra}


def init_cache(cfg, batch: int, max_len: int, dtype, dp_size: int = 1):
    specs = cache_specs(cfg, batch, max_len, dp_size)

    def mk(leaf):
        shape, _ = leaf
        return jnp.zeros(shape, dtype)

    return jax.tree.map(mk, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], tuple))


def prefill(params, tokens: jax.Array, cfg, max_len: int,
            frames: Optional[jax.Array] = None, dp_size: int = 1):
    """Run the full prompt, return (last-token logits, populated cache).

    The prefill KV cache is built by running full-sequence attention and then
    writing K/V into the cache buffers unit-by-unit (scan)."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s)
    cache = init_cache(cfg, b, max_len, x.dtype, dp_size)

    enc_out = None
    enc_positions = None
    if cfg.encdec:
        assert frames is not None
        enc_out = _encode(params, frames, cfg)
        enc_positions = jnp.arange(enc_out.shape[1])

    _, layout = unit_layout(cfg)

    def fill_unit(h, p, unit_cache):
        """Forward one unit while capturing K/V + SSD final state."""
        from .attention import _project_qkv, attn_forward, cross_kv
        from .ssm import ssd_scan
        new_cache = dict(unit_cache) if isinstance(unit_cache, dict) else unit_cache

        def one_layer(pp, hh, kind, mlp_kind, lcache):
            from .blocks import layer_forward
            # capture kv BEFORE the layer transform (same projections)
            hn = rmsnorm(hh, pp["ln1"], cfg.norm_eps)
            if kind == "attn":
                q, k, v = _project_qkv(pp["attn"], hn, hn, cfg, positions, positions)
                lc = {
                    "k": jax.lax.dynamic_update_slice(
                        lcache["k"], k.astype(lcache["k"].dtype), (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(
                        lcache["v"], v.astype(lcache["v"].dtype), (0, 0, 0, 0)),
                }
            else:
                lc = _capture_ssm_state(pp["ssm"], hn, cfg, lcache)
            enc_kv = None
            if enc_out is not None:
                enc_kv = cross_kv(pp["cross"], enc_out)
            hh = layer_forward(pp, hh, cfg, kind, mlp_kind, positions,
                               causal=True, enc_kv=enc_kv,
                               enc_positions=enc_positions)
            return hh, lc

        if len(layout) == 1:
            kind, mlp_kind = layout[0]
            h, nc = one_layer(p, h, kind, mlp_kind, unit_cache)
            return h, nc
        nc = {}
        for i, (kind, mlp_kind) in enumerate(layout):
            key = f"layer{i}"
            h, nc[key] = one_layer(p[key], h, kind, mlp_kind, unit_cache[key])
        return h, nc

    def step(h, inp):
        p, ucache = inp
        h, nc = fill_unit(h, p, ucache)
        return h, nc

    scan_in = (params["decoder"], cache["units"])
    x, new_units = scan_units(cfg, step, x, scan_in)
    cache = {**cache, "units": new_units}

    if cfg.encdec:
        from .attention import cross_kv

        def enc_kv_unit(_, p):
            k, v = cross_kv(p["cross"], enc_out)
            return None, (k, v)

        _, (ek, ev) = scan_units(cfg, enc_kv_unit, None, params["decoder"])
        pad = cache["enc_k"].shape[2] - ek.shape[2]
        cache["enc_k"] = jnp.pad(ek, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["enc_v"] = jnp.pad(ev, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["enc_len"] = jnp.asarray(enc_out.shape[1], jnp.int32)

    logits = lm_logits(params, x[:, -1:, :], cfg)
    return logits, cache


def _capture_ssm_state(p, xin, cfg, lcache):
    """Recompute the SSD state at end-of-prompt for the decode cache.
    ``xin`` is the ln1-normed layer input (identical to ssm_forward's)."""
    from .ssm import _causal_conv, _head_expand, ssd_scan
    b, s, _ = xin.shape
    x = jnp.einsum("bsd,de->bse", xin, p["wx"])
    Bm = jnp.einsum("bsd,dgn->bsgn", xin, p["wB"])
    Cm = jnp.einsum("bsd,dgn->bsgn", xin, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", xin, p["wdt"])

    def conv_tail(t):  # last (W-1) raw inputs, left-padded for short prompts
        w1 = cfg.conv_width - 1
        pad = [(0, 0), (w1, 0)] + [(0, 0)] * (t.ndim - 2)
        return jnp.pad(t, pad)[:, t.shape[1]:]

    cx, cB, cC = conv_tail(x), conv_tail(Bm), conv_tail(Cm)
    x = jax.nn.silu(_causal_conv(x, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32)).astype(xin.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(xin.dtype)
    nh, hd = cfg.ssm_heads, cfg.ssm_headdim
    xh = x.reshape(b, s, nh, hd)
    _, s_final = ssd_scan(xh, dt, A, _head_expand(Bm, nh), _head_expand(Cm, nh),
                          cfg.ssm_chunk)
    return {"ssd": s_final.astype(lcache["ssd"].dtype),
            "conv_x": cx.astype(lcache["conv_x"].dtype),
            "conv_B": cB.astype(lcache["conv_B"].dtype),
            "conv_C": cC.astype(lcache["conv_C"].dtype)}


def decode_step(params, cache, token: jax.Array, pos: jax.Array, cfg):
    """One decode step.  token (B,1) int32; pos () int32.
    Returns (logits (B,1,Vpad), new cache)."""
    x = embed_tokens(params, token, cfg)
    enc_positions = None
    if cfg.encdec:
        smax = cache["enc_k"].shape[2]
        idx = jnp.arange(smax)
        enc_positions = jnp.where(idx < cache["enc_len"], idx, -1)

    def step(h, inp):
        p, ucache = inp
        enc_kv = None
        if cfg.encdec:
            # per-unit encoder KV is carried in the scanned cache
            enc_kv = (ucache["__enc_k"], ucache["__enc_v"])
            ucache = {k: v for k, v in ucache.items() if not k.startswith("__")}
        h, nc = unit_decode(p, h, cfg, ucache, pos,
                            enc_kv=enc_kv, enc_positions=enc_positions)
        if cfg.encdec:
            nc = {**nc, "__enc_k": enc_kv[0], "__enc_v": enc_kv[1]}
        return h, nc

    units = cache["units"]
    if cfg.encdec:
        units = jax.tree.map(lambda x: x, units)
        units = {**units, "__enc_k": cache["enc_k"], "__enc_v": cache["enc_v"]}
    x, new_units = scan_units(cfg, step, x, (params["decoder"], units))
    if cfg.encdec:
        new_cache = {"units": {k: v for k, v in new_units.items()
                               if not k.startswith("__")},
                     "enc_k": cache["enc_k"], "enc_v": cache["enc_v"],
                     "enc_len": cache["enc_len"]}
    else:
        new_cache = {"units": new_units}
    logits = lm_logits(params, x, cfg)
    return logits, new_cache
