"""Shared model machinery: spec-driven parameters, norms, RoPE, embeddings.

Parameters are declared as ``ParamSpec`` trees (shape + logical axes + init).
One source of truth yields (a) real initialized params, (b) allocation-free
ShapeDtypeStructs for the dry-run, and (c) NamedShardings via the logical
rules in repro.parallel.sharding.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import sharding as shd


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def stack_specs(specs: Any, n: int) -> Any:
    """Prefix a scan ('layers') axis onto every spec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.init, s.scale),
        specs, is_leaf=is_spec)


def init_params(specs: Any, key: jax.Array, dtype: jnp.dtype) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            fan_in = s.shape[0] if len(s.shape) > 1 else 1
            std = s.scale if s.init == "normal" else 1.0 / np.sqrt(fan_in)
            out.append((jax.random.normal(k, s.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs: Any, dtype: jnp.dtype, mesh=None) -> Any:
    """ShapeDtypeStructs (+ shardings when a mesh is given) — dry-run inputs."""
    def mk(s: ParamSpec):
        if mesh is not None:
            ns = shd.named_sharding(s.logical, shape=s.shape, mesh=mesh)
            return jax.ShapeDtypeStruct(s.shape, dtype, sharding=ns)
        return jax.ShapeDtypeStruct(s.shape, dtype)
    return jax.tree.map(mk, specs, is_leaf=is_spec)


def param_shardings(specs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: shd.named_sharding(s.logical, shape=s.shape, mesh=mesh),
        specs, is_leaf=is_spec)


def param_count(specs: Any) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with f32 *reduction* but bf16 activation tensors.

    Materializing x in f32 (the textbook formulation) makes GSPMD place
    sequence-parallel reshards on f32 activation tensors — 2x collective and
    HBM bytes on every layer boundary.  Only the (B,S,1) variance is f32 here;
    the (B,S,D) tensors stay in the model dtype.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale


def rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                                  # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# embeddings / unembedding (vocab padded to /256 for clean TP)
# ---------------------------------------------------------------------------

def embed_specs(cfg) -> Dict[str, ParamSpec]:
    vpad = round_up(cfg.vocab_size, 256)
    return {
        "tok_embed": ParamSpec((vpad, cfg.d_model), ("vocab_in", "embed_tbl")),
        "lm_head": ParamSpec((cfg.d_model, vpad), ("embed", "vocab_out")),
        "final_norm": ParamSpec((cfg.d_model,), ("norm",), init="ones"),
    }


def embed_tokens(params, tokens: jax.Array, cfg) -> jax.Array:
    """tokens (B, S) -> (B, S, D).  Table cols are TP-sharded; the gather is
    local (indices replicated over 'model')."""
    emb = jnp.take(params["tok_embed"], tokens, axis=0)
    return shd.constrain(emb, "act_batch", "act_seq", "act_embed")


def lm_logits(params, x: jax.Array, cfg) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return shd.constrain(logits, "act_batch", None, "act_vocab")


def softmax_xent(logits: jax.Array, labels: jax.Array, vocab_size: int) -> jax.Array:
    """Mean token cross-entropy; padded vocab tail masked out."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad != vocab_size:
        neg = jnp.full((vpad - vocab_size,), -1e30, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab_size,), jnp.float32), neg])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
