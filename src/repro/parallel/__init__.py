from . import sharding
