"""Pipeline parallelism: GPipe-style microbatch schedule over a 'stage' mesh
axis, built on shard_map + lax.ppermute.

The framework's depth scaling is primarily scan-over-layers + FSDP/TP, but at
1000+ nodes a pipeline axis is the standard third dimension (cuts the FSDP
all-gather span and the TP collective domain).  This module provides the
composable stage executor; `tests/test_pipeline.py` proves numerical
equivalence with sequential execution on a multi-device host mesh.

Schedule (forward): T = M + S - 1 ticks for M microbatches over S stages.
At tick t, stage s computes microbatch (t - s) (a bubble otherwise), then the
activations rotate one hop with a single collective-permute — the classic
GPipe pipeline with an S-1-tick fill/drain bubble; utilization M/(M+S-1).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def pipeline_forward(
    stage_params: Any,          # pytree, leaves stacked on a leading S dim
    x: jax.Array,               # (M, mb, ...) microbatched inputs
    body: Callable[[Any, jax.Array], jax.Array],   # one stage's computation
    mesh: Mesh,
    stage_axis: str = "stage",
    batch_axis: str = "data",
) -> jax.Array:                 # (M, mb, ...) outputs of the final stage
    """Run `body` S times over x as an S-stage pipeline."""
    n_stages = mesh.shape[stage_axis]
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    param_specs = jax.tree.map(lambda _: P(stage_axis), stage_params)
    x_spec = P(None, batch_axis)
    out_spec = P(None, batch_axis)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(param_specs, x_spec), out_specs=out_spec,
        check_vma=False,
    )
    def run(local_params, xs):
        # local_params leaves have leading dim 1 (this stage's slice)
        my_params = jax.tree.map(lambda a: a[0], local_params)
        sid = jax.lax.axis_index(stage_axis)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        zero = jnp.zeros_like(xs[0])

        def tick(carry, t):
            ring_in = carry
            # stage 0 ingests microbatch t (when valid); others take the ring
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            inp = jnp.where(sid == 0, feed, ring_in)
            out = body(my_params, inp)
            ring_out = jax.lax.ppermute(out, stage_axis, perm)
            # final stage emits microbatch (t - S + 1) at this tick
            return ring_out, out

        _, outs = jax.lax.scan(tick, zero, jnp.arange(ticks))  # (T, mb, ...)
        # keep the last-stage outputs for ticks S-1 .. T-1, i.e. microbatches
        # 0..M-1; on non-final stages this value is discarded by the psum mask
        valid = outs[n_stages - 1:]
        is_last = (sid == n_stages - 1).astype(valid.dtype)
        # every stage returns its slice; only the final stage's is nonzero,
        # and the stage axis is contracted by summing (one nonzero term)
        return jax.lax.psum(valid * is_last, stage_axis)

    return run(stage_params, x)


def split_stages(params_stacked: Any, n_stages: int) -> Any:
    """(L, ...) layer-stacked params -> (S, L/S, ...) stage-stacked."""
    def r(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree.map(r, params_stacked)
