"""Logical-axis sharding: one rules table maps logical tensor axes to mesh axes.

Parallelism recipe (single pod = (data=16, model=16); multi-pod adds 'pod'):

  * DP/FSDP : batch over ('pod','data'); weight d_model dims over 'data'
              (ZeRO-3 — XLA all-gathers per layer under scan, reduce-scatters
              grads);
  * TP      : ffn / q-heads / vocab(out) / expert dim over 'model';
  * SP      : residual-stream seq dim over 'model' between blocks
              (Megatron-style sequence parallelism), KV-cache seq over 'model'
              at decode (flash-decoding-style split-KV), and over
              ('data','model') for the 524k single-sequence cell;
  * EP      : experts over 'model'.

Activations are constrained at block boundaries only; GSPMD derives the
interior collectives.  Dims that do not divide their mesh axes are left
unconstrained (recorded as padding/waste in the roofline ratio instead of
crashing the compile).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# Logical axis -> preferred mesh axes.
DEFAULT_RULES: Dict[str, Axes] = {
    # --- weights ---
    "embed": "data",            # FSDP dim of weight matrices
    "ffn": "model",
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "experts": "model",
    "vocab_in": None,           # embedding table rows (gather stays local)
    "embed_tbl": "model",       # embedding table cols
    "vocab_out": "model",       # lm-head output dim
    "layers": None,             # scan-stacked dim
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_chan": "model",
    "norm": None,
    # --- activations ---
    "act_batch": ("pod", "data"),
    "act_seq": "model",         # sequence-parallel residual stream
    "act_kv_seq": "model",      # split-KV decode
    "act_kv_seq_long": ("data", "model"),  # 524k single-sequence decode
    "act_heads": "model",
    "act_ffn": "model",
    "act_vocab": "model",
    "act_embed": None,
    "act_experts": "model",
    "act_groups": ("pod", "data"),
    "act_ssm_heads": "model",
    None: None,
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Dict[str, Axes] = DEFAULT_RULES
    enabled: bool = False


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Optional[Dict[str, Axes]] = None):
    """Enable logical-axis constraints inside model code."""
    prev = (_CTX.mesh, _CTX.rules, _CTX.enabled)
    _CTX.mesh, _CTX.rules, _CTX.enabled = mesh, {**DEFAULT_RULES, **(rules or {})}, True
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.enabled = prev


def active() -> bool:
    return _CTX.enabled and _CTX.mesh is not None


def _mesh_axes_for(logical: Optional[str], mesh: Mesh,
                   rules: Dict[str, Axes]) -> Tuple[str, ...]:
    ax = rules.get(logical, None)
    if ax is None:
        return ()
    if isinstance(ax, str):
        ax = (ax,)
    return tuple(a for a in ax if a in mesh.shape)


def pspec(logical_axes: Sequence[Optional[str]],
          shape: Optional[Sequence[int]] = None,
          mesh: Optional[Mesh] = None,
          rules: Optional[Dict[str, Axes]] = None) -> P:
    """Logical axes -> PartitionSpec, dropping non-divisible constraints."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    assert mesh is not None
    used: set = set()
    out = []
    for i, name in enumerate(logical_axes):
        axes = _mesh_axes_for(name, mesh, rules)
        axes = tuple(a for a in axes if a not in used)
        if shape is not None and axes:
            total = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[i] % total != 0:
                axes = ()  # padding-free: leave unsharded, report as waste
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint via the logical rules (no-op outside ctx)."""
    if not active():
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = pspec(logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None,
                   mesh: Optional[Mesh] = None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    return NamedSharding(mesh, pspec(logical_axes, shape=shape, mesh=mesh))
