"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave, MoE 16e top-2.
Superblocks of 8 layers (attention at in-block index 3), MoE every 2nd layer.
[arXiv:2403.19887; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=24576, moe_every=2,
    force_kv_seq_attn=True,  # adopted: EXPERIMENTS.md §Perf iters 4-5
    superblock=8, attn_every=8, attn_offset=3,
    ssm_state=128, ssm_expand=2, ssm_headdim=128, ssm_groups=1, ssm_chunk=128,
    moe_groups_per_dp=16, capacity_factor=1.0,
    train_microbatches=8,
    opt_state_dtype="bfloat16",
    source="arXiv:2403.19887",
)
