"""seamless-m4t-large-v2 — encoder-decoder, multimodal (speech frontend is a
stub feeding precomputed frame embeddings).  GQA kv=16 == MHA at 16 heads.
[arXiv:2308.11596; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=8192, vocab_size=256206,
    encdec=True, n_enc_layers=24, frontend_dim=1024,
    force_kv_seq_attn=True,  # adopted: EXPERIMENTS.md §Perf iters 4-5
    source="arXiv:2308.11596",
)
