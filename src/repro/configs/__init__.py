"""Architecture registry: --arch <id> -> ModelConfig (exact published shapes)."""
from ..models.config import (ALL_SHAPES, DECODE_32K, LONG_500K, ModelConfig,
                             PREFILL_32K, ShapeSpec, TRAIN_4K, shape_by_name)

from .arctic_480b import CONFIG as ARCTIC_480B
from .llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from .qwen3_32b import CONFIG as QWEN3_32B
from .mistral_nemo_12b import CONFIG as MISTRAL_NEMO_12B
from .qwen3_8b import CONFIG as QWEN3_8B
from .starcoder2_7b import CONFIG as STARCODER2_7B
from .jamba_1_5_large_398b import CONFIG as JAMBA_1_5_LARGE
from .mamba2_2_7b import CONFIG as MAMBA2_2_7B
from .seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T
from .chameleon_34b import CONFIG as CHAMELEON_34B

ARCHS = {
    c.name: c for c in (
        ARCTIC_480B, LLAMA4_MAVERICK, QWEN3_32B, MISTRAL_NEMO_12B, QWEN3_8B,
        STARCODER2_7B, JAMBA_1_5_LARGE, MAMBA2_2_7B, SEAMLESS_M4T,
        CHAMELEON_34B,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]
