"""llama4-maverick-400b-a17b — top-1 routed MoE, early fusion, 202k vocab.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202048,
    n_experts=128, top_k=1, moe_d_ff=8192,
    moe_groups_per_dp=16, capacity_factor=1.0,
    opt_state_dtype="bfloat16",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
