"""mamba2-2.7b — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    ssm=True, ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    source="arXiv:2405.21060",
)
