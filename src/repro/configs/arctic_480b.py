"""arctic-480b — 128-expert top-2 MoE + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    moe_groups_per_dp=16, capacity_factor=1.0,
    train_microbatches=4,
    opt_state_dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
)
