"""chameleon-34b — early-fusion VLM; VQ image tokens live in the 65536 vocab,
so the modality frontend stub is the token stream itself.  Uses qk-norm
(per the Chameleon paper's training-stability recipe).
[arXiv:2405.09818; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab_size=65536, qk_norm=True,
    force_kv_seq_attn=True,  # adopted: EXPERIMENTS.md §Perf iters 4-5
    source="arXiv:2405.09818",
)
