"""mistral-nemo-12b — dense GQA, 128k context (rope theta 1e6).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=131072, rope_theta=1e6,
    force_kv_seq_attn=True,  # adopted: EXPERIMENTS.md §Perf iters 4-5
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
