"""qwen3-8b — dense, qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=12288, vocab_size=151936, qk_norm=True,
    force_kv_seq_attn=True,  # adopted: EXPERIMENTS.md §Perf iters 4-5
    source="hf:Qwen/Qwen3-8B",
)
