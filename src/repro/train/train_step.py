"""The jitted training step: loss -> grads -> (optional compression) -> AdamW.

Microbatch gradient accumulation runs as a lax.scan, which lets XLA overlap
each microbatch's backward compute with the previous reduce-scatter (the
standard compute/comm overlap at scale); remat policy lives inside the model
(per scan-unit jax.checkpoint).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.registry import Model
from .optimizer import (AdamWConfig, AdamWState, apply_updates, compress_grads)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    n_microbatches: int = 1,
                    compression: Optional[str] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch['tokens']/['labels']`` are (B, S); with microbatching B splits into
    ``n_microbatches`` leading chunks accumulated in fp32.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def single(params, batch):
        return grad_fn(params, batch)

    def accumulated(params, batch):
        b = batch["tokens"].shape[0]
        assert b % n_microbatches == 0, (b, n_microbatches)
        mb = b // n_microbatches

        def split(x):
            return x.reshape((n_microbatches, mb) + x.shape[1:])

        mbatches = {k: split(v) for k, v in batch.items()}

        def step(acc, mbatch):
            loss, grads = grad_fn(params, mbatch)
            acc_loss, acc_grads = acc
            acc_grads = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
            return (acc_loss + loss, acc_grads), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        init = (jnp.zeros((), jnp.float32), zero)
        if model.cfg.unroll_stack:
            # cost-analysis variants unroll this loop too (a while body is
            # counted once by XLA's cost model; see launch/dryrun.py)
            acc = init
            for i in range(n_microbatches):
                acc, _ = step(acc, jax.tree.map(lambda x: x[i], mbatches))
            loss, grads = acc
        else:
            (loss, grads), _ = jax.lax.scan(step, init, mbatches)
        inv = 1.0 / n_microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]):
        if n_microbatches > 1:
            loss, grads = accumulated(params, batch)
        else:
            loss, grads = single(params, batch)
        grads = compress_grads(grads, compression)
        params, opt_state, metrics = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {**metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step
