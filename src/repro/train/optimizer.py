"""Sharded AdamW (hand-rolled; optax is not available offline) with global-norm
clipping, decoupled weight decay, cosine/linear schedules, and an optional
gradient-compression hook applied before the (XLA-inserted) gradient
reduction — bf16 or int8-with-per-tensor-scale, the cross-pod bandwidth saver.

Optimizer state is a pytree shaped like params (m, v) in ``opt_state_dtype``
(bf16 for the >=100B configs to fit 16 GB/chip HBM; see DESIGN.md).  Because m
and v inherit each param's sharding (FSDP over 'data', TP over 'model'), the
optimizer is ZeRO-style sharded for free.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array     # () int32
    m: Any              # pytree like params
    v: Any              # pytree like params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params: Any, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def abstract_state(abstract_params: Any, cfg: AdamWConfig) -> AdamWState:
    """ShapeDtypeStruct state (keeps each param's sharding) — for the dry-run."""
    dt = jnp.dtype(cfg.state_dtype)

    def mk(p):
        return jax.ShapeDtypeStruct(p.shape, dt, sharding=getattr(p, "sharding", None))

    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(mk, abstract_params),
                      v=jax.tree.map(mk, abstract_params))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9)).astype(jnp.float32)
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# -- gradient compression (cross-pod all-reduce bandwidth) -------------------

def compress_bf16(g: jax.Array) -> jax.Array:
    return g.astype(jnp.bfloat16)


def decompress_bf16(g: jax.Array, like: jnp.dtype) -> jax.Array:
    return g.astype(like)


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, like: jnp.dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(like)


def compress_grads(grads: Any, mode: Optional[str]) -> Any:
    """Round-trip gradient compression (bf16 / int8 + per-tensor scale).

    Scope (honest accounting): XLA inserts the data-parallel gradient
    reductions *inside* the backward dots, before this function runs, so this
    round-trip models the NUMERICS of compressed gradient exchange (what
    training convergence sees) — not a narrower wire in the compiled HLO.
    Narrowing the wire itself requires either a custom partitioner pass or the
    explicit hierarchical cross-pod exchange (shard_map psum over 'pod' on the
    int8 representation) sketched in DESIGN.md §5; the numerics path here is
    what the convergence tests exercise."""
    if mode in (None, "none"):
        return grads
    if mode == "bf16":
        return jax.tree.map(
            lambda g: decompress_bf16(compress_bf16(g), g.dtype), grads)
    if mode == "int8":
        def rt(g):
            q, s = compress_int8(g)
            return decompress_int8(q, s, g.dtype)
        return jax.tree.map(rt, grads)
    raise ValueError(f"unknown compression mode {mode!r}")


# -- the update ---------------------------------------------------------------

def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: AdamWConfig) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(sdt), v32.astype(sdt))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
