from .optimizer import AdamWConfig, AdamWState, apply_updates, init_state
from .train_step import make_train_step
