"""Serving launcher: batched prefill + decode loop on a local mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..models import get_model
    from ..parallel import sharding as shd
    from .mesh import make_host_mesh

    model = get_model(args.arch, reduced=args.reduced)
    cfg = model.cfg
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)
    max_len = args.prompt_len + args.gen

    with mesh, shd.sharding_ctx(mesh):
        params = model.init(jax.random.key(args.seed))
        rng = np.random.default_rng(args.seed)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           (args.batch, args.prompt_len)),
                              jnp.int32)
        frames = None
        if cfg.encdec:
            frames = jnp.asarray(rng.normal(size=(args.batch, args.prompt_len,
                                                  cfg.frontend_dim)),
                                 jnp.dtype(cfg.dtype))

        t0 = time.time()
        logits, cache = jax.jit(
            lambda p, t, f: model.prefill(p, t, max_len, frames=f)
        )(params, prompts, frames)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
        t_prefill = time.time() - t0
        print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill:.2f}s")

        decode = jax.jit(model.decode_step)
        out = [next_tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, cache = decode(params, cache, out[-1].astype(jnp.int32), pos)
            out.append(jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None])
        dt = time.time() - t0
        toks = jnp.concatenate(out, axis=1)
        print(f"decoded {args.gen - 1} steps in {dt:.2f}s "
              f"({args.batch * (args.gen - 1) / max(dt, 1e-9):,.1f} tok/s)")
        print("sample:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()
