"""Distributed mining launcher — the paper's workload on a mesh.

  PYTHONPATH=src python -m repro.launch.mine --rows 20000 --items 60 \
      --p-x 0.12 --p-y 0.02 --min-support 0.001 --min-conf 0.2

Runs the Minority-Report pipeline with the TPU-native engine over a local
mesh (transactions sharded over 'data', targets over 'model'), checkpointing
per level; cross-validates the rule set against the paper-faithful host
implementation when --verify.

``--backend`` switches from the MRA pipeline (default ``mra``) to a plain
frequent-itemset mine through a chosen counting engine: ``auto`` consults
the adaptive chooser (``mining/chooser.py``) over measured DB traits and
prints its decision; ``dense``/``streaming``/``gfp`` force an engine.
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20000)
    ap.add_argument("--items", type=int, default=60)
    ap.add_argument("--p-x", type=float, default=0.12)
    ap.add_argument("--p-y", type=float, default=0.02)
    ap.add_argument("--min-support", type=float, default=0.001)
    ap.add_argument("--min-conf", type=float, default=0.05)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt", default=None,
                    help="MiningCheckpoint path: per-chunk durable progress "
                         "(streaming engine), resume mid-level after a kill")
    ap.add_argument("--streaming", action="store_true",
                    help="force the out-of-core chunked engine (default: "
                         "auto-select by encoded DB size)")
    ap.add_argument("--chunk-rows", type=int, default=None,
                    help="rows per streamed chunk (default: staging-budget "
                         "heuristic, see mining/plan.py)")
    ap.add_argument("--backend", default="mra",
                    choices=["mra", "auto", "dense", "streaming", "gfp"],
                    help="mra (default): the full Minority-Report pipeline; "
                         "otherwise mine frequent itemsets through the named "
                         "engine — auto consults the adaptive chooser over "
                         "measured DB traits")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from ..data import bernoulli_db
    from ..mining import minority_report_dense
    from ..mining.distributed import MiningCheckpoint
    from .mesh import make_host_mesh

    tx, y = bernoulli_db(args.rows, args.items, args.p_x, args.p_y, args.seed)
    print(f"db: {args.rows} rows, {args.items} items, "
          f"{int(y.sum())} rare-class rows")

    ckpt = MiningCheckpoint(args.ckpt) if args.ckpt else None
    if ckpt is not None:
        state = ckpt.load_state()
        if state is not None:
            partial = state.get("partial")
            where = (f"mid-level {partial['level']} at chunk "
                     f"{partial['next_chunk']}" if partial
                     else f"level {state['level']} complete")
            print(f"resuming from checkpoint {args.ckpt}: {where}, "
                  f"{len(state['frequent'])} itemsets banked")

    from .. import obs
    from ..roofline import autotune

    print(f"autotune: {autotune.describe_active()}")

    if args.backend != "mra":
        _mine_backend(tx, args, ckpt)
        print(obs.summary_line())
        return
    t0 = time.time()
    res = minority_report_dense(
        tx, y, min_support=args.min_support, min_confidence=args.min_conf,
        streaming=True if args.streaming else None,
        chunk_rows=args.chunk_rows, checkpoint=ckpt)
    t_dense = time.time() - t0
    print(f"{res.engine} engine: {len(res.rules)} rules, "
          f"{res.kernel_launches} kernel "
          f"launches, {t_dense:.2f}s; items kept: {len(res.items_kept)}")
    for r in res.rules[:10]:
        print("  ", r)

    if args.verify:
        from ..core import minority_report
        t0 = time.time()
        host = minority_report(tx, y, min_support=args.min_support,
                               min_confidence=args.min_conf)
        t_host = time.time() - t0
        a = {r.antecedent: (r.count, r.g_count) for r in res.rules}
        b = {r.antecedent: (r.count, r.g_count) for r in host.rules}
        assert a == b, "dense/host rule mismatch!"
        print(f"verified against paper-faithful engine ({t_host:.2f}s): "
              f"{len(b)} rules identical")
    print(obs.summary_line())


def _mine_backend(tx, args, ckpt) -> None:
    """Plain frequent-itemset mine through a chooser-selected (or forced)
    counting backend, with the chooser's decision printed."""
    import time as _time

    from ..core.incremental import ceil_count
    from ..mining import DenseDB, backend_for_db, mine_frequent_backend

    db = DenseDB.encode(tx)
    name = None if args.backend == "auto" else args.backend
    backend, choice = backend_for_db(db, name=name)
    print(f"backend: {choice.name} ({choice.reason})")
    if choice.traits is not None:
        t = choice.traits
        print(f"traits: {t.n_rows} rows ({t.n_unique} unique, "
              f"dedup {t.dedup_ratio:.2f}), density {t.density:.2f}, "
              f"skew {t.skew:.1f}x, {t.nbytes} bytes")

    min_count = ceil_count(args.min_support * len(tx))
    t0 = _time.time()
    frequent = mine_frequent_backend(backend, min_count, checkpoint=ckpt)
    dt = _time.time() - t0
    launches = getattr(backend, "kernel_launches", None)
    extra = "" if launches is None else f", {launches} kernel launches"
    print(f"{choice.name} engine: {len(frequent)} frequent itemsets at "
          f"min_count={min_count} in {dt:.2f}s{extra}")

    if args.verify:
        from ..core import mine_frequent
        t0 = _time.time()
        want = mine_frequent(tx, min_count)
        t_host = _time.time() - t0
        assert frequent == want, "backend/host frequent-set mismatch!"
        print(f"verified against paper-faithful engine ({t_host:.2f}s): "
              f"{len(want)} itemsets identical")


if __name__ == "__main__":
    main()
