"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  These are the dry-run's inputs and the
single source of truth for launcher in_shardings."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import shape_by_name
from ..models.registry import Model, get_model
from ..parallel import sharding as shd
from ..train.optimizer import AdamWConfig, abstract_state
from .mesh import dp_size


def _sds(shape, dtype, logical, mesh):
    return jax.ShapeDtypeStruct(
        tuple(shape), dtype,
        sharding=shd.named_sharding(logical, shape=shape, mesh=mesh))


def batch_specs(model: Model, seq_len: int, global_batch: int, mesh) -> Dict[str, Any]:
    cfg = model.cfg
    out = {
        "tokens": _sds((global_batch, seq_len), jnp.int32,
                       ("act_batch", None), mesh),
        "labels": _sds((global_batch, seq_len), jnp.int32,
                       ("act_batch", None), mesh),
    }
    if cfg.encdec:
        out["frames"] = _sds((global_batch, seq_len, cfg.frontend_dim),
                             jnp.dtype(cfg.dtype), ("act_batch", None, None), mesh)
    return out


def cache_abstract(model: Model, batch: int, max_len: int, mesh) -> Any:
    cfg = model.cfg
    specs = model.cache_specs(batch, max_len, dp_size(mesh))

    def mk(leaf):
        shape, logical = leaf
        if shape == ():  # enc_len scalar
            return jax.ShapeDtypeStruct((), jnp.int32)
        return _sds(shape, jnp.dtype(cfg.dtype), logical, mesh)

    return jax.tree.map(mk, specs,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], tuple))


def input_specs(arch: str, shape_name: str, mesh,
                opt_cfg: Optional[AdamWConfig] = None,
                reduced: bool = False,
                cfg_override=None) -> Tuple[str, Tuple, Dict[str, Any]]:
    """-> (step_kind, args_abstract, info).

    step_kind in {'train', 'prefill', 'decode'}; args match the corresponding
    step function's signature.  ``cfg_override`` swaps in a modified
    ModelConfig (depth-reduced analysis variants, perf-iteration candidates).
    """
    if cfg_override is not None:
        model = Model(cfg_override)
    else:
        model = get_model(arch, reduced=reduced)
    cfg = model.cfg
    shape = shape_by_name(shape_name)
    with shd.sharding_ctx(mesh):
        params = model.abstract(mesh=mesh)
        if shape.kind == "train":
            opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.opt_state_dtype)
            opt = abstract_state(params, opt_cfg)
            batch = batch_specs(model, shape.seq_len, shape.global_batch, mesh)
            return "train", (params, opt, batch), {"model": model,
                                                   "opt_cfg": opt_cfg}
        if shape.kind == "prefill":
            batch = batch_specs(model, shape.seq_len, shape.global_batch, mesh)
            args = (params, batch["tokens"])
            if cfg.encdec:
                args = args + (batch["frames"],)
            return "prefill", args, {"model": model, "max_len": shape.seq_len}
        # decode: one new token against a seq_len-deep cache
        cache = cache_abstract(model, shape.global_batch, shape.seq_len, mesh)
        token = _sds((shape.global_batch, 1), jnp.int32, ("act_batch", None), mesh)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        return "decode", (params, cache, token, pos), {"model": model}


def step_fn(kind: str, info: Dict[str, Any]):
    """The function to lower for a given cell."""
    model: Model = info["model"]
    if kind == "train":
        from ..train.train_step import make_train_step
        return make_train_step(model, info["opt_cfg"],
                               n_microbatches=model.cfg.train_microbatches)
    if kind == "prefill":
        max_len = info["max_len"]
        if model.cfg.encdec:
            def prefill_ed(params, tokens, frames):
                return model.prefill(params, tokens, max_len, frames=frames)
            return prefill_ed
        def prefill_fn(params, tokens):
            return model.prefill(params, tokens, max_len)
        return prefill_fn
    if kind == "decode":
        def decode_fn(params, cache, token, pos):
            return model.decode_step(params, cache, token, pos)
        return decode_fn
    raise ValueError(kind)
