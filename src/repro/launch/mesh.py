"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod = (data=16, model=16) = 256 chips;
multi-pod = (pod=2, data=16, model=16) = 512 chips.  When the process has
more placeholder devices than the mesh needs (the dry-run process always
creates 512), the mesh takes a prefix slice.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    import jax

    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])


def dp_size(mesh) -> int:
    return int(mesh.shape.get("data", 1) * mesh.shape.get("pod", 1))
