"""Count-serving launcher — drive the GFP count server with a query workload.

  PYTHONPATH=src python -m repro.launch.serve_counts --rows 20000 --items 40 \
      --clients 8 --rounds 16 --batch 32 --appends 2 --verify

Builds a synthetic transaction DB, keeps it resident in a ``CountServer``
(device-dense or host-streaming by size), and serves rounds of micro-batched
itemset-count queries from simulated clients — with optional mid-run appends
(version bumps + cache invalidation) and ``--theta`` incremental re-mining.
``--verify`` cross-checks every distinct served key against a fresh dense
encode of the full history at the final version (bit-identical or it dies).

``--shards N`` row-partitions the store over N ``VersionedDB`` shards
(``--mesh-data D`` additionally lays them out over a D-device host mesh —
needs ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` or real
devices).  ``--async-flush`` serves through the background flush loop
(``--max-delay-ms`` / ``--min-batch`` triggers): requests are submitted as
futures and the flush-latency distribution is reported at the end.

``--rules`` layers a ``RuleServer`` on top: every round additionally serves
minority-rule queries (antecedent -> ``--target-class`` at ``--min-conf``)
from the same pool through the rule cache, appends go through the rule
server (stale-verdict purge + hottest-key prefetch), and with ``--theta``
the run ends with a resumable ``top_rules`` sweep.  ``--verify`` then also
cross-checks every served rule — and the top_rules list — against the host
``minority_report`` / ``optimal_rule_set`` oracle on the full history.
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20000)
    ap.add_argument("--items", type=int, default=40)
    ap.add_argument("--p-x", type=float, default=0.15)
    ap.add_argument("--p-y", type=float, default=0.05)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=16,
                    help="flush rounds; each round submits --batch requests")
    ap.add_argument("--batch", type=int, default=32,
                    help="requests coalesced per flush (micro-batch size)")
    ap.add_argument("--targets-per-query", type=int, default=2)
    ap.add_argument("--max-itemset-len", type=int, default=3)
    ap.add_argument("--pool", type=int, default=128,
                    help="distinct query pool size (repeats exercise the cache)")
    ap.add_argument("--appends", type=int, default=0,
                    help="mid-run append batches (version bumps)")
    ap.add_argument("--append-rows", type=int, default=1000)
    ap.add_argument("--theta", type=float, default=None,
                    help="maintain the frequent set incrementally at theta")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--cache-size", type=int, default=65536)
    ap.add_argument("--block-k", type=int, default=None,
                    help="serve K-pad block (default: per-device tuning "
                         "table, else 256)")
    ap.add_argument("--spill-dir", default=None, metavar="DIR",
                    help="disk-tier root: spill the base past the budget "
                         "(default $REPRO_SPILL_DIR)")
    ap.add_argument("--spill-threshold-bytes", type=int, default=None,
                    help="host-RAM budget before the base spills to disk")
    ap.add_argument("--bg-compact", action="store_true",
                    help="fold deltas on a background compactor thread "
                         "instead of inline in append()")
    ap.add_argument("--min-compact-rows", type=int, default=None,
                    help="auto-compaction floor (delta rows)")
    ap.add_argument("--streaming", action="store_true",
                    help="force the host-resident streaming backend")
    ap.add_argument("--chunk-rows", type=int, default=None)
    ap.add_argument("--shards", type=int, default=None,
                    help="row-partition the store over N shards")
    ap.add_argument("--mesh-data", type=int, default=None,
                    help="lay the shards over a D-device host mesh")
    ap.add_argument("--async-flush", action="store_true",
                    help="serve through the background flush loop")
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--min-batch", type=int, default=8)
    ap.add_argument("--rules", action="store_true",
                    help="serve minority rules over the count path")
    ap.add_argument("--min-conf", type=float, default=0.3)
    ap.add_argument("--target-class", type=int, default=1)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus text) and /metrics.json "
                         "on this port for the run's duration (0=ephemeral)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write the final registry snapshot as JSON")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable span tracing; write a Chrome trace_event "
                         "JSON dump (chrome://tracing / Perfetto) and print "
                         "the per-span summary on exit")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from .. import obs
    from ..data import bernoulli_db
    from ..serve import CountServer

    if args.trace:
        obs.configure(tracing=True)
    metrics_srv = None
    if args.metrics_port is not None:
        from ..obs.export import start_metrics_server

        metrics_srv = start_metrics_server(args.metrics_port)
        print(f"metrics: http://127.0.0.1:"
              f"{metrics_srv.server_address[1]}/metrics")

    mesh = None
    if args.mesh_data is not None:
        import jax

        if args.shards is None:
            raise SystemExit("--mesh-data requires --shards")
        if len(jax.devices()) < args.mesh_data:
            raise SystemExit(
                f"--mesh-data {args.mesh_data} needs that many devices "
                f"(have {len(jax.devices())}); set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.mesh_data}")
        mesh = jax.make_mesh((args.mesh_data,), ("data",))

    tx, y = bernoulli_db(args.rows, args.items, args.p_x, args.p_y, args.seed)
    server = CountServer(
        tx, classes=list(y), use_kernel=True,
        streaming=True if args.streaming else None,
        chunk_rows=args.chunk_rows, cache=not args.no_cache,
        cache_size=args.cache_size, block_k=args.block_k,
        min_compact_rows=args.min_compact_rows, spill_dir=args.spill_dir,
        spill_threshold_bytes=args.spill_threshold_bytes,
        background_compaction=args.bg_compact,
        shards=args.shards, mesh=mesh, async_flush=args.async_flush,
        max_delay_ms=args.max_delay_ms, min_batch=args.min_batch)
    st = server.store
    print(f"resident: {st.resident} DB, {st.base_rows} unique rows "
          f"(of {st.n_rows}), {st.vocab.size} items, v{st.version}")
    from ..roofline import autotune
    print(f"autotune: {autotune.describe_active()} "
          f"(block_k={server.batcher.block_k})")
    ruler = None
    if args.rules:
        from ..serve import RuleServer

        ruler = RuleServer(server, target_class=args.target_class,
                           cache=not args.no_cache)
    if args.theta is not None:
        t0 = time.time()
        freq = server.mine(args.theta)
        print(f"mined {len(freq)} frequent itemsets at theta={args.theta} "
              f"({time.time() - t0:.2f}s)")

    rng = np.random.default_rng(args.seed + 1)
    pool = [tuple(rng.choice(args.items,
                             size=rng.integers(1, args.max_itemset_len + 1),
                             replace=False).tolist())
            for _ in range(args.pool)]
    # spread appends over rounds 1..rounds-1 without collapsing: linspace
    # over the ROUND INDICES keeps every pick distinct (spacing >= 1) and
    # caps the count at the available rounds
    avail = list(range(1, args.rounds))
    n_app = min(args.appends, len(avail))
    append_at = ({avail[i] for i in
                  np.linspace(0, len(avail) - 1, n_app).round().astype(int)}
                 if n_app > 0 else set())
    if len(append_at) < args.appends:
        print(f"note: only {len(append_at)} append rounds fit in "
              f"--rounds {args.rounds}")

    n_queries = 0
    n_rule_queries = 0
    t_serve = 0.0
    t_rules = 0.0
    for rnd in range(args.rounds):
        if rnd in append_at:
            batch, yb = bernoulli_db(args.append_rows, args.items, args.p_x,
                                     args.p_y, args.seed + 100 + rnd)
            t0 = time.time()
            appender = server if ruler is None else ruler
            v = appender.append(batch, classes=list(yb))
            msg = f"append #{v}: +{len(batch)} rows ({time.time()-t0:.2f}s)"
            if args.theta is not None:
                msg += f", frequent set -> {len(server.frequent)}"
            print(msg)
        t0 = time.time()
        futures = []
        for b in range(args.batch):
            client = f"client-{(rnd * args.batch + b) % args.clients}"
            picks = rng.integers(0, len(pool), args.targets_per_query)
            request = [pool[i] for i in picks]
            if args.async_flush:
                futures.append(server.submit_async(client, request))
            else:
                server.submit(client, request)
            n_queries += args.targets_per_query
        if args.async_flush:
            for fut in futures:
                fut.result(timeout=60)   # background loop answers the round
        else:
            server.flush()
        t_serve += time.time() - t0
        if ruler is not None:            # rule traffic rides the same pool,
            t0 = time.time()             # timed on its own clock
            picks = rng.integers(0, len(pool), args.batch)
            ruler.rules_for([pool[i] for i in picks],
                            min_conf=args.min_conf)
            t_rules += time.time() - t0
            n_rule_queries += args.batch
    server.close()                        # drains any still-pending tickets

    us_q = 1e6 * t_serve / max(1, n_queries)
    print(f"served {n_queries} queries in {args.rounds} rounds: "
          f"{us_q:.1f} us/query, {n_queries / max(t_serve, 1e-9):,.0f} q/s")
    s = server.stats()
    if s["async"] is not None:
        a = s["async"]
        lat = a["flush_latency_ms"]
        print(f"async: {a['flushes']} flushes {a['by_trigger']}, "
              f"{a['flush_errors']} errors, flush latency "
              f"p50={lat['p50']:.1f}ms p95={lat['p95']:.1f}ms "
              f"max={lat['max']:.1f}ms (budget {a['max_delay_ms']:.0f}ms)")
    cache = s["cache"]
    cache_msg = ("cache off" if cache is None else
                 f"cache hit rate {cache['hit_rate']:.2f} "
                 f"({cache['hits']} hits)")
    print(f"batcher deduped {s['batcher']['deduped']}/"
          f"{s['batcher']['queries']} queries; {cache_msg}; "
          f"{s['store']['kernel_launches']} kernel launches")
    top = None
    if ruler is not None:
        rst = ruler.stats()
        rc = rst["rule_cache"]
        rc_msg = ("rule cache off" if rc is None else
                  f"rule cache hit rate {rc['hit_rate']:.2f} "
                  f"({rc['hits']} hits)")
        us_r = 1e6 * t_rules / max(1, n_rule_queries)
        print(f"rules: {rst['rule_queries']} rule queries "
              f"({us_r:.1f} us/rule-query), {rst['prefetches']} prefetch "
              f"rounds ({rst['prefetched_keys']} keys re-warmed); {rc_msg}")
        if args.theta is not None:
            t0 = time.time()
            top = ruler.top_rules(args.theta, args.min_conf, optimal=True)
            print(f"top_rules(theta={args.theta}, "
                  f"min_conf={args.min_conf}): {len(top)} optimal rules "
                  f"({time.time() - t0:.2f}s)")
            for r in top[:3]:
                print(f"  {r}")

    if args.verify:
        from ..mining import DenseDB, encode_targets
        from ..kernels.itemset_count import itemset_counts
        import jax.numpy as jnp

        # rebuild the full history exactly as served
        all_tx = [list(t) for t in tx]
        all_y = list(y)
        for rnd in sorted(append_at):
            batch, yb = bernoulli_db(args.append_rows, args.items, args.p_x,
                                     args.p_y, args.seed + 100 + rnd)
            all_tx += [list(t) for t in batch]
            all_y += list(yb)
        ddb = DenseDB.encode(all_tx, classes=all_y,
                             n_classes=server.store.n_classes)
        keys = [k for k in pool if all(a in ddb.vocab for a in k)]
        got = server.query(keys)
        want = np.asarray(itemset_counts(
            ddb.bits, jnp.asarray(encode_targets(keys, ddb.vocab)),
            ddb.weights))
        assert (got == want).all(), "served counts != fresh dense"
        print(f"verified {len(keys)} keys bit-identical to a fresh dense "
              f"encode at v{server.store.version}")
        if ruler is not None:
            # served rule verdicts vs the independently counted fresh rows
            served = ruler.rules_for(keys, min_conf=args.min_conf)
            n_db = server.store.n_rows
            for key, row, rule in zip(keys, want, served):
                key = tuple(sorted(set(key), key=repr))
                cnt = int(row[args.target_class])
                gcnt = int(row.sum()) - cnt
                conf = cnt / (cnt + gcnt) if (cnt + gcnt) else 0.0
                if conf >= args.min_conf:
                    assert rule is not None and rule.count == cnt \
                        and rule.g_count == gcnt \
                        and rule.confidence == conf \
                        and rule.support == cnt / n_db, key
                else:
                    assert rule is None, key
            if args.theta is not None:
                from ..core import minority_report, optimal_rule_set

                res = minority_report(
                    all_tx, all_y, target_class=args.target_class,
                    min_support=args.theta, min_confidence=args.min_conf)
                assert ruler.top_rules(args.theta, args.min_conf) \
                    == res.rules, "served rule set != host minority_report"
                assert top == optimal_rule_set(res.rules), \
                    "served optimal set != host optimal_rule_set"
                print(f"verified {len(res.rules)} rules "
                      f"({len(top)} optimal) == host minority_report "
                      f"oracle at v{server.store.version}")

    snap = obs.snapshot()
    if args.metrics_dump:
        from ..obs.export import dump_json

        dump_json(args.metrics_dump, snap,
                  extra={"kernel_efficiency": obs.kernel_efficiency(snap)})
        print(f"metrics snapshot -> {args.metrics_dump}")
    if args.trace:
        import json

        with open(args.trace, "w") as f:
            json.dump(obs.TRACER.chrome_trace(), f)
        print(f"chrome trace ({len(obs.TRACER.spans())} spans) -> "
              f"{args.trace}")
        print(obs.TRACER.summary())
    if metrics_srv is not None:
        metrics_srv.shutdown()
    print(obs.summary_line(snap))


if __name__ == "__main__":
    main()
