import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init); everything below may now import jax freely.

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell and
extract memory_analysis / cost_analysis / collective schedule for §Dry-run and
§Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch arctic-480b --shape decode_32k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl
"""
import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax

from ..configs import ARCHS, get_config
from ..models import shape_by_name, ALL_SHAPES
from ..parallel import sharding as shd
from ..roofline.analysis import analyze, model_flops
from .mesh import make_production_mesh
from .specs import input_specs, step_fn


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.has_subquadratic_decode():
        return "SKIP(full-attn): 524k decode requires sub-quadratic mixer"
    return None


def _compile_cell(arch, shape_name, mesh, cfg_override=None):
    kind, args, info = input_specs(arch, shape_name, mesh,
                                   cfg_override=cfg_override)
    fn = step_fn(kind, info)
    with mesh, shd.sharding_ctx(mesh):
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    return kind, compiled


def _depth_variant(cfg, n_units: int):
    import dataclasses
    unit = cfg.superblock or (cfg.moe_every if cfg.is_moe and cfg.moe_every > 1 else 1)
    return dataclasses.replace(
        cfg, n_layers=unit * n_units, unroll_stack=True,
        # the q-block and SSD-chunk scans are while loops too — their bodies
        # would be counted once; single-block/-chunk shapes in the analysis
        # variants keep the FLOP/wire accounting exact (compile-only, so the
        # giant score tiles are symbolic, never allocated)
        attn_block_q=1 << 20, ssm_chunk=1 << 20,
        n_enc_layers=min(cfg.n_enc_layers, n_units) if cfg.encdec else 0)


def corrected_roofline(arch, shape_name, mesh, compiled_full, n_devices):
    """cost_analysis counts a lax.scan (while-loop) body ONCE; correct the
    totals by measuring the per-unit delta between depth-1 and depth-2
    compiles and extrapolating linearly to the true unit count (exact for
    homogeneous scan bodies).  Memory analysis still comes from the full
    compile."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    unit = cfg.superblock or (cfg.moe_every if cfg.is_moe and cfg.moe_every > 1 else 1)
    n_units = cfg.n_layers // unit
    mf = model_flops(cfg, shape)

    full = analyze(compiled_full, mf, n_devices)
    _, c1 = _compile_cell(arch, shape_name, mesh, _depth_variant(cfg, 1))
    _, c2 = _compile_cell(arch, shape_name, mesh, _depth_variant(cfg, 2))
    r1 = analyze(c1, mf, n_devices)
    r2 = analyze(c2, mf, n_devices)

    def extrap(v1, v2):
        delta = v2 - v1
        return max(v1 + (n_units - 1) * delta, 0.0)

    # enc-dec: encoder scan corrects with the same delta trick (enc units
    # scale together with dec units in the variants; linearity still holds
    # since both stacks are homogeneous).
    import dataclasses
    corrected = dataclasses.replace(
        full,
        flops=extrap(r1.flops, r2.flops),
        bytes_accessed=extrap(r1.bytes_accessed, r2.bytes_accessed),
        wire_bytes=extrap(r1.wire_bytes, r2.wire_bytes),
    )
    return full, corrected


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quiet: bool = False, correct_scan: bool = True) -> dict:
    reason = skip_reason(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if reason:
        rec["status"] = "skip"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.devices.size
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)

    t0 = time.time()
    kind, args, info = input_specs(arch, shape_name, mesh)
    fn = step_fn(kind, info)
    with mesh, shd.sharding_ctx(mesh):
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
    if correct_scan:
        raw, roof = corrected_roofline(arch, shape_name, mesh, compiled,
                                       n_devices)
        rec["roofline_raw_scan_body_once"] = raw.as_dict()
    else:
        roof = analyze(compiled, model_flops(cfg, shape), n_devices)

    rec.update({
        "status": "ok",
        "kind": kind,
        "n_devices": n_devices,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_est": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "roofline": roof.as_dict(),
    })
    if not quiet:
        print(f"[{arch} × {shape_name} × {rec['mesh']}] kind={kind}")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB  (per device)")
        print(f"  cost_analysis: flops/dev={roof.flops:.3e} "
              f"bytes/dev={roof.bytes_accessed:.3e} wire/dev={roof.wire_bytes:.3e}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"-> bottleneck={roof.bottleneck} "
              f"useful={roof.useful_ratio:.2f} frac={roof.roofline_fraction:.3f}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES], default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch, shape) for the chosen mesh")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--no-correct", action="store_true",
                    help="skip the scan-body depth-correction compiles "
                         "(multi-pod sweep: compile proof only)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for s in ALL_SHAPES:
                cells.append((arch, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape_name in cells:
        try:
            rec = run_cell(arch, shape_name, args.multi_pod,
                           correct_scan=not args.no_correct)
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "fail", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            failures += 1
            print(f"[{arch} × {shape_name}] FAILED: {rec['error']}",
                  file=sys.stderr)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
