"""Training launcher: config-driven, fault-tolerant, restartable.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1 [--resume]

Production behaviour exercised here end-to-end (and by tests):
  * deterministic data as a function of step (elastic-safe),
  * periodic async checkpoints (atomic publish),
  * SIGTERM -> checkpoint-and-exit (PreemptionGuard),
  * resume from the latest checkpoint (optionally on a different mesh),
  * straggler detection hooks,
  * gradient compression for cross-pod reduction.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default=None, choices=[None, "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    from ..checkpoint import CheckpointManager, PreemptionGuard, StragglerMonitor
    from ..data import TokenPipeline
    from ..models import get_model
    from ..parallel import sharding as shd
    from ..train import AdamWConfig, init_state, make_train_step
    from .mesh import make_host_mesh

    model = get_model(args.arch, reduced=args.reduced)
    cfg = model.cfg
    mesh = make_host_mesh(args.data_mesh, args.model_mesh)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20),
                          state_dtype=cfg.opt_state_dtype)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    guard = PreemptionGuard().install()
    straggler = StragglerMonitor()

    with mesh, shd.sharding_ctx(mesh):
        params = model.init(jax.random.key(args.seed))
        opt_state = init_state(params, opt_cfg)
        start_step = 0
        if args.resume and mgr and mgr.latest_step() is not None:
            (params, opt_state), manifest = mgr.restore((params, opt_state))
            start_step = manifest["step"]
            print(f"resumed from step {start_step}")

        step_fn = jax.jit(make_train_step(model, opt_cfg,
                                          n_microbatches=args.microbatches,
                                          compression=args.compression))
        n_tok = args.batch * args.seq
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in pipe.host_slice(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            slow = straggler.record(dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{n_tok/dt:,.0f} tok/s{'  [straggler]' if slow else ''}")
            should_ckpt = mgr and (step + 1) % args.ckpt_every == 0
            if guard.requested:
                print("SIGTERM received: checkpointing and exiting")
                if mgr:
                    mgr.save(step + 1, (params, opt_state), blocking=True)
                return
            if should_ckpt:
                mgr.save(step + 1, (params, opt_state))
        if mgr:
            mgr.save(args.steps, (params, opt_state), blocking=True)
        guard.uninstall()
        print("done")


if __name__ == "__main__":
    main()
