import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: compile a cell under a named config variant and
report the roofline delta vs the baseline config.

  PYTHONPATH=src python -m repro.launch.perf --arch arctic-480b \
      --shape train_4k --variant moe_gather

Variants are explicit, named hypotheses (see VARIANTS below); each run prints
baseline and variant three-term rooflines so the hypothesis→change→measure
cycle lands directly in EXPERIMENTS.md §Perf.
"""
import argparse
import dataclasses
import json

import jax

from ..configs import ARCHS, get_config
from ..models import shape_by_name, ALL_SHAPES
from ..parallel import sharding as shd
from ..roofline.analysis import analyze, model_flops
from .dryrun import _compile_cell, _depth_variant
from .mesh import make_production_mesh
from .specs import input_specs, step_fn


def v_moe_gather(cfg):
    """MoE dispatch via sort/gather buffers instead of one-hot einsums —
    hypothesis: removes the 2·T·(E·C)·D dispatch/combine FLOPs (~30-70% of
    MoE-layer HLO flops) and the (T,E,C) transient."""
    return dataclasses.replace(cfg, moe_impl="gather")


def v_no_remat(cfg):
    """Disable activation rematerialization — hypothesis: removes the
    recomputed forward (~25% of train FLOPs) and its re-gathers, paying
    activation HBM instead.  Only sane where memory headroom exists."""
    return dataclasses.replace(cfg, remat=False)


def v_attn_kv_seq(cfg):
    """Force the kv_seq (split-KV) attention sharding even when heads divide
    the mesh — hypothesis: k/v stay seq-sharded (no repeat-to-heads gather);
    scores psum over 'model' instead.  Wins when Skv is large vs H."""
    return dataclasses.replace(cfg, force_kv_seq_attn=True)


def v_cap_075(cfg):
    """Capacity factor 1.0 -> 0.75 — hypothesis: linear cut of expert-FFN and
    dispatch FLOPs/bytes at the cost of more dropped tokens (quality trade
    recorded, not evaluated here)."""
    return dataclasses.replace(cfg, capacity_factor=0.75)


def v_groups_x2(cfg):
    """Double dispatch groups — hypothesis: halves the (T_g,E,C) dispatch
    transient and its HBM traffic at equal FLOPs."""
    return dataclasses.replace(cfg, moe_groups_per_dp=cfg.moe_groups_per_dp * 2)


def v_chunk_512(cfg):
    """SSD chunk 128/256 -> 512 — hypothesis: fewer inter-chunk scan steps
    (less state HBM traffic) at quadratically larger intra-chunk matmuls;
    helps while compute term has headroom."""
    return dataclasses.replace(cfg, ssm_chunk=512)


def v_qblock_2048(cfg):
    """Attention q-block 512 -> 2048 — hypothesis: 4x fewer scan steps and
    score-tile launches; raises transient memory by 4x."""
    return dataclasses.replace(cfg, attn_block_q=2048)


def v_mb4(cfg):
    """4 gradient-accumulation microbatches — hypothesis: activation
    transients (the (B,S,D)-sized live set dominating MoE train temp) shrink
    ~4x; FSDP weight re-gathers go up ~4x (wire trade)."""
    return dataclasses.replace(cfg, train_microbatches=4)


def v_mb8(cfg):
    return dataclasses.replace(cfg, train_microbatches=8)


VARIANTS = {
    "mb4": v_mb4,
    "mb8": v_mb8,
    "moe_gather": v_moe_gather,
    "no_remat": v_no_remat,
    "attn_kv_seq": v_attn_kv_seq,
    "cap_0.75": v_cap_075,
    "groups_x2": v_groups_x2,
    "ssd_chunk_512": v_chunk_512,
    "qblock_2048": v_qblock_2048,
}


def measure(arch, shape_name, mesh, cfg, n_devices):
    """Corrected roofline for an arbitrary cfg (same depth-delta method)."""
    base_cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    unit = cfg.superblock or (cfg.moe_every if cfg.is_moe and cfg.moe_every > 1 else 1)
    n_units = cfg.n_layers // unit
    mf = model_flops(base_cfg, shape)
    _, cfull = _compile_cell(arch, shape_name, mesh, cfg)
    mem = cfull.memory_analysis()
    d1 = dataclasses.replace(cfg, n_layers=unit, unroll_stack=True,
                             n_enc_layers=min(cfg.n_enc_layers, 1) if cfg.encdec else 0)
    d2 = dataclasses.replace(cfg, n_layers=unit * 2, unroll_stack=True,
                             n_enc_layers=min(cfg.n_enc_layers, 2) if cfg.encdec else 0)
    _, c1 = _compile_cell(arch, shape_name, mesh, d1)
    _, c2 = _compile_cell(arch, shape_name, mesh, d2)
    r1 = analyze(c1, mf, n_devices)
    r2 = analyze(c2, mf, n_devices)
    full = analyze(cfull, mf, n_devices)

    def extrap(v1, v2):
        return max(v1 + (n_units - 1) * (v2 - v1), 0.0)

    roof = dataclasses.replace(
        full,
        flops=extrap(r1.flops, r2.flops),
        bytes_accessed=extrap(r1.bytes_accessed, r2.bytes_accessed),
        wire_bytes=extrap(r1.wire_bytes, r2.wire_bytes))
    return roof, mem


def fmt(roof, mem) -> str:
    return (f"compute={roof.t_compute*1e3:9.1f}ms memory={roof.t_memory*1e3:9.1f}ms "
            f"collective={roof.t_collective*1e3:9.1f}ms bottleneck={roof.bottleneck:10s} "
            f"useful={roof.useful_ratio:5.2f} frac={roof.roofline_fraction:6.3f} "
            f"temp={mem.temp_size_in_bytes/2**30:6.2f}GiB args={mem.argument_size_in_bytes/2**30:6.2f}GiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES], required=True)
    ap.add_argument("--variant", choices=sorted(VARIANTS), required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-baseline", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n = mesh.devices.size
    base_cfg = get_config(args.arch)
    if not args.skip_baseline:
        roof, mem = measure(args.arch, args.shape, mesh, base_cfg, n)
        print(f"BASELINE {args.arch}×{args.shape}: {fmt(roof, mem)}")
    vcfg = VARIANTS[args.variant](base_cfg)
    roof, mem = measure(args.arch, args.shape, mesh, vcfg, n)
    print(f"VARIANT[{args.variant}] {args.arch}×{args.shape}: {fmt(roof, mem)}")


if __name__ == "__main__":
    main()
