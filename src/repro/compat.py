"""Compatibility shims across the jax versions this repo runs under.

The container pins one jax version; CI images and dev machines drift.  Two
API seams matter to us:

  * ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
    and renamed its replication-check kwarg ``check_rep`` -> ``check_vma``;
  * ``jax.sharding.AbstractMesh`` changed its constructor from
    ``((name, size), ...)`` pairs to ``(sizes, names)``.

Callers use these wrappers and stay version-agnostic.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6: public top-level API
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg spelled per-version.

    Accepts ``check_vma=`` (the modern spelling) and translates as needed.
    """
    if "check_vma" in kwargs and _CHECK_KW != "check_vma":
        kwargs[_CHECK_KW] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)


def abstract_mesh(axis_sizes, axis_names):
    """Version-agnostic ``jax.sharding.AbstractMesh`` constructor."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # older jax: ((name, size), ...) pairs
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))
