"""Process-wide metrics registry — counters, gauges, fixed-bucket histograms.

Designed for the serving hot path:

  * **no locks on record** — every recording thread gets its own *shard*
    (a ``threading.local`` dict of plain int/float cells); ``inc()`` /
    ``observe()`` are a couple of dict operations by the owning thread, so
    there are no lost updates and nothing to contend on.  The registry lock
    is taken only to REGISTER a new shard (once per thread) and to snapshot.
  * **exact ledgers** — shards are thread-confined, so ``snapshot()`` (which
    sums across shards under the registry lock) can lag an in-flight bump but
    never double- or under-counts a completed one.  Histograms maintain
    ``count == sum(bucket_counts)`` by construction: each ``observe`` bumps
    exactly one bucket, the count, and the sum.
  * **zero overhead when disabled** — ``enabled`` is checked first in every
    record method and the call returns without allocating; the
    zero-allocation contract on the count path is pinned by
    ``tests/test_obs.py`` with a tracemalloc filter over this package.

Instruments are BOUND: ``registry.counter(name, **labels)`` resolves the
label key once and returns a :class:`Counter` whose ``inc`` is just the
shard bump — create instruments at module/instance setup, not per call.
Gauges are last-write-wins cells written directly on the registry (a single
GIL-atomic dict store; gauges are not hot-path instruments).

Counters here are allowed negative increments (e.g. the batcher rolls back
its dedup counter when a failed flush restores requests) — the registry is
an exact ledger first, a Prometheus exposition second.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]
MetricKey = Tuple[str, LabelKey]

# Default histogram buckets: latencies in milliseconds, log-ish spacing from
# sub-100us dispatches to multi-second mines.  Upper bounds; +inf implicit.
DEFAULT_MS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                      50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 10000.0)


def label_key(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def nearest_rank(sorted_values: Sequence[float], p: float) -> Optional[float]:
    """Exact nearest-rank percentile of an ascending-sorted sample.

    The nearest-rank definition: the p-th percentile of n samples is the
    value at (1-based) rank ``ceil(p * n)`` — exact on small samples, always
    an observed value, never an interpolation.  ``p`` in (0, 1];
    returns None on an empty sample."""
    n = len(sorted_values)
    if n == 0:
        return None
    if not (0.0 < p <= 1.0):
        raise ValueError("p in (0, 1]")
    return sorted_values[max(0, math.ceil(p * n) - 1)]


class _HistCell:
    """One thread's shard of one histogram: bucket counts + sum + count."""

    __slots__ = ("buckets", "counts", "total", "n")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1: the +inf bucket
        self.total = 0.0
        self.n = 0


class _Shard:
    """Per-thread recording surface: plain dicts, touched only by the owner."""

    __slots__ = ("counters", "hists")

    def __init__(self):
        self.counters: Dict[MetricKey, float] = {}
        self.hists: Dict[MetricKey, _HistCell] = {}


class Counter:
    """Bound counter: ``inc(n)`` bumps this thread's shard cell."""

    __slots__ = ("_reg", "key")

    def __init__(self, reg: "MetricsRegistry", key: MetricKey):
        self._reg = reg
        self.key = key

    def inc(self, n: float = 1) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        d = reg._shard().counters
        d[self.key] = d.get(self.key, 0) + n


class Histogram:
    """Bound fixed-bucket histogram: ``observe(v)`` bumps exactly one bucket
    (bisect over the registered upper bounds), the count, and the sum."""

    __slots__ = ("_reg", "key", "buckets")

    def __init__(self, reg: "MetricsRegistry", key: MetricKey,
                 buckets: Tuple[float, ...]):
        self._reg = reg
        self.key = key
        self.buckets = buckets

    def observe(self, v: float) -> None:
        reg = self._reg
        if not reg.enabled:
            return
        hists = reg._shard().hists
        cell = hists.get(self.key)
        if cell is None:
            cell = hists[self.key] = _HistCell(self.buckets)
        # bucket i holds v <= buckets[i]; the last slot is the +inf bucket
        cell.counts[bisect.bisect_left(cell.buckets, v)] += 1
        cell.total += v
        cell.n += 1

    def observe_many(self, values: Sequence[float]) -> None:
        """Bulk observe: ONE shard/cell fetch, then a tight loop.  The
        drain-point companion to :meth:`observe` — per-item latencies
        recorded where a batch is drained cost a fraction of per-item
        ``observe`` calls on the submit path."""
        reg = self._reg
        if not reg.enabled or not values:
            return
        hists = reg._shard().hists
        cell = hists.get(self.key)
        if cell is None:
            cell = hists[self.key] = _HistCell(self.buckets)
        counts, buckets, bl = cell.counts, cell.buckets, bisect.bisect_left
        total = 0.0
        for v in values:
            counts[bl(buckets, v)] += 1
            total += v
        cell.total += total
        cell.n += len(values)


class Gauge:
    """Bound gauge: last-write-wins cell on the registry."""

    __slots__ = ("_reg", "key")

    def __init__(self, reg: "MetricsRegistry", key: MetricKey):
        self._reg = reg
        self.key = key

    def set(self, v: float) -> None:
        if self._reg.enabled:
            self._reg._gauges[self.key] = v


class MetricsRegistry:
    """The process-wide instrument store (see module docstring)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._local = threading.local()
        self._shards: List[_Shard] = []
        self._gauges: Dict[MetricKey, float] = {}
        self._hist_buckets: Dict[str, Tuple[float, ...]] = {}

    # -- shard plumbing -------------------------------------------------------
    def _shard(self) -> _Shard:
        s = getattr(self._local, "shard", None)
        if s is None:
            s = _Shard()
            self._local.shard = s
            with self._lock:
                self._shards.append(s)
        return s

    @property
    def n_shards(self) -> int:
        """Registered per-thread shards (0 until something records)."""
        with self._lock:
            return len(self._shards)

    def reset(self) -> None:
        """Drop every recorded value and shard.  Only safe when no recording
        thread is mid-bump (tests / process teardown); bound instruments keep
        working — their next record re-registers a shard."""
        with self._lock:
            self._shards.clear()
            self._gauges.clear()
        # threads that still hold a threading.local shard must get a fresh
        # one on their next record, or their old (now-unregistered) cells
        # would silently vanish from snapshots
        self._local = threading.local()

    # -- instrument construction ----------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        return Counter(self, (name, label_key(labels)))

    def gauge(self, name: str, **labels) -> Gauge:
        return Gauge(self, (name, label_key(labels)))

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        """Bound histogram; the FIRST registration fixes the bucket bounds
        for the name (every label set of one name shares one grid, so
        snapshots aggregate and export coherently)."""
        with self._lock:
            have = self._hist_buckets.get(name)
            if have is None:
                have = tuple(sorted(buckets)) if buckets is not None \
                    else DEFAULT_MS_BUCKETS
                self._hist_buckets[name] = have
            elif buckets is not None and tuple(sorted(buckets)) != have:
                raise ValueError(
                    f"histogram {name!r} already registered with different "
                    f"buckets")
        return Histogram(self, (name, label_key(labels)), have)

    def set_gauge(self, name: str, value: float, *, exclusive: bool = False,
                  **labels) -> None:
        """Direct gauge write; ``exclusive=True`` clears every OTHER label
        set of the same name first (a one-hot decision gauge, e.g. the
        chooser's last verdict)."""
        if not self.enabled:
            return
        key = (name, label_key(labels))
        with self._lock:
            if exclusive:
                for k in [k for k in self._gauges if k[0] == name]:
                    del self._gauges[k]
            self._gauges[key] = value

    # -- snapshot -------------------------------------------------------------
    def snapshot(self) -> dict:
        """Merge all shards into one JSON-safe view:

        ``{"counters": {name: {label_str: value}},
           "gauges":   {name: {label_str: value}},
           "histograms": {name: {label_str: {"buckets": [...],
                                             "counts": [...],
                                             "sum": s, "count": n}}}}``

        where ``label_str`` is ``a=1,b=2`` (empty string for no labels).
        """
        counters: Dict[MetricKey, float] = {}
        hists: Dict[MetricKey, dict] = {}
        with self._lock:
            shards = list(self._shards)
            gauges = dict(self._gauges)
        for s in shards:
            for k, v in list(s.counters.items()):
                counters[k] = counters.get(k, 0) + v
            for k, cell in list(s.hists.items()):
                agg = hists.get(k)
                if agg is None:
                    agg = hists[k] = {"buckets": list(cell.buckets),
                                      "counts": [0] * len(cell.counts),
                                      "sum": 0.0, "count": 0}
                for i, c in enumerate(cell.counts):
                    agg["counts"][i] += c
                agg["sum"] += cell.total
                agg["count"] += cell.n
        return {"counters": _nest(counters), "gauges": _nest(gauges),
                "histograms": _nest(hists)}


def _label_str(lk: LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in lk)


def _nest(flat: Dict[MetricKey, object]) -> Dict[str, dict]:
    out: Dict[str, dict] = {}
    for (name, lk), v in sorted(flat.items(), key=lambda kv: kv[0]):
        out.setdefault(name, {})[_label_str(lk)] = v
    return out


# -- snapshot readers (shared by exports, summaries, and tests) --------------

def counter_total(snap: dict, name: str) -> float:
    """Sum of a counter across all label sets (0 when absent)."""
    return sum((snap.get("counters", {}).get(name) or {}).values())


def counter_value(snap: dict, name: str, **labels) -> float:
    return (snap.get("counters", {}).get(name) or {}).get(
        _label_str(label_key(labels)), 0)


def hist_get(snap: dict, name: str, label_str: str = "") -> Optional[dict]:
    return (snap.get("histograms", {}).get(name) or {}).get(label_str)


def hist_merge(snap: dict, name: str) -> Optional[dict]:
    """Aggregate one histogram name across its label sets."""
    sets = snap.get("histograms", {}).get(name)
    if not sets:
        return None
    out = None
    for h in sets.values():
        if out is None:
            out = {"buckets": list(h["buckets"]),
                   "counts": list(h["counts"]),
                   "sum": h["sum"], "count": h["count"]}
        else:
            out["counts"] = [a + b for a, b in zip(out["counts"],
                                                   h["counts"])]
            out["sum"] += h["sum"]
            out["count"] += h["count"]
    return out


def hist_quantile(hist: Optional[dict], p: float) -> Optional[float]:
    """Nearest-rank quantile over a bucketed histogram: the upper bound of
    the bucket holding the ceil(p*n)-th observation (conservative — the true
    value is <= the returned bound; +inf bucket reports the overall mean as
    the best available point estimate)."""
    if not hist or not hist["count"]:
        return None
    rank = max(1, math.ceil(p * hist["count"]))
    seen = 0
    for ub, c in zip(hist["buckets"], hist["counts"]):
        seen += c
        if seen >= rank:
            return ub
    return hist["sum"] / hist["count"]   # landed in the +inf bucket
