"""Span tracing of the query lifecycle — ring-buffered, Chrome-dumpable.

A :class:`Span` covers one timed region (``with tracer.span("serve.flush")``)
with per-span attributes (backend chosen, n_masks, chunk index, cache
verdict...).  Parent/child structure comes from a thread-local span stack:
a span opened while another is live on the same thread records that span's
id as its ``parent_id`` — so the full ``submit -> queue wait -> dedup ->
flush -> backend counts -> cache fill -> reply`` chain nests naturally, and
cross-thread handoffs (an async submit answered by the flusher thread)
link through explicit attributes (ticket ids) instead of fake nesting.

Finished spans land in a bounded ring buffer (``deque(maxlen=...)``) — the
store is O(capacity) forever, old spans age out.  Export:

  * :meth:`Tracer.chrome_trace` — Chrome ``trace_event`` JSON (open in
    ``chrome://tracing`` / Perfetto): one ``"ph": "X"`` complete event per
    span, instants as ``"ph": "i"``, span/parent ids in ``args``;
  * :meth:`Tracer.summary` — human per-span-name table (count, total,
    mean, max) for terminal dumps.

Tracing is OFF by default (the ring buffer and per-span objects are real
allocations); ``tracer.enabled = True`` (or ``repro.obs.configure``) turns
it on.  When disabled, ``span()`` returns a shared no-op singleton without
allocating — the same zero-overhead contract as the metrics registry.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

DEFAULT_RING_SPANS = 16384


class _NoopSpan:
    """Shared do-nothing span: returned by a disabled tracer."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, key: str, value) -> None:
        return None


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed region; finished spans are immutable ring entries."""

    __slots__ = ("tracer", "name", "span_id", "parent_id", "tid",
                 "t0", "t1", "attrs")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[dict] = None):
        self.tracer = tracer
        self.name = name
        self.span_id = next(tracer._ids)
        self.parent_id: Optional[int] = None
        self.tid = threading.get_ident()
        self.t0 = 0.0
        self.t1 = 0.0
        self.attrs: Dict[str, object] = dict(attrs) if attrs else {}

    def set(self, key: str, value) -> None:
        self.attrs[key] = value

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        if stack:
            self.parent_id = stack[-1].span_id
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.t1 = time.perf_counter()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        stack = self.tracer._stack()
        # tolerate foreign frames on the stack (an exception unwound past a
        # span): pop down to and including this span
        while stack:
            top = stack.pop()
            if top is self:
                break
        self.tracer._ring.append(self)


class Tracer:
    """Ring-buffered span store with a thread-local nesting stack."""

    def __init__(self, enabled: bool = False,
                 ring_spans: int = DEFAULT_RING_SPANS):
        self.enabled = enabled
        self._ring: "deque[Span]" = deque(maxlen=ring_spans)
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._epoch = time.perf_counter()

    def _stack(self) -> List[Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, attrs: Optional[dict] = None):
        """Open a span (use as a context manager).  ``attrs`` is an optional
        dict — passed positionally, not **kwargs, so a disabled tracer costs
        one call and no allocation."""
        if not self.enabled:
            return NOOP_SPAN
        return Span(self, name, attrs)

    def instant(self, name: str, attrs: Optional[dict] = None) -> None:
        """Zero-duration marker (e.g. one submit): a span with t0 == t1."""
        if not self.enabled:
            return
        s = Span(self, name, attrs)
        stack = self._stack()
        if stack:
            s.parent_id = stack[-1].span_id
        s.t0 = s.t1 = time.perf_counter()
        self._ring.append(s)

    def reset(self) -> None:
        self._ring.clear()
        self._epoch = time.perf_counter()

    def spans(self) -> List[Span]:
        """Current ring contents, oldest first (a copy: stable to iterate)."""
        return list(self._ring)

    # -- export ---------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON object (``{"traceEvents": [...]}``)."""
        pid = os.getpid()
        events = []
        for s in self.spans():
            us0 = (s.t0 - self._epoch) * 1e6
            args = {"span_id": s.span_id}
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            args.update(s.attrs)
            ev = {"name": s.name, "cat": "repro", "pid": pid, "tid": s.tid,
                  "ts": us0, "args": args}
            if s.t1 > s.t0:
                ev["ph"] = "X"
                ev["dur"] = (s.t1 - s.t0) * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def summary(self) -> str:
        """Per-span-name rollup: count, total/mean/max ms — the human dump."""
        agg: Dict[str, List[float]] = {}
        for s in self.spans():
            agg.setdefault(s.name, []).append((s.t1 - s.t0) * 1e3)
        lines = [f"{'span':<28} {'count':>7} {'total_ms':>10} "
                 f"{'mean_ms':>9} {'max_ms':>9}"]
        for name in sorted(agg):
            ds = agg[name]
            lines.append(f"{name:<28} {len(ds):>7} {sum(ds):>10.2f} "
                         f"{sum(ds) / len(ds):>9.3f} {max(ds):>9.3f}")
        return "\n".join(lines)
