"""Dynamic lock-order watcher: the runtime twin of the CONC001 checker.

The static concurrency checker (``repro.analysis.concurrency``) derives the
lock-acquisition graph from the AST; this module records the graph an
ACTUAL threaded run exercises, so the two can cross-check each other: every
edge observed live must appear in the static graph (else the static
analysis is blind to a path), and neither graph may contain a cycle.

Opt-in and zero-cost when unused: wrap the locks you care about and run
traffic —

    watcher = LockOrderWatcher()
    server._lock = watcher.wrap(server._lock, "CountServer._lock")
    ... threaded traffic ...
    assert not watcher.cycles(), watcher.report()

or use :func:`instrument_server` for the standard serving pair.  Wrapped
locks proxy ``acquire``/``release``/context-manager entry to the original
lock and record, per thread, which locks were already held at each
acquisition — every (held, acquired) pair is an order edge.  Re-entrant
re-acquisition of the SAME lock (RLock) is counted but adds no edge.

Instrument BEFORE starting traffic: swapping a lock attribute while another
thread holds the old lock object briefly leaves two referents for "the"
lock, which is exactly the race this module exists to find.

Stdlib-only, like the rest of ``repro.obs``.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple


class LockOrderError(AssertionError):
    """Raised by :meth:`LockOrderWatcher.check` when a cycle was observed."""


class WatchedLock:
    """Transparent proxy around a ``threading.Lock``/``RLock`` that reports
    acquisition order to its watcher.  Unknown attributes forward to the
    wrapped lock."""

    __slots__ = ("_watcher", "_lock", "name")

    def __init__(self, watcher: "LockOrderWatcher", lock, name: str):
        self._watcher = watcher
        self._lock = lock
        self.name = name

    def acquire(self, *args, **kwargs) -> bool:
        ok = self._lock.acquire(*args, **kwargs)
        if ok:
            self._watcher._on_acquired(self.name)
        return ok

    def release(self) -> None:
        self._watcher._on_released(self.name)
        self._lock.release()

    def __enter__(self) -> "WatchedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def __getattr__(self, attr):
        return getattr(self._lock, attr)


class LockOrderWatcher:
    """Records per-thread lock-acquisition order edges across wrapped locks.

    Thread-safe: the held-lock stack is thread-local; the edge map is
    guarded by the watcher's own (unwatched) mutex."""

    def __init__(self):
        self._mu = threading.Lock()
        self._edges: Dict[Tuple[str, str], int] = {}
        self._names: Set[str] = set()
        self._tls = threading.local()

    # -- instrumentation ------------------------------------------------------

    def wrap(self, lock, name: str) -> WatchedLock:
        """Wrap one lock under a stable display name (conventionally
        ``Class.attr``, matching the static checker's node names)."""
        with self._mu:
            self._names.add(name)
        return WatchedLock(self, lock, name)

    def _stack(self) -> List[List]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _on_acquired(self, name: str) -> None:
        st = self._stack()
        fresh = [(held, name) for held, _ in st if held != name]
        if st and st[-1][0] == name:
            st[-1][1] += 1          # re-entrant re-acquire: no edge
        else:
            st.append([name, 1])
        if fresh:
            with self._mu:
                for e in fresh:
                    self._edges[e] = self._edges.get(e, 0) + 1

    def _on_released(self, name: str) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i][0] == name:
                st[i][1] -= 1
                if st[i][1] == 0:
                    del st[i]
                return
        # release of a lock acquired before wrapping: ignore silently

    # -- inspection -----------------------------------------------------------

    def edges(self) -> Dict[Tuple[str, str], int]:
        """Observed (held -> acquired) pairs with occurrence counts."""
        with self._mu:
            return dict(self._edges)

    def cycles(self) -> List[List[str]]:
        """Every distinct acquisition-order cycle observed (closed node
        lists, first == last); an ABBA deadlock hazard if non-empty."""
        adj: Dict[str, Set[str]] = {}
        for (a, b) in self.edges():
            adj.setdefault(a, set()).add(b)
        out: List[List[str]] = []
        seen: Set[frozenset] = set()
        while True:
            cycle = _find_cycle(adj)
            if cycle is None:
                return out
            key = frozenset(cycle)
            if key not in seen:
                seen.add(key)
                out.append(cycle)
            adj[cycle[0]].discard(cycle[1])

    def report(self) -> dict:
        with self._mu:
            edges = {f"{a} -> {b}": n for (a, b), n in self._edges.items()}
            names = sorted(self._names)
        return {"locks": names, "edges": edges, "cycles": self.cycles()}

    def check(self) -> None:
        """Raise :class:`LockOrderError` if any order cycle was observed."""
        cycles = self.cycles()
        if cycles:
            raise LockOrderError(
                f"lock-order cycle(s) observed at runtime: "
                f"{[' -> '.join(c) for c in cycles]}")

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()


def instrument_server(server, watcher: Optional[LockOrderWatcher] = None,
                      registry=None) -> LockOrderWatcher:
    """Wrap a :class:`~repro.serve.service.CountServer`'s serving locks
    (and optionally a metrics registry's) under one watcher.  Call BEFORE
    submitting traffic.  Sync servers (``async_flush=False``) hold a
    nullcontext instead of a lock and are left alone.  The store's lock and
    its background compactor's (when present) are wrapped too — the disk
    tier added real cross-thread traffic on both."""
    w = watcher if watcher is not None else LockOrderWatcher()
    if hasattr(server._lock, "acquire"):
        server._lock = w.wrap(server._lock, "CountServer._lock")
    flusher = getattr(server, "_flusher", None)
    if flusher is not None:
        flusher._lat_lock = w.wrap(flusher._lat_lock,
                                   "AsyncFlusher._lat_lock")
    store_lock = getattr(server.store, "_store_lock", None)
    if store_lock is not None:
        server.store._store_lock = w.wrap(store_lock, "VersionedDB._store_lock")
    compactor = getattr(server.store, "_compactor", None)
    if compactor is not None:
        compactor._mu = w.wrap(compactor._mu, "AsyncCompactor._mu")
    if registry is not None:
        registry._lock = w.wrap(registry._lock, "MetricsRegistry._lock")
    return w


def _find_cycle(edges: Dict[str, Set[str]]) -> Optional[List[str]]:
    """First cycle in a directed graph (closed node list), or None.
    Mirror of ``repro.analysis.engine.find_cycle`` — duplicated so obs
    stays dependency-free in both directions."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {u: WHITE for u in edges}
    for vs in edges.values():
        for v in vs:
            color.setdefault(v, WHITE)
    for start in sorted(color):
        if color[start] != WHITE:
            continue
        stack = [(start, iter(sorted(edges.get(start, ()))))]
        color[start] = GRAY
        path = [start]
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color[nxt] == GRAY:
                    return path[path.index(nxt):] + [nxt]
                if color[nxt] == WHITE:
                    color[nxt] = GRAY
                    path.append(nxt)
                    stack.append((nxt, iter(sorted(edges.get(nxt, ())))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                path.pop()
                stack.pop()
    return None
