"""Telemetry export: Prometheus text exposition, JSON snapshots, HTTP serve.

``prometheus_text(snapshot)`` renders a :meth:`MetricsRegistry.snapshot`
in the Prometheus text exposition format (counters with ``_total`` names as
recorded, histograms as cumulative ``_bucket{le=...}`` series + ``_sum`` /
``_count``, gauges as-is).  ``start_metrics_server(port)`` serves it from a
daemon thread at ``/metrics`` (text) and ``/metrics.json`` (raw snapshot)
— the seam ``launch/serve_counts.py --metrics-port`` exposes.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional


def _prom_labels(label_str: str, extra: str = "") -> str:
    parts = []
    if label_str:
        for kv in label_str.split(","):
            k, _, v = kv.partition("=")
            parts.append(f'{k}="{v}"')
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _sanitize(name: str) -> str:
    return "".join(ch if (ch.isalnum() or ch == "_") else "_" for ch in name)


def prometheus_text(snapshot: dict) -> str:
    """Render a registry snapshot in Prometheus text exposition format."""
    out = []
    for name, sets in snapshot.get("counters", {}).items():
        pname = _sanitize(name)
        out.append(f"# TYPE {pname} counter")
        for ls, v in sets.items():
            out.append(f"{pname}{_prom_labels(ls)} {_num(v)}")
    for name, sets in snapshot.get("gauges", {}).items():
        pname = _sanitize(name)
        out.append(f"# TYPE {pname} gauge")
        for ls, v in sets.items():
            out.append(f"{pname}{_prom_labels(ls)} {_num(v)}")
    for name, sets in snapshot.get("histograms", {}).items():
        pname = _sanitize(name)
        out.append(f"# TYPE {pname} histogram")
        for ls, h in sets.items():
            cum = 0
            for ub, c in zip(h["buckets"], h["counts"]):
                cum += c
                le = 'le="%s"' % _num(ub)
                out.append(f"{pname}_bucket{_prom_labels(ls, le)} {cum}")
            inf = 'le="+Inf"'
            out.append(f"{pname}_bucket{_prom_labels(ls, inf)} {h['count']}")
            out.append(f"{pname}_sum{_prom_labels(ls)} {_num(h['sum'])}")
            out.append(f"{pname}_count{_prom_labels(ls)} {h['count']}")
    return "\n".join(out) + "\n"


def _num(v) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return str(v)


class _MetricsHandler(BaseHTTPRequestHandler):
    registry = None   # class attr bound by start_metrics_server

    def do_GET(self):   # noqa: N802 (http.server API)
        snap = self.registry.snapshot()
        if self.path.startswith("/metrics.json"):
            body = json.dumps(snap, indent=1).encode()
            ctype = "application/json"
        elif self.path.startswith("/metrics") or self.path == "/":
            body = prometheus_text(snap).encode()
            ctype = "text/plain; version=0.0.4"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *a):   # silence per-request stderr noise
        return None


def start_metrics_server(port: int,
                         registry=None) -> ThreadingHTTPServer:
    """Serve ``/metrics`` (Prometheus text) and ``/metrics.json`` from a
    daemon thread; returns the server (``.shutdown()`` to stop).  ``port=0``
    binds an ephemeral port (``server.server_address[1]``)."""
    if registry is None:
        from . import REGISTRY
        registry = REGISTRY
    handler = type("Handler", (_MetricsHandler,), {"registry": registry})
    srv = ThreadingHTTPServer(("127.0.0.1", port), handler)
    threading.Thread(target=srv.serve_forever, daemon=True,
                     name="metrics-http").start()
    return srv


def dump_json(path: str, snapshot: dict,
              extra: Optional[dict] = None) -> None:
    """Write a snapshot (plus optional extra sections) as indented JSON."""
    doc = dict(snapshot)
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
