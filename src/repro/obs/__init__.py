"""Unified telemetry layer: one process-wide metrics registry + span tracer.

Every seam of the serve/mine/kernel stack records here — the batcher, the
async flusher, both caches, the versioned/sharded stores, the mining
driver's level/chunk loop, the GFP hybrid's launch/host-block/CPB counters,
the chooser's decisions, and per-launch kernel wall time against the
roofline model's prediction.  Exports: ``snapshot()`` (JSON-safe),
``prometheus_text`` / ``start_metrics_server`` (``obs.export``), Chrome
trace dumps (``obs.tracing``), and the ``summary_line()`` one-liner every
entry point prints on exit.

State model:

  * ``REGISTRY`` (metrics) is ENABLED by default: counters/histograms are
    thread-confined dict bumps, cheap enough for the hot path (the
    ``benchmarks/obs_overhead.py`` gate holds the serve suite under 5%).
  * ``TRACER`` (spans) is DISABLED by default: ring-buffer traces are an
    opt-in debugging surface (``--trace`` in the launchers).
  * ``KERNEL_TIMING`` gates the per-launch wall-time measurement in
    ``kernels/itemset_count/ops.py``: it blocks on the launch result to get
    a true wall time, which is free on CPU (callers materialize the counts
    immediately) but would serialize a pipelined TPU launch stream — turn it
    off on real accelerators when overlap matters more than the
    measured-vs-predicted ratio.
  * ``configure(metrics=..., tracing=..., kernel_timing=...)`` flips any
    subset; ``disable_all()`` is the zero-overhead escape hatch (pinned by
    the no-allocation test in ``tests/test_obs.py``).

Import discipline: this package imports only the stdlib — serve/, mining/,
kernels/, and roofline/ all import it, never the reverse.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from .lockwatch import (LockOrderError, LockOrderWatcher, WatchedLock,
                        instrument_server)
from .metrics import (MetricsRegistry, counter_total, counter_value,
                      hist_get, hist_merge, hist_quantile, nearest_rank)
from .tracing import Tracer

__all__ = [
    "REGISTRY", "TRACER", "configure", "disable_all", "enabled",
    "snapshot", "reset", "summary_line", "kernel_timing_enabled",
    "kernel_efficiency", "telemetry_section", "register_section",
    "counter_total", "counter_value", "hist_get", "hist_merge",
    "hist_quantile", "nearest_rank", "MetricsRegistry", "Tracer",
    "LockOrderError", "LockOrderWatcher", "WatchedLock",
    "instrument_server",
]

REGISTRY = MetricsRegistry(enabled=True)
TRACER = Tracer(enabled=False)
KERNEL_TIMING = True


def configure(metrics: Optional[bool] = None, tracing: Optional[bool] = None,
              kernel_timing: Optional[bool] = None) -> None:
    """Flip any subset of the three telemetry switches (None = leave)."""
    global KERNEL_TIMING
    if metrics is not None:
        REGISTRY.enabled = metrics
    if tracing is not None:
        TRACER.enabled = tracing
    if kernel_timing is not None:
        KERNEL_TIMING = kernel_timing


def disable_all() -> None:
    configure(metrics=False, tracing=False, kernel_timing=False)


def enabled() -> bool:
    return REGISTRY.enabled


def kernel_timing_enabled() -> bool:
    return KERNEL_TIMING and REGISTRY.enabled


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    """Drop all recorded telemetry and restore default switches (tests)."""
    global KERNEL_TIMING
    REGISTRY.reset()
    REGISTRY.enabled = True
    TRACER.reset()
    TRACER.enabled = False
    KERNEL_TIMING = True


# -- derived views -----------------------------------------------------------

def kernel_efficiency(snap: Optional[dict] = None) -> dict:
    """Measured-vs-predicted kernel report per launch geometry.

    ``{geometry: {launches, measured_s, predicted_s, efficiency}}`` where
    ``efficiency = predicted / measured`` — 1.0 means the launch ran at the
    roofline model's bound for the TARGET hardware; far below 1.0 on this
    CPU/interpret container is expected (the trend, not the absolute, is
    the signal there).  Geometries come from the per-launch recording in
    ``kernels/itemset_count/ops.py`` via ``roofline.kernel_model``."""
    snap = snap if snap is not None else snapshot()
    launches = snap.get("counters", {}).get("kernel_launches_total", {})
    measured = snap.get("counters", {}).get("kernel_measured_s_total", {})
    predicted = snap.get("counters", {}).get("kernel_predicted_s_total", {})
    out = {}
    for ls, n in launches.items():
        geom = ls.replace("geometry=", "", 1) if ls else ""
        m = measured.get(ls, 0.0)
        p = predicted.get(ls, 0.0)
        out[geom] = {
            "launches": int(n),
            "measured_s": m,
            "predicted_s": p,
            "efficiency": (p / m) if m > 0 else None,
        }
    return out


# Extension sections: higher layers (which import obs — never the reverse)
# contribute named blocks to the telemetry report by registering a provider.
# Keeps this package stdlib-only while letting e.g. roofline.autotune expose
# its active-table + staleness state through CountServer.stats().
_SECTIONS: Dict[str, Callable[[], dict]] = {}


def register_section(name: str, provider: Callable[[], dict]) -> None:
    """Register (or replace) a named provider merged into every
    :func:`telemetry_section` result.  Provider errors are captured per
    section, never propagated — telemetry must not take down serving."""
    _SECTIONS[name] = provider


def telemetry_section(snap: Optional[dict] = None) -> dict:
    """The registry-backed block ``CountServer.stats()`` embeds: the raw
    snapshot plus the derived kernel measured-vs-predicted report, plus any
    registered extension sections (e.g. ``autotune``)."""
    snap = snap if snap is not None else snapshot()
    out = {"enabled": REGISTRY.enabled, "metrics": snap,
           "kernel_efficiency": kernel_efficiency(snap)}
    for name, provider in _SECTIONS.items():
        try:
            out[name] = provider()
        except Exception as e:  # pragma: no cover - defensive
            # section names come from register_section callers — a fixed,
            # code-defined vocabulary, so the label set is bounded
            out[name] = {"error": f"{type(e).__name__}: {e}"}
            REGISTRY.counter("telemetry_section_errors_total",
                             section=name).inc()
    return out


def summary_line(snap: Optional[dict] = None) -> str:
    """One-line telemetry rollup for entry-point exit banners:
    launches, host blocks, cache hit rate, p95 flush latency — each part
    shown only when something actually recorded it."""
    snap = snap if snap is not None else snapshot()
    parts = []
    launches = counter_total(snap, "kernel_launches_total")
    if launches:
        parts.append(f"{int(launches)} kernel launches")
    chunks = counter_total(snap, "mine_chunks_total")
    if chunks:
        levels = counter_total(snap, "mine_levels_total")
        parts.append(f"{int(chunks)} chunk counts over {int(levels)} levels")
    gfp_host = counter_value(snap, "gfp_blocks_total", path="host")
    if gfp_host:
        parts.append(f"{int(gfp_host)} host blocks")
    hits = counter_total(snap, "cache_hits_total")
    misses = counter_total(snap, "cache_misses_total")
    if hits + misses:
        parts.append(f"cache hit rate {hits / (hits + misses):.2f}")
    p95 = hist_quantile(hist_merge(snap, "serve_flush_wait_ms"), 0.95)
    if p95 is not None:
        parts.append(f"p95 flush wait <={p95:g}ms")
    else:
        p95q = hist_quantile(hist_merge(snap, "serve_queue_wait_ms"), 0.95)
        if p95q is not None:
            parts.append(f"p95 queue wait <={p95q:g}ms")
    if not REGISTRY.enabled:
        return "telemetry: disabled"
    return "telemetry: " + (", ".join(parts) if parts else "no activity")
