from .manager import CheckpointManager, PreemptionGuard, StragglerMonitor
