"""Fault-tolerant checkpointing: atomic, async, elastic.

Layout per step:  <dir>/step_<n>/  arrays.npz  MANIFEST.json  (tmp+rename, so a
crash mid-write never corrupts the latest good checkpoint).  ``MANIFEST.json``
records the flattened tree structure, shapes and dtypes; restore re-sharding
is free because arrays are device_put against whatever mesh/shardings the NEW
topology provides (elastic restart = same checkpoint, different mesh).

On a real multi-host pod each process writes its addressable shards
(``process_index`` in the filename) and restore re-assembles per the manifest;
in this single-process container that degenerates to one file, but the naming
and manifest format already carry the process dimension.

``PreemptionGuard`` converts SIGTERM (the cloud preemption signal) into a
"checkpoint now, then exit" request the train loop polls once per step.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        flat = _flatten(tree)
        # Pull to host NOW (cheap copy); disk IO happens in the background.
        host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]
        manifest = {
            "step": step,
            "time": time.time(),
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "keys": [k for k, _ in host],
            "shapes": {k: list(v.shape) for k, v in host},
            "dtypes": {k: str(v.dtype) for k, v in host},
            "extra": extra or {},
        }
        # serialize writers: a blocking save racing an in-flight async save of
        # the same step would have its tmp dir os.replace()d away mid-write
        self.wait()
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, manifest)

    def _write(self, step: int, host, manifest) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp{jax.process_index()}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"arrays_p{jax.process_index()}.npz"),
                 **{k: v for k, v in host})
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp0"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, dict]:
        """Restore into the structure of ``tree_like``; device_put against
        ``shardings`` (same structure) when given — elastic re-shard."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, f"arrays_p{jax.process_index()}.npz"))
        flat = _flatten(tree_like)
        shard_flat = _flatten(shardings) if shardings is not None else None
        out = []
        for i, (k, like) in enumerate(flat):
            arr = data[k]
            want_dtype = getattr(like, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i][1])
            out.append(arr)
        treedef = jax.tree_util.tree_structure(tree_like)
        return jax.tree_util.tree_unflatten(treedef, out), manifest


class PreemptionGuard:
    """SIGTERM -> graceful checkpoint-and-exit for the train loop."""

    def __init__(self):
        self.requested = False
        self._prev = None

    def install(self) -> "PreemptionGuard":
        def handler(signum, frame):
            self.requested = True
        self._prev = signal.signal(signal.SIGTERM, handler)
        return self

    def uninstall(self) -> None:
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)


class StragglerMonitor:
    """Step-time tracker: flags steps slower than ``threshold``x the running
    median.  On a real pod the per-host step time is psum-maxed and the slow
    host re-sharded out (recipe in DESIGN.md); here we expose detection +
    counters so the loop and tests can exercise the policy."""

    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.flagged = 0

    def record(self, dt: float) -> bool:
        import statistics
        slow = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            slow = dt > self.threshold * med
        self.times.append(dt)
        self.flagged += slow
        return slow
