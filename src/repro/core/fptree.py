"""Paper-faithful FP-tree (Han et al. 2000/2004), as used by FP-growth and GFP-growth.

This is the host-side reference implementation: pointer/dict-based nodes with a
header table of per-item linked lists, exactly as described in [10] of the
paper.  The TPU-native engine (repro.mining) is derived from this reference and
is cross-validated against it in tests.

Item identity is an arbitrary hashable (int or str).  Item *order* is explicit:
an ``ItemOrder`` maps item -> rank, rank 0 being the item that sits closest to
the root (support-descending order in classic FP-growth).  The Minority-Report
algorithm requires the same order for both of its trees (paper §4.1), so the
order is a first-class object here rather than something recomputed per tree.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Hashable, Iterable, Iterator, List, Optional, Sequence, Tuple

Item = Hashable
Transaction = Sequence[Item]


class ItemOrder:
    """Explicit item ordering: rank 0 = first when inserting paths (root side).

    Classic FP-growth uses support-descending order so that frequent items share
    prefixes near the root.  ``rank`` is a dense dict item -> int.
    """

    def __init__(self, items_by_rank: Sequence[Item]):
        self.items_by_rank: List[Item] = list(items_by_rank)
        self.rank: Dict[Item, int] = {a: i for i, a in enumerate(self.items_by_rank)}
        if len(self.rank) != len(self.items_by_rank):
            raise ValueError("duplicate items in order")

    def __contains__(self, item: Item) -> bool:
        return item in self.rank

    def __len__(self) -> int:
        return len(self.items_by_rank)

    def sort_transaction(self, t: Iterable[Item]) -> List[Item]:
        """Project to ordered items and sort by rank (root side first)."""
        kept = [a for a in set(t) if a in self.rank]
        kept.sort(key=self.rank.__getitem__)
        return kept

    @staticmethod
    def from_counts(counts: Dict[Item, int], min_count: int = 1) -> "ItemOrder":
        """Support-descending order (ties broken by repr for determinism)."""
        items = [a for a, c in counts.items() if c >= min_count]
        items.sort(key=lambda a: (-counts[a], repr(a)))
        return ItemOrder(items)


class FPNode:
    __slots__ = ("item", "count", "parent", "children", "next")

    def __init__(self, item: Optional[Item], parent: Optional["FPNode"]):
        self.item = item
        self.count = 0
        self.parent = parent
        self.children: Dict[Item, FPNode] = {}
        self.next: Optional[FPNode] = None  # header-table linked list

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"FPNode({self.item}:{self.count})"


class HeaderEntry:
    __slots__ = ("item", "count", "head", "tail")

    def __init__(self, item: Item):
        self.item = item
        self.count = 0  # total count of item in the tree (sum over linked list)
        self.head: Optional[FPNode] = None
        self.tail: Optional[FPNode] = None

    def link(self, node: FPNode) -> None:
        if self.head is None:
            self.head = self.tail = node
        else:
            assert self.tail is not None
            self.tail.next = node
            self.tail = node

    def nodes(self) -> Iterator[FPNode]:
        n = self.head
        while n is not None:
            yield n
            n = n.next


class FPTree:
    """FP-tree with header table.  ``order`` fixes the path arrangement."""

    def __init__(self, order: ItemOrder):
        self.order = order
        self.root = FPNode(None, None)
        self.header: Dict[Item, HeaderEntry] = {}
        self.n_transactions = 0  # total weight inserted (incl. empty projections)

    # -- construction -------------------------------------------------------
    def insert(self, sorted_items: Sequence[Item], weight: int = 1) -> None:
        """Insert a transaction already projected+sorted by ``order``."""
        self.n_transactions += weight
        node = self.root
        for a in sorted_items:
            child = node.children.get(a)
            if child is None:
                child = FPNode(a, node)
                node.children[a] = child
                entry = self.header.get(a)
                if entry is None:
                    entry = self.header[a] = HeaderEntry(a)
                entry.link(child)
            child.count += weight
            self.header[a].count += weight
            node = child

    @staticmethod
    def build(
        transactions: Iterable[Transaction],
        order: ItemOrder,
        weights: Optional[Sequence[int]] = None,
    ) -> "FPTree":
        tree = FPTree(order)
        if weights is None:
            for t in transactions:
                tree.insert(order.sort_transaction(t))
        else:
            for t, w in zip(transactions, weights):
                tree.insert(order.sort_transaction(t), w)
        return tree

    # -- queries ------------------------------------------------------------
    def __contains__(self, item: Item) -> bool:
        return item in self.header

    def item_count(self, item: Item) -> int:
        """Count of ``item`` in the represented database.

        Paper: "follow the linked list starting at the entry of a_i in the
        header table, summing the counts from the visited nodes".  We keep the
        running total in the header entry (equivalent, O(1)); ``recount=True``
        paths in tests verify the linked-list sum matches.
        """
        e = self.header.get(item)
        return 0 if e is None else e.count

    def item_count_via_links(self, item: Item) -> int:
        e = self.header.get(item)
        return 0 if e is None else sum(n.count for n in e.nodes())

    def is_empty(self) -> bool:
        return not self.root.children

    def items_ascending(self) -> List[Item]:
        """Header items in support-ascending processing order (pattern-growth
        order = reverse of the tree arrangement order)."""
        items = list(self.header.keys())
        items.sort(key=self.order.rank.__getitem__, reverse=True)
        return items

    # -- conditional trees ---------------------------------------------------
    def prefix_paths(self, item: Item) -> Iterator[Tuple[List[Item], int]]:
        """(path items root->parent, count) for every node of ``item``."""
        e = self.header.get(item)
        if e is None:
            return
        for node in e.nodes():
            path: List[Item] = []
            p = node.parent
            while p is not None and p.item is not None:
                path.append(p.item)
                p = p.parent
            path.reverse()
            yield path, node.count

    def conditional_tree(
        self,
        item: Item,
        min_count: int = 0,
        item_filter: Optional[frozenset] = None,
    ) -> "FPTree":
        """Build the conditional FP-tree for ``item``.

        ``item_filter`` implements the paper's GFP data-reduction optimization
        (#4): items not present in the current TIS sub-tree are skipped when the
        conditional tree is constructed.  ``min_count`` > 0 additionally prunes
        items infrequent in the projected database (classic FP-growth behaviour;
        GFP-growth passes 0 = no min-support, per paper §3.2).
        """
        # First pass over prefix paths: projected item counts.
        counts: Dict[Item, int] = defaultdict(int)
        paths = list(self.prefix_paths(item))
        for path, c in paths:
            for a in path:
                if item_filter is None or a in item_filter:
                    counts[a] += c
        keep = {a for a, c in counts.items() if c >= min_count}
        # The conditional tree reuses the parent ordering restricted to `keep`
        # (same relative order — required for coordinated TIS traversal).
        sub_order = ItemOrder([a for a in self.order.items_by_rank if a in keep])
        ctree = FPTree(sub_order)
        for path, c in paths:
            ctree.insert([a for a in path if a in keep], c)
        return ctree
