"""TIS-tree (Target Item-Set tree) — paper §3.2.

A trie of target itemsets arranged in *pattern-growth order*: the reverse of
the FP-tree arrangement order, i.e. support-ascending.  For a child a_j of a_i,
C(a_j) >= C(a_i) (paper: "TIS-tree should be arranged such that ... C(a_j) >=
C(a_i)").  Following the TIS-tree top-down therefore explores the FP-tree
bottom-up, exactly as FP-growth does.

Each node carries:
  * ``target``  — whether the node represents a target itemset (paper flag);
  * ``g_count`` — the counter filled by GFP-growth (paper: g-count);
  * ``count``   — the counter filled by FP-growth in the MRA (paper: count, =C1);
  * ``subtree_items`` — the set of items appearing in the node's sub-tree,
    supporting GFP data-reduction optimization #4.  The paper suggests a
    bit-map / hash-table / linked-list; we use a frozenset (host reference) —
    the TPU engine uses actual packed bitmaps.
"""
from __future__ import annotations

from typing import Dict, Hashable, Iterator, List, Optional, Sequence, Tuple

from .fptree import ItemOrder

Item = Hashable


class TISNode:
    __slots__ = ("item", "children", "target", "g_count", "count", "subtree_items", "parent")

    def __init__(self, item: Optional[Item], parent: Optional["TISNode"]):
        self.item = item
        self.parent = parent
        self.children: Dict[Item, TISNode] = {}
        self.target = False
        self.g_count = 0
        self.count = 0
        self.subtree_items: frozenset = frozenset()

    def has_children(self) -> bool:
        return bool(self.children)

    def itemset(self) -> Tuple[Item, ...]:
        """The itemset this node represents (path from root), in PG order."""
        path: List[Item] = []
        n: Optional[TISNode] = self
        while n is not None and n.item is not None:
            path.append(n.item)
            n = n.parent
        path.reverse()
        return tuple(path)


class TISTree:
    """Target itemset trie in pattern-growth (support-ascending) order.

    ``order`` is the FP-tree arrangement order (support-descending).  Paths in
    the TIS-tree are sorted by *descending* rank, i.e. least-frequent item at
    the root side, which is the pattern-growth order.
    """

    def __init__(self, order: ItemOrder):
        self.order = order
        self.root = TISNode(None, None)
        self.n_targets = 0

    def pg_sort(self, itemset: Sequence[Item]) -> List[Item]:
        """Sort an itemset into pattern-growth order (reverse arrangement order)."""
        items = [a for a in set(itemset)]
        for a in items:
            if a not in self.order:
                raise KeyError(f"item {a!r} not in item order")
        items.sort(key=self.order.rank.__getitem__, reverse=True)
        return items

    def insert(self, itemset: Sequence[Item], count: int = 0, target: bool = True) -> TISNode:
        """Insert a target itemset; returns its node.

        Intermediate nodes created on the way are *not* targets (paper: the
        TIS-tree may contain non-target internal prefixes, for which
        optimization #6 skips the count computation).
        """
        node = self.root
        for a in self.pg_sort(itemset):
            child = node.children.get(a)
            if child is None:
                child = TISNode(a, node)
                node.children[a] = child
            node = child
        if node is self.root:
            raise ValueError("cannot insert the empty itemset")
        if target and not node.target:
            self.n_targets += 1
        node.target = node.target or target
        if count:
            node.count = count
        return node

    def finalize(self) -> None:
        """Compute ``subtree_items`` bottom-up (GFP data-reduction support)."""

        def rec(node: TISNode) -> frozenset:
            acc = set()
            for item, child in node.children.items():
                acc.add(item)
                acc |= rec(child)
            node.subtree_items = frozenset(acc)
            return node.subtree_items

        rec(self.root)

    # -- queries -------------------------------------------------------------
    def find(self, itemset: Sequence[Item]) -> Optional[TISNode]:
        node = self.root
        for a in self.pg_sort(itemset):
            node = node.children.get(a)
            if node is None:
                return None
        return node

    def walk(self) -> Iterator[TISNode]:
        """All non-root nodes, DFS preorder."""
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def targets(self) -> Iterator[TISNode]:
        for n in self.walk():
            if n.target:
                yield n

    def as_dict(self, which: str = "g_count") -> Dict[Tuple[Item, ...], int]:
        """{frozenset-like sorted tuple -> counter} for every *target* node."""
        out: Dict[Tuple[Item, ...], int] = {}
        for n in self.targets():
            key = tuple(sorted(n.itemset(), key=repr))
            out[key] = getattr(n, which)
        return out

    def levels(self) -> List[List[TISNode]]:
        """Nodes grouped by depth (1-based level 0 = root children) — used by
        the TPU level-synchronous scheduler."""
        out: List[List[TISNode]] = []
        frontier = list(self.root.children.values())
        while frontier:
            out.append(frontier)
            frontier = [c for n in frontier for c in n.children.values()]
        return out
