"""Classic FP-growth (Han et al.) — the paper's baseline and MRA sub-procedure.

``fp_growth`` enumerates all frequent itemsets of an FP-tree (min_count
threshold) in pattern-growth order.  The MRA variant (``fp_growth_into_tis``)
inserts every discovered itemset (with its count) into a TIS-tree, which the
paper assumes: "We assume an implementation of the FP-growth procedure which
inserts each discovered frequent-itemset, along with its frequency-count, into
TIS-tree" (§4.1).  Because itemsets are discovered in pattern-growth order, the
TIS insertion is an O(depth) attach, matching the paper's §4.1 discussion.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable, Iterable, Optional, Sequence, Tuple

from .fptree import FPTree, ItemOrder
from .tis import TISTree

Item = Hashable
Collector = Callable[[Tuple[Item, ...], int], None]


def fp_growth(tree: FPTree, min_count: int, collector: Collector,
              suffix: Tuple[Item, ...] = ()) -> None:
    """Mine ``tree``; call ``collector(itemset_in_pg_order, count)`` per
    frequent itemset.  ``itemset`` tuples grow left-to-right in pattern-growth
    order: (a_i, a_j, ...) where a_i is less frequent than a_j.
    """
    for item in tree.items_ascending():
        count = tree.item_count(item)
        if count < min_count:
            continue
        found = suffix + (item,) if not suffix else suffix + (item,)
        # NOTE: pattern-growth order — new item appended after its prefix.
        collector(found, count)
        ctree = tree.conditional_tree(item, min_count=min_count)
        if not ctree.is_empty():
            fp_growth(ctree, min_count, collector, found)


def mine_frequent(
    transactions: Iterable[Sequence[Item]],
    min_count: int,
    order: Optional[ItemOrder] = None,
) -> Dict[Tuple[Item, ...], int]:
    """End-to-end classic FP-growth: two DB passes + mining.

    Returns {sorted-tuple itemset -> count}.
    """
    transactions = [list(t) for t in transactions]
    if order is None:
        counts: Dict[Item, int] = {}
        for t in transactions:
            for a in set(t):
                counts[a] = counts.get(a, 0) + 1
        order = ItemOrder.from_counts(counts, min_count=min_count)
    tree = FPTree.build(transactions, order)
    out: Dict[Tuple[Item, ...], int] = {}

    def collect(itemset: Tuple[Item, ...], count: int) -> None:
        out[tuple(sorted(itemset, key=repr))] = count

    fp_growth(tree, min_count, collect)
    return out


def fp_growth_into_tis(tree: FPTree, min_count: int, tis: TISTree) -> None:
    """FP-growth that records every frequent itemset into ``tis`` with its
    count (sets node.count; marks node as target).  Used by MRA step 3."""

    def collect(itemset: Tuple[Item, ...], count: int) -> None:
        node = tis.insert(itemset, target=True)
        node.count = count

    fp_growth(tree, min_count, collect)
