"""Minority-Report Algorithm (MRA) — paper Algorithm 4.1.

Mines class-association rules `alpha -> target_class` for a rare class from
imbalanced data:

  1. first DB pass: I' = items frequent *within the rare class*
     (C1(a_k) >= C* = xi * |DB|);
  2. second pass: build FP0 (common class) and FP1 (rare class) over I' with a
     *shared* item order (support-descending over the entire DB — the paper's
     performance-optimized choice, §4.1);
  3. FP-growth(FP1, min-count=C*) -> TIS-tree with .count = C1(alpha);
  4. GFP-growth(TIS-tree, FP0)    ->              .g_count = C0(alpha);
  5. confidence = C1/(C1+C0) >= minconf -> emit rule.

Exactness (Theorems 2-3) is cross-checked in tests against a brute-force oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

from .fpgrowth import fp_growth_into_tis
from .fptree import FPTree, ItemOrder
from .gfp import GFPStats, gfp_growth
from .tis import TISTree

Item = Hashable


@dataclass(frozen=True)
class Rule:
    antecedent: Tuple[Item, ...]  # sorted by repr for determinism
    consequent: Item
    support: float                # support(antecedent ∪ {class}) in DB
    confidence: float
    count: int                    # C1(antecedent)
    g_count: int                  # C0(antecedent)

    def __str__(self) -> str:  # pragma: no cover - display helper
        lhs = ",".join(map(str, self.antecedent))
        return (f"{{{lhs}}} -> {self.consequent} "
                f"(sup={self.support:.4g}, conf={self.confidence:.4g})")


@dataclass
class MRAResult:
    rules: List[Rule]
    tis: TISTree
    order: ItemOrder
    n_db: int
    n_rare: int
    stats: GFPStats
    items_kept: List[Item]


def minority_report(
    transactions: Iterable[Sequence[Item]],
    classes: Sequence[int],
    *,
    target_class: int = 1,
    min_support: float,
    min_confidence: float,
    use_data_reduction: bool = True,
) -> MRAResult:
    """Run MRA on (transactions, classes).

    ``classes[i]`` is the class label of transaction i; ``target_class`` plays
    the paper's class '1' (rare).  The class item itself must NOT appear inside
    the transactions (callers using a class-item encoding should strip it).
    """
    db: List[List[Item]] = [list(t) for t in transactions]
    if len(db) != len(classes):
        raise ValueError("transactions/classes length mismatch")
    n_db = len(db)
    from .incremental import ceil_count
    # ONE threshold rule end to end (the repo-wide epsilon-guarded ceil):
    # filtering I' on the raw float product would exclude an item whose count
    # sits exactly on a threshold that carries upward FP noise (e.g.
    # 0.07 * 100 = 7.000000000000001) while the FP-growth min-count below —
    # and every engine-side miner — accepts it
    min_count = ceil_count(min_support * n_db)

    # ---- first pass: per-item counts in rare class and overall -------------
    c1: Dict[Item, int] = {}
    c_all: Dict[Item, int] = {}
    n_rare = 0
    for t, y in zip(db, classes):
        rare = y == target_class
        n_rare += rare
        for a in set(t):
            c_all[a] = c_all.get(a, 0) + 1
            if rare:
                c1[a] = c1.get(a, 0) + 1
    items_kept = [a for a, c in c1.items() if c >= min_count]

    # Shared support-descending order over the *entire DB* (paper §4.1).
    order = ItemOrder(sorted(items_kept, key=lambda a: (-c_all[a], repr(a))))

    # ---- second pass: build FP0 / FP1 over I' -------------------------------
    fp0 = FPTree(order)
    fp1 = FPTree(order)
    for t, y in zip(db, classes):
        proj = order.sort_transaction(t)
        (fp1 if y == target_class else fp0).insert(proj)

    # ---- FP-growth on the small (rare) tree -> TIS-tree ---------------------
    tis = TISTree(order)
    fp_growth_into_tis(fp1, min_count, tis)

    # ---- GFP-growth on the big (common) tree --------------------------------
    stats = gfp_growth(tis, fp0, use_data_reduction=use_data_reduction)

    # ---- rule generation -----------------------------------------------------
    rules: List[Rule] = []
    for node in tis.targets():
        cnt, gcnt = node.count, node.g_count
        conf = cnt / (cnt + gcnt) if (cnt + gcnt) else 0.0
        if conf >= min_confidence:
            rules.append(Rule(
                antecedent=tuple(sorted(node.itemset(), key=repr)),
                consequent=target_class,
                support=cnt / n_db,
                confidence=conf,
                count=cnt,
                g_count=gcnt,
            ))
    rules.sort(key=lambda r: (-r.confidence, -r.support, r.antecedent))
    return MRAResult(rules=rules, tis=tis, order=order, n_db=n_db,
                     n_rare=n_rare, stats=stats, items_kept=items_kept)


# ---------------------------------------------------------------------------
# Baseline for benchmarking: the "well-known solution" the paper compares MRA
# against — run full FP-growth over the entire DB (class items included) with
# the same min-support, then post-filter itemsets containing the class item.
# ---------------------------------------------------------------------------

def full_fpgrowth_rules(
    transactions: Iterable[Sequence[Item]],
    classes: Sequence[int],
    *,
    target_class: int = 1,
    min_support: float,
    min_confidence: float,
    class_item: str = "__class__",
) -> List[Rule]:
    from .fpgrowth import mine_frequent

    db = []
    for t, y in zip(transactions, classes):
        t = list(t)
        if y == target_class:
            t.append(class_item)
        db.append(t)
    n_db = len(db)
    import math
    min_count = max(1, math.ceil(min_support * n_db - 1e-9))
    freq = mine_frequent(db, min_count)
    rules: List[Rule] = []
    for itemset, cnt in freq.items():
        if class_item not in itemset:
            continue
        ante = tuple(sorted((a for a in itemset if a != class_item), key=repr))
        if not ante:
            continue
        total = freq.get(ante)
        if total is None:  # antecedent itself frequent by anti-monotonicity
            continue
        conf = cnt / total
        if conf >= min_confidence:
            rules.append(Rule(ante, target_class, cnt / n_db, conf, cnt, total - cnt))
    rules.sort(key=lambda r: (-r.confidence, -r.support, r.antecedent))
    return rules
