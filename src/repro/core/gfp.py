"""GFP-growth — the paper's Algorithm 3.1, with all six §3.1 optimizations.

    GFP-GROWTH(TIS-tree, FP-tree):
      for each item a_i in TIS-tree (direct children of the TIS root):
        if (a_i in FP-tree):                       # O(1) header consult   (#2)
          if (TIS-tree(a_i).target):               # skip non-targets      (#6)
            TIS-tree(a_i).g-count = a_i.count in FP-tree
          if (TIS-tree(a_i) has children):         # leaf => no recursion  (#3)
            construct a_i's conditional FP-tree c-Tree   # item_filter     (#4)
            if c-Tree != empty:
              call GFP-growth(TIS-tree(a_i), c-Tree)

Results are written into TIS-tree node counters in place (#5).  The procedure
applies no min-support constraint (per paper §3.2 — required for the MRA and
other use-cases); `min_count` may still be passed for constrained use-cases,
affecting conditional-tree pruning exactly as in [10].

Instrumentation counters are kept on the side so benchmarks can report how much
of the FP-tree the guided walk actually touched (conditional trees built,
header consults, link-list traversals) versus classic FP-growth.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Hashable, Optional

from .fptree import FPTree
from .tis import TISNode, TISTree

Item = Hashable


@dataclass
class GFPStats:
    header_consults: int = 0
    count_computations: int = 0
    conditional_trees: int = 0
    recursive_calls: int = 0
    nodes_visited: int = 0

    def merge(self, other: "GFPStats") -> None:
        self.header_consults += other.header_consults
        self.count_computations += other.count_computations
        self.conditional_trees += other.conditional_trees
        self.recursive_calls += other.recursive_calls
        self.nodes_visited += other.nodes_visited


def gfp_growth(
    tis: TISTree,
    fp: FPTree,
    *,
    use_data_reduction: bool = True,
    min_count: int = 0,
    stats: Optional[GFPStats] = None,
) -> GFPStats:
    """Run GFP-growth; fills ``g_count`` on every reachable TIS node.

    ``use_data_reduction=False`` disables optimization #4 (conditional trees
    keep all items) — used by benchmarks to quantify the optimization, and to
    mirror the paper's own "partial GFP-growth implementation" note in §4.3.
    """
    if stats is None:
        stats = GFPStats()
    tis.finalize()  # compute subtree_items for data reduction
    _gfp(tis.root, fp, use_data_reduction, min_count, stats)
    return stats


def _gfp(tnode: TISNode, fp: FPTree, reduce_items: bool, min_count: int,
         stats: GFPStats) -> None:
    for item, child in tnode.children.items():
        stats.nodes_visited += 1
        stats.header_consults += 1
        if item not in fp:                                   # (#2) O(1)
            continue
        if child.target:                                     # (#6)
            stats.count_computations += 1
            child.g_count = fp.item_count(item)
        if child.has_children():                             # (#3)
            item_filter = child.subtree_items if reduce_items else None
            ctree = fp.conditional_tree(item, min_count=min_count,
                                        item_filter=item_filter)  # (#4)
            stats.conditional_trees += 1
            if not ctree.is_empty():
                stats.recursive_calls += 1
                _gfp(child, ctree, reduce_items, min_count, stats)
