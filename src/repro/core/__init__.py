# The paper's primary contribution — paper-faithful host implementations of
# the FP-tree, FP-growth, TIS-tree, GFP-growth (Algorithm 3.1) and the
# Minority-Report Algorithm (Algorithm 4.1).  The TPU-native engine derived
# from these lives in repro.mining + repro.kernels.
from .fptree import FPTree, ItemOrder
from .tis import TISTree, TISNode
from .fpgrowth import fp_growth, fp_growth_into_tis, mine_frequent
from .gfp import GFPStats, gfp_growth
from .mra import MRAResult, Rule, full_fpgrowth_rules, minority_report
from .apriori import apriori, apriori_gen, brute_force_counts

__all__ = [
    "FPTree", "ItemOrder", "TISTree", "TISNode",
    "fp_growth", "fp_growth_into_tis", "mine_frequent",
    "GFPStats", "gfp_growth",
    "MRAResult", "Rule", "full_fpgrowth_rules", "minority_report",
    "apriori", "apriori_gen", "brute_force_counts",
]
from .optimal_rules import is_optimal_set, optimal_rule_set
