"""Paper §5.1 — per-level Apriori candidate counting via a single GFP call.

"At each level, use the Apriori candidate-generation procedure and create a
tree representing the candidates.  Count the frequency of all the candidates by
applying a single invocation of the guided FP-growth procedure with the
candidate-representing TIS-tree as its guide."

This replaces the per-candidate (or per-itemset) targeted-mining invocations of
[5]/[6] with one guided pass per level, eliminating repeated overlapping walks
of the tree.  The FP-tree over the dataset is built once and reused each level.
"""
from __future__ import annotations

from typing import Dict, Hashable, Sequence, Set, Tuple, FrozenSet

from .apriori import apriori_gen
from .fptree import FPTree, ItemOrder
from .gfp import GFPStats, gfp_growth
from .tis import TISTree

Item = Hashable


def apriori_gfp(
    transactions: Sequence[Sequence[Item]],
    min_count: int,
) -> Tuple[Dict[Tuple[Item, ...], int], GFPStats]:
    """Level-wise frequent-itemset mining: Apriori generation + GFP counting.

    Returns ({sorted-tuple itemset -> count}, aggregated GFPStats).
    Exactly equivalent to FP-growth / Apriori output (tested).
    """
    counts: Dict[Item, int] = {}
    for t in transactions:
        for a in set(t):
            counts[a] = counts.get(a, 0) + 1
    order = ItemOrder.from_counts(counts, min_count=min_count)
    tree = FPTree.build(transactions, order)

    out: Dict[Tuple[Item, ...], int] = {}
    frequent: Set[FrozenSet] = set()
    for a in order.items_by_rank:
        out[(a,)] = counts[a]
        frequent.add(frozenset([a]))

    total_stats = GFPStats()
    k = 1
    while frequent:
        cands = apriori_gen(frequent, k)
        cands = [c for c in cands if all(a in order for a in c)]
        if not cands:
            break
        tis = TISTree(order)
        for c in cands:
            tis.insert(sorted(c, key=repr), target=True)
        stats = gfp_growth(tis, tree)  # ONE guided pass counts all candidates
        total_stats.merge(stats)
        frequent = set()
        for node in tis.targets():
            if node.g_count >= min_count:
                itemset = node.itemset()
                frequent.add(frozenset(itemset))
                out[tuple(sorted(itemset, key=repr))] = node.g_count
        k += 1
    return out, total_stats
