"""Optimal class-association rule set (Li, Shen & Topor 2002 — the paper's
§5.1 reference [26]) over Minority-Report output.

A rule α→c is in the optimal set iff no rule β→c with β ⊂ α has confidence
>= confidence(α→c): supersets that don't improve confidence are redundant for
classification (Li et al. prove the optimal set has the same predictive power
as the complete set).  The paper suggests GFP-growth as the counting engine
for per-level optimal-rule discovery ([7], [8]); here the filter runs over the
complete MRA rule set, whose counts GFP-growth already collected in one pass —
no further tree mining is needed.
"""
from __future__ import annotations

from itertools import combinations
from typing import Dict, List, Sequence, Tuple

from .mra import Rule


def optimal_rule_set(rules: Sequence[Rule], eps: float = 1e-12) -> List[Rule]:
    """Filter to the optimal set: drop α→c if some proper subset β→c has
    confidence(β) >= confidence(α)."""
    by_ante: Dict[Tuple, float] = {r.antecedent: r.confidence for r in rules}
    out: List[Rule] = []
    for r in rules:
        ante = r.antecedent
        dominated = False
        for k in range(1, len(ante)):
            for sub in combinations(ante, k):
                c = by_ante.get(tuple(sub))
                if c is not None and c >= r.confidence - eps:
                    dominated = True
                    break
            if dominated:
                break
        if not dominated:
            out.append(r)
    return out


def is_optimal_set(rules: Sequence[Rule], universe: Sequence[Rule]) -> bool:
    """Check the optimality invariant (for property tests)."""
    by_ante = {r.antecedent: r.confidence for r in universe}
    for r in rules:
        for k in range(1, len(r.antecedent)):
            for sub in combinations(r.antecedent, k):
                c = by_ante.get(tuple(sub))
                if c is not None and c >= r.confidence + 1e-12:
                    return False
    return True
