"""Paper §5.2 — incremental frequent-itemset mining via GFP-growth.

Setting: a (potentially huge) original dataset already mined at relative
threshold theta, plus a new increment batch.  The paper's idea: "perform guided
mining of the (potentially huge) original FP-growth tree, focusing only on
itemsets which may potentially become frequent" — i.e. those frequent in the
increment but not previously frequent — plus a guided pass over the (small)
increment tree to refresh counts of the previously-frequent itemsets.

Pigeonhole guarantee (exactness): if an itemset is frequent in the combined
dataset, C(α) >= θ(n₀+n₁), then C₀(α) >= θ·n₀ or C₁(α) >= θ·n₁ — so the
candidate set {frequent in original} ∪ {frequent in increment} is complete.

Note on the FP-tree item universe: as the paper discusses, a min-support-built
FP-tree drops globally-infrequent items, which breaks incremental exactness
when such an item becomes frequent.  ``IncrementalMiner`` therefore keeps its
base FP-tree over the *full* item universe (min_count=1 at the item level, as
itemset trees do); the frequent-itemset *mining* threshold is still theta.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (Dict, Hashable, Iterable, List, Optional, Sequence,
                    Tuple)

from .fpgrowth import mine_frequent
from .fptree import FPTree, ItemOrder
from .gfp import GFPStats, gfp_growth
from .tis import TISTree

Item = Hashable
Key = Tuple[Item, ...]


def incremental_candidates(
    prev_frequent: Iterable[Key],
    inc_frequent: Iterable[Key],
) -> Tuple[List[Key], List[Key]]:
    """§5.2 pigeonhole candidate set, partitioned.

    Returns ``(previously, newly)``: the itemsets frequent before the
    increment, and those frequent in the increment but not before — disjoint,
    each repr-sorted (deterministic).  Their union is COMPLETE: if
    C(α) >= θ(n₀+n₁) then C₀(α) >= θ·n₀ or C₁(α) >= θ·n₁, so any
    combined-frequent itemset is in one of the two lists.  Shared by the host
    ``IncrementalMiner`` (guided FP-tree recounts per partition) and the
    engine-backed recount in ``repro.serve`` (one dense/streaming batch over
    the union).
    """
    prev = set(prev_frequent)
    previously = sorted(prev, key=repr)
    newly = sorted((k for k in inc_frequent if k not in prev), key=repr)
    return previously, newly


@dataclass
class IncrementalState:
    order: ItemOrder
    tree: FPTree                       # FP-tree over all data seen so far
    n: int                             # transactions so far
    frequent: Dict[Tuple[Item, ...], int]  # current frequent set with counts
    stats: GFPStats


class IncrementalMiner:
    """Maintains the frequent-itemset set of a growing dataset using GFP-guided
    recounts instead of full re-mining."""

    def __init__(self, theta: float):
        if not (0.0 < theta <= 1.0):
            raise ValueError("theta in (0, 1]")
        self.theta = theta
        self.state: Optional[IncrementalState] = None

    def _require_state(self) -> IncrementalState:
        if self.state is None:
            raise RuntimeError("call fit() first")
        return self.state

    @property
    def frequent(self) -> Dict[Tuple[Item, ...], int]:
        """Current frequent set with counts (requires ``fit()``)."""
        return dict(self._require_state().frequent)

    @property
    def n_seen(self) -> int:
        """Transactions folded in so far (requires ``fit()``)."""
        return self._require_state().n

    # -- bootstrap -----------------------------------------------------------
    def fit(self, transactions: Sequence[Sequence[Item]]) -> Dict[Tuple[Item, ...], int]:
        db = [list(t) for t in transactions]
        counts: Dict[Item, int] = {}
        for t in db:
            for a in set(t):
                counts[a] = counts.get(a, 0) + 1
        order = ItemOrder.from_counts(counts, min_count=1)  # full item universe
        tree = FPTree.build(db, order)
        n = len(db)
        min_count = _ceil(self.theta * n)
        frequent = mine_frequent(db, min_count, order=order)
        self.state = IncrementalState(order, tree, n, frequent, GFPStats())
        return dict(frequent)

    # -- increment -----------------------------------------------------------
    def update(self, new_transactions: Sequence[Sequence[Item]]) -> Dict[Tuple[Item, ...], int]:
        st = self._require_state()
        inc = [list(t) for t in new_transactions]
        n1 = len(inc)
        n_total = st.n + n1

        # Items possibly unseen before: extend the order (appended at the tail;
        # relative order of existing items is preserved so the existing tree
        # remains valid).
        new_items = []
        seen = set(st.order.rank)
        for t in inc:
            for a in set(t):
                if a not in seen:
                    seen.add(a)
                    new_items.append(a)
        if new_items:
            order = ItemOrder(st.order.items_by_rank + sorted(new_items, key=repr))
            st.tree.order = order  # tail extension: existing paths unaffected
            st.order = order

        # 1) Mine the small increment at the combined-threshold-compatible
        #    level: candidates must reach theta*n1 in the increment (pigeonhole).
        inc_min = _ceil(self.theta * n1)
        inc_frequent = mine_frequent(inc, inc_min, order=st.order)
        previously, newly = incremental_candidates(st.frequent, inc_frequent)

        # 2) Guided recount of previously-frequent itemsets in the increment
        #    (small tree) — refresh their counts.
        inc_tree = FPTree.build(inc, st.order)
        if previously:
            tis_old = TISTree(st.order)
            for itemset in previously:
                tis_old.insert(itemset, target=True)
            st.stats.merge(gfp_growth(tis_old, inc_tree))
            old_updated = {
                k: st.frequent[k] + cnt
                for k, cnt in tis_old.as_dict("g_count").items()
            }
        else:
            old_updated = {}

        # 3) Guided recount, in the HUGE original tree, of itemsets newly
        #    frequent in the increment only — the paper's §5.2 focus.
        new_counts: Dict[Tuple[Item, ...], int] = {}
        if newly:
            tis_new = TISTree(st.order)
            for itemset in newly:
                tis_new.insert(itemset, target=True)
            st.stats.merge(gfp_growth(tis_new, st.tree))
            for k, c_orig in tis_new.as_dict("g_count").items():
                new_counts[k] = c_orig + inc_frequent[k]

        # 4) Merge + final threshold over the combined dataset.
        min_total = _ceil(self.theta * n_total)
        merged = {**old_updated, **new_counts}
        frequent = {k: c for k, c in merged.items() if c >= min_total}

        # 5) Fold the increment into the base tree for future updates.
        for t in inc:
            st.tree.insert(st.order.sort_transaction(t))
        st.n = n_total
        st.frequent = frequent
        return dict(frequent)


def ceil_count(x: float) -> int:
    """The repo-wide frequency threshold rule: ``count >= x`` with a float
    threshold, epsilon-guarded against FP noise, floored at 1.  Shared by the
    host miners, the incremental miner, and the serving engine's theta ->
    min_count conversion (``CountServer.mine``); the unified level-wise
    driver (``mining/driver.py``) takes the resulting ``min_count`` directly,
    so every engine applies ONE rule — the parity tests assume it."""
    import math
    return max(1, math.ceil(x - 1e-9))


_ceil = ceil_count  # internal alias
