"""Apriori baseline (Agrawal & Srikant 1994) + brute-force counting oracle.

The paper positions FP-growth/GFP-growth against Apriori-like candidate
generation; we ship Apriori both as a benchmark baseline and as the candidate
generator for the §5.1 extension (per-level GFP counting).
"""
from __future__ import annotations

from itertools import combinations
from typing import Dict, FrozenSet, Hashable, Iterable, List, Sequence, Set, Tuple

Item = Hashable


def brute_force_counts(
    transactions: Sequence[Sequence[Item]],
    itemsets: Iterable[Sequence[Item]],
    weights: Sequence[int] = None,
) -> Dict[Tuple[Item, ...], int]:
    """Oracle: exact count of each itemset by direct subset tests."""
    tsets = [frozenset(t) for t in transactions]
    if weights is None:
        weights = [1] * len(tsets)
    out: Dict[Tuple[Item, ...], int] = {}
    for its in itemsets:
        key = tuple(sorted(set(its), key=repr))
        s = frozenset(its)
        out[key] = sum(w for t, w in zip(tsets, weights) if s <= t)
    return out


def apriori_gen(frequent_k: Set[FrozenSet], k: int) -> List[FrozenSet]:
    """Candidate generation with prefix join + anti-monotone prune."""
    cands: Set[FrozenSet] = set()
    freq = sorted(frequent_k, key=lambda s: tuple(sorted(map(repr, s))))
    for i, a in enumerate(freq):
        for b in freq[i + 1:]:
            u = a | b
            if len(u) == k + 1:
                if all(frozenset(c) in frequent_k for c in combinations(u, k)):
                    cands.add(u)
    return sorted(cands, key=lambda s: tuple(sorted(map(repr, s))))


def apriori(
    transactions: Sequence[Sequence[Item]],
    min_count: int,
) -> Dict[Tuple[Item, ...], int]:
    """Classic Apriori.  Returns {sorted-tuple itemset -> count}."""
    tsets = [frozenset(t) for t in transactions]
    counts: Dict[Item, int] = {}
    for t in tsets:
        for a in t:
            counts[a] = counts.get(a, 0) + 1
    out: Dict[Tuple[Item, ...], int] = {}
    frequent: Set[FrozenSet] = set()
    for a, c in counts.items():
        if c >= min_count:
            frequent.add(frozenset([a]))
            out[(a,)] = c
    k = 1
    while frequent:
        cands = apriori_gen(frequent, k)
        if not cands:
            break
        ccount = {c: 0 for c in cands}
        for t in tsets:
            for c in cands:
                if c <= t:
                    ccount[c] += 1
        frequent = set()
        for c, n in ccount.items():
            if n >= min_count:
                frequent.add(c)
                out[tuple(sorted(c, key=repr))] = n
        k += 1
    return out
