from .pipeline import TokenPipeline, TransactionPipeline
from .synth import bernoulli_db, census_like_db, token_stream
