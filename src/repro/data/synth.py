"""Synthetic data generators.

``bernoulli_db``   — the paper's §4.3 simulation model: each item is Bernoulli
(p_X) per transaction; the class label is Bernoulli(p_Y).
``census_like_db`` — a categorical dataset matching the paper's preprocessed
UCI 'Census income' schema (12 columns, 115 distinct items, imbalanced target
via p_Y resampling).  The real UCI file isn't downloadable offline; the
generator reproduces the *shape* of the experiment (items-per-row = #columns,
several categories per column, correlated target) so the Fig-6 benchmark
exercises the same workload pattern.
``token_stream``   — LM token corpus for the training substrate.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

# the paper's census preprocessing: 12 categorical columns, 115 items total
CENSUS_COLUMNS: Tuple[Tuple[str, int], ...] = (
    ("age", 5), ("workclass", 7), ("fnlwgt", 10), ("education", 16),
    ("marital.status", 7), ("occupation", 14), ("relationship", 6),
    ("race", 5), ("sex", 2), ("hours.per.week", 6), ("native.country", 32),
    ("salary_proxy_bin", 5),
)
assert sum(k for _, k in CENSUS_COLUMNS) == 115


def bernoulli_db(n_transactions: int, n_items: int, p_x: float, p_y: float,
                 seed: int = 0) -> Tuple[List[List[int]], np.ndarray]:
    """Paper §4.3 simulation: returns (transactions, classes)."""
    rng = np.random.default_rng(seed)
    mat = rng.random((n_transactions, n_items)) < p_x
    y = (rng.random(n_transactions) < p_y).astype(np.int32)
    tx = [np.flatnonzero(row).tolist() for row in mat]
    return tx, y


def census_like_db(n_rows: int, p_y: float, seed: int = 0,
                   target_correlation: float = 0.35
                   ) -> Tuple[List[List[str]], np.ndarray]:
    """Imbalanced categorical rows: every row has one item per column (the
    paper's transaction encoding of a table); the target class tilts a subset
    of columns' category distributions so that real rules exist."""
    rng = np.random.default_rng(seed)
    y = (rng.random(n_rows) < p_y).astype(np.int32)
    rows: List[List[str]] = []
    for i in range(n_rows):
        row = []
        for col, k in CENSUS_COLUMNS:
            base = rng.zipf(1.7) % k  # skewed category popularity
            if y[i] and rng.random() < target_correlation:
                cat = (base + 1) % k  # class-correlated shift => minable rules
            else:
                cat = base
            row.append(f"{col}={cat}")
        rows.append(row)
    return rows, y


def token_stream(n_tokens: int, vocab_size: int, seed: int = 0,
                 zipf_a: float = 1.3) -> np.ndarray:
    """Zipfian token ids (LM training data)."""
    rng = np.random.default_rng(seed)
    toks = rng.zipf(zipf_a, size=n_tokens) - 1
    return (toks % vocab_size).astype(np.int32)
