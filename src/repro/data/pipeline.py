"""Sharded, deterministic, restartable data pipeline for LM training.

Determinism + elasticity: batch content is a pure function of (seed, step,
global batch size) — NOT of topology.  A job restarted on a different mesh
(or with a straggler host removed) re-derives exactly the remaining stream
from the checkpointed step counter, so no sample is lost or repeated.

Each host materializes only its addressable slice (here: the whole batch on
the single-process container; `host_slice` carries the per-process math).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The full logical batch for ``step`` (pure function)."""
        rng = np.random.default_rng((self.seed, step))
        toks = rng.zipf(self.zipf_a, size=(self.global_batch, self.seq_len + 1)) - 1
        toks = (toks % self.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_slice(self, step: int, process_index: Optional[int] = None,
                   process_count: Optional[int] = None) -> Dict[str, np.ndarray]:
        pi = jax.process_index() if process_index is None else process_index
        pc = jax.process_count() if process_count is None else process_count
        assert self.global_batch % pc == 0
        per = self.global_batch // pc
        batch = self.batch_at(step)
        return {k: v[pi * per:(pi + 1) * per] for k, v in batch.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.host_slice(step)
            step += 1


@dataclass
class TransactionPipeline:
    """Sharded transaction-bitmap stream for the distributed mining engine:
    block ``i`` of the database is a pure function of (seed, i) — mining
    restarts (see MiningCheckpoint) re-derive identical blocks."""
    n_items: int
    p_x: float
    p_y: float
    block_rows: int
    seed: int = 0

    def block(self, index: int) -> Tuple[np.ndarray, np.ndarray]:
        from ..mining.encode import ItemVocab, class_weights, encode_bitmap
        rng = np.random.default_rng((self.seed, index))
        mat = rng.random((self.block_rows, self.n_items)) < self.p_x
        y = (rng.random(self.block_rows) < self.p_y).astype(np.int32)
        vocab = ItemVocab(tuple(range(self.n_items)))
        tx = [np.flatnonzero(r).tolist() for r in mat]
        return encode_bitmap(tx, vocab), class_weights(y, 2)
