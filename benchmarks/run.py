# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness:

  PYTHONPATH=src python -m benchmarks.run [--only fig5|fig6|kernel|scaling]

fig5    — paper Fig 5 (simulation, p_Y in {0.01, 0.1}) runtime + ratios
fig6    — paper Fig 6 (census-like categorical data) runtime + ratios
kernel  — counting-kernel micro + GFP §3.1 optimization ablation
scaling — distributed engine strong-scaling on an 8-device host mesh
stream  — streaming out-of-core sweep vs single-pass dense counting
serve   — micro-batched count serving vs per-query launches, cold/warm cache
mine    — unified level-wise mining driver vs the legacy per-engine loops
shard   — sharded-store throughput (1/2/4/8 shards) + async flush latency
rules   — minority-rule serving cold/warm throughput + 1/2/4-shard parity
gfp     — GFP-hybrid vs level-wise launches-per-mine on dense long patterns
obs     — telemetry overhead on the warm serve path (metrics off vs on)
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig5", "fig6", "kernel", "scaling", "stream",
                             "serve", "mine", "shard", "rules", "gfp",
                             "obs"])
    args = ap.parse_args()

    from .common import emit

    suites = {}
    if args.only in (None, "fig5"):
        from . import fig5_sim
        suites["fig5"] = fig5_sim.run
    if args.only in (None, "fig6"):
        from . import fig6_census
        suites["fig6"] = fig6_census.run
    if args.only in (None, "kernel"):
        from . import kernel_bench
        suites["kernel"] = kernel_bench.run
    if args.only in (None, "scaling"):
        from . import scaling
        suites["scaling"] = scaling.run
    if args.only in (None, "stream"):
        from . import streaming
        suites["stream"] = streaming.run
    if args.only in (None, "serve"):
        from . import serve
        suites["serve"] = serve.run
    if args.only in (None, "mine"):
        from . import mine_loop
        suites["mine"] = mine_loop.run
    if args.only in (None, "shard"):
        from . import shard_serve
        suites["shard"] = shard_serve.run
    if args.only in (None, "rules"):
        from . import rule_serve
        suites["rules"] = rule_serve.run
    if args.only in (None, "gfp"):
        from . import gfp_hybrid
        suites["gfp"] = gfp_hybrid.run
    if args.only in (None, "obs"):
        from . import obs_overhead
        suites["obs"] = obs_overhead.run

    print("name,us_per_call,derived")
    ok = True
    for name, fn in suites.items():
        try:
            emit(fn())
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"{name}/SUITE_FAILED,0,{type(e).__name__}:{e}",
                  file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
