"""Benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
