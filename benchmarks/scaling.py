"""Distributed-engine strong scaling: the same counting workload on host
meshes of 1..8 CPU devices (subprocess — this process keeps 1 device).
Derived column: speedup vs 1 device and exactness check."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

from .common import Row

SCRIPT = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.mining import ItemVocab, class_weights, encode_bitmap, encode_targets
from repro.mining.distributed import distributed_counts

rng = np.random.default_rng(0)
N, M, K = 60000, 48, 512
mat = rng.random((N, M)) < 0.2
tx = [np.flatnonzero(r).tolist() for r in mat]
y = rng.integers(0, 2, N)
vocab = ItemVocab(tuple(range(M)))
bits = encode_bitmap(tx, vocab)
w = class_weights(y, 2)
tgts = []
for _ in range(K):
    tgts.append(sorted(rng.choice(M, size=rng.integers(1, 4), replace=False).tolist()))
masks = encode_targets(tgts, vocab)

out = {}
ref = None
for d in (1, 2, 4, 8):
    mesh = jax.make_mesh((d,), ("data",), devices=jax.devices()[:d])
    # warm
    distributed_counts(bits, masks, w, mesh, model_axis=None)
    t0 = time.perf_counter()
    got = distributed_counts(bits, masks, w, mesh, model_axis=None)
    dt = time.perf_counter() - t0
    if ref is None:
        ref = got
    assert (got == ref).all()
    out[d] = dt * 1e6
print(json.dumps(out))
"""


def run() -> List[Row]:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-1500:])
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    base = data["1"]
    rows: List[Row] = []
    for d, us in data.items():
        rows.append((f"scaling[devices={d}]", us,
                     f"speedup_vs_1dev={base / us:.2f}x"))
    return rows
