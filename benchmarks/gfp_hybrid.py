"""GFP-hybrid vs level-wise sweep: kernel launches per mine and wall time.

The level-wise engines pay one whole-DB kernel launch per candidate level;
the GFP hybrid (``repro/mining/gfp_backend.py``) counts each level's
candidates against per-tail-item conditional pattern bases — blocks small
enough to count on the host pay NO launch at all, larger ones pay one launch
per tree item.  On a dense long-pattern workload (the FP-growth home turf:
high density, heavy prefix compression, mining depth >= 4) this bench
records launches-per-mine and wall time for:

  levelwise/dense  — the driver over ``DenseBackend`` (one launch per level)
  gfp/hybrid       — the driver over ``GFPBackend`` (host/kernel per block)
  gfp/device-only  — ``host_rows=0`` ablation: every conditional block goes
                     through the kernel (quantifies the hybrid's host side)

  PYTHONPATH=src python -m benchmarks.gfp_hybrid [--json BENCH_gfp.json]
  PYTHONPATH=src python -m benchmarks.gfp_hybrid --smoke   # CI sanity check

Exactness is asserted for every variant (identical frequent dicts), and the
regression gate is enforced on every run: at mining depth >=
``GATE_MIN_DEPTH`` the hybrid must show at least ``GATE_MIN_REDUCTION``x
fewer kernel launches than the level-wise sweep.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.mining import (DenseBackend, DenseDB, GFPBackend,
                          mine_frequent_backend)

from .common import Row

N, M, P, MIN_COUNT = 30_000, 12, 0.55, 900
SMOKE = (3_000, 10, 0.55, 90)
REPEATS = 3

GATE_MIN_REDUCTION = 2.0   # hybrid must launch >= 2x less than level-wise
GATE_MIN_DEPTH = 4         # ... at a mining depth where levels pile up


def _transactions(n: int, m: int, p: float, seed: int = 0) -> List[List[int]]:
    rng = np.random.default_rng(seed)
    mat = rng.random((n, m)) < p
    return [np.flatnonzero(row).tolist() for row in mat]


class _CountingDense(DenseBackend):
    """DenseBackend with a kernel-launch counter (one launch per counts())."""

    def __init__(self, db, **kw):
        super().__init__(db, **kw)
        self.kernel_launches = 0

    def counts(self, masks, *, start_chunk=0, init=None, on_chunk=None):
        if start_chunk < self.n_count_chunks and masks.shape[0]:
            self.kernel_launches += 1
        return super().counts(masks, start_chunk=start_chunk, init=init,
                              on_chunk=on_chunk)


def _best_run(make_backend, min_count, repeats):
    """Fastest of ``repeats`` full mines, each on a FRESH backend (no warm
    conditional-block cache): (seconds, launches, host_blocks, result)."""
    best = None
    for _ in range(repeats):
        backend = make_backend()
        t0 = time.perf_counter()
        got = mine_frequent_backend(backend, min_count)
        dt = time.perf_counter() - t0
        if best is None or dt < best[0]:
            best = (dt, backend.kernel_launches,
                    getattr(backend, "host_blocks", 0), got)
    return best


def run(record: Optional[List[dict]] = None, smoke: bool = False,
        repeats: int = REPEATS) -> List[Row]:
    n, m, p, min_count = SMOKE if smoke else (N, M, P, MIN_COUNT)
    tx = _transactions(n, m, p)
    db = DenseDB.encode(tx)

    variants = [
        ("levelwise/dense", lambda: _CountingDense(db)),
        ("gfp/hybrid", lambda: GFPBackend(db)),
        ("gfp/device-only", lambda: GFPBackend(db, host_rows=0)),
    ]

    rows: List[Row] = []
    results: Dict[str, Dict[Tuple[int, ...], int]] = {}
    launches: Dict[str, int] = {}
    for name, make in variants:
        dt, nl, host_blocks, got = _best_run(make, min_count, repeats)
        results[name] = got
        launches[name] = nl
        rows.append((f"gfp_hybrid/{name}", dt * 1e6,
                     f"launches={nl};host_blocks={host_blocks};"
                     f"frequent={len(got)}"))
        if record is not None:
            record.append({"variant": name, "total_us": dt * 1e6,
                           "kernel_launches": nl,
                           "host_blocks": host_blocks,
                           "n_frequent": len(got)})

    # exactness: all three count paths produce the identical frequent dict
    assert results["gfp/hybrid"] == results["levelwise/dense"]
    assert results["gfp/device-only"] == results["levelwise/dense"]

    # the regression gate: a dense long-pattern mine (depth >= 4) must show
    # the headline launch reduction, every run
    depth = max(len(k) for k in results["levelwise/dense"])
    assert depth >= GATE_MIN_DEPTH, \
        f"workload too shallow for the gate: depth {depth}"
    reduction = launches["levelwise/dense"] / max(1, launches["gfp/hybrid"])
    assert reduction >= GATE_MIN_REDUCTION, \
        (f"launch reduction regressed: {reduction:.2f}x < "
         f"{GATE_MIN_REDUCTION}x (levelwise {launches['levelwise/dense']}, "
         f"hybrid {launches['gfp/hybrid']})")
    rows.append(("gfp_hybrid/launch_reduction", reduction,
                 f"depth={depth};gate>={GATE_MIN_REDUCTION}"))
    if record is not None:
        record.append({"variant": "launch_reduction", "ratio": reduction,
                       "depth": depth, "gate": GATE_MIN_REDUCTION})
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_gfp.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem, exactness + gate only (no JSON)")
    ap.add_argument("--repeats", type=int, default=REPEATS)
    args = ap.parse_args()

    record: Optional[List[dict]] = None if args.smoke else []
    rows = run(record, smoke=args.smoke, repeats=args.repeats)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.smoke:
        print("gfp smoke OK (hybrid == level-wise, launch gate holds)")
        return

    payload = {
        "bench": "gfp_hybrid",
        "backend": jax.default_backend(),
        "problem": {"n": N, "m": M, "p": P, "min_count": MIN_COUNT},
        "gate": {"min_reduction": GATE_MIN_REDUCTION,
                 "min_depth": GATE_MIN_DEPTH},
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
