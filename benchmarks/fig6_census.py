"""Paper Figure 6 analogue: census-income-shaped categorical data (12 columns,
115 items — the paper's preprocessing), target-class probability p_Y swept by
resampling, min-support 5e-4 as in the paper.  Reports FP-growth vs
MRA/GFP-growth vs dense-engine runtimes and the ratio."""
from __future__ import annotations

import time
from typing import List

from repro.core import full_fpgrowth_rules, minority_report
from repro.data import census_like_db
from repro.mining import minority_report_dense

from .common import Row


def run() -> List[Row]:
    rows: List[Row] = []
    n_rows = 4000
    for p_y in (0.01, 0.05, 0.1, 0.25):
        tx, y = census_like_db(n_rows, p_y, seed=int(p_y * 1000))
        # 5e-3 keeps the full-FP-growth baseline tractable on one core (the
        # paper's 5e-4 at 22.5k rows runs on an m4.16xlarge)
        min_sup = 5e-3
        t0 = time.perf_counter()
        base = full_fpgrowth_rules(tx, y, min_support=min_sup, min_confidence=0.0)
        t_full = time.perf_counter() - t0
        t0 = time.perf_counter()
        mra = minority_report(tx, y, min_support=min_sup, min_confidence=0.0)
        t_mra = time.perf_counter() - t0
        t0 = time.perf_counter()
        dense = minority_report_dense(tx, y, min_support=min_sup,
                                      min_confidence=0.0)
        t_dense = time.perf_counter() - t0

        a = {r.antecedent for r in base}
        b = {r.antecedent for r in mra.rules}
        c = {r.antecedent for r in dense.rules}
        assert a == b == c

        tag = f"fig6[pY={p_y},rows={n_rows}]"
        rows.append((f"{tag}/fpgrowth_full", t_full * 1e6, f"rules={len(a)}"))
        rows.append((f"{tag}/mra_gfp", t_mra * 1e6,
                     f"speedup_vs_full={t_full / max(t_mra, 1e-9):.1f}x"))
        rows.append((f"{tag}/mra_dense", t_dense * 1e6,
                     f"speedup_vs_full={t_full / max(t_dense, 1e-9):.1f}x"))
    return rows
