"""Disk-tier benchmark: spilled (mmap + async prefetch) sweep vs all-RAM.

Times the same chunked counting sweep from three residencies — host-RAM
streaming (the baseline the disk tier must stay close to), spilled segments
with the async prefetch thread overlapping disk reads + H2D with the kernel,
and spilled WITHOUT prefetch (the synchronous ablation isolating what the
overlap buys) — verifies all three bit-identical to the blocked jnp oracle,
and enforces the acceptance envelope in-run: the prefetch-overlapped spilled
sweep must stay within ``MAX_SLOWDOWN``x of all-RAM.  Run as a script it
emits ``BENCH_disk.json`` (gated by ``tools/perfgate.py --suite disk``).

  PYTHONPATH=src python -m benchmarks.disk_tier [--json BENCH_disk.json]
  PYTHONPATH=src python -m benchmarks.disk_tier --smoke   # CI sanity check
"""
from __future__ import annotations

import json
import shutil
import tempfile
from typing import List, Optional

import numpy as np

from repro.kernels.itemset_count import itemset_counts_ref_blocked
from repro.mining import ItemVocab, SpilledDB, spilled_counts, streaming_counts
from repro.obs import REGISTRY, counter_total

from .common import Row, timeit

N, K, W, C = 65536, 256, 4, 2
CHUNK = 8192
SMOKE = {"n": 4096, "k": 32, "chunk": 512}   # 8 real segments, tiny budget
MAX_SLOWDOWN = 1.5   # spilled+prefetch must stay within 1.5x of all-RAM


def _problem(n: int, k: int, w: int, c: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    tx = (rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32)
          & rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    tgt = np.zeros((k, w), np.uint32)
    for i in range(k):
        for b in rng.integers(0, 32 * w, 3):
            tgt[i, b >> 5] |= np.uint32(1) << np.uint32(b & 31)
    wts = rng.integers(0, 3, (n, c)).astype(np.int32)
    return tx, tgt, wts


def _prefetch_hit_ratio(db: SpilledDB, tgt: np.ndarray) -> float:
    """One instrumented sweep; hit ratio from the registry deltas."""
    before = REGISTRY.snapshot()
    np.asarray(spilled_counts(db, tgt, prefetch=True))
    after = REGISTRY.snapshot()
    hits = (counter_total(after, "spill_prefetch_hits_total")
            - counter_total(before, "spill_prefetch_hits_total"))
    misses = (counter_total(after, "spill_prefetch_misses_total")
              - counter_total(before, "spill_prefetch_misses_total"))
    total = hits + misses
    return hits / total if total else 0.0


def run(record: Optional[List[dict]] = None, smoke: bool = False) -> List[Row]:
    import jax.numpy as jnp

    n = SMOKE["n"] if smoke else N
    k = SMOKE["k"] if smoke else K
    chunk = SMOKE["chunk"] if smoke else CHUNK
    tx, tgt, wts = _problem(n, k, W, C)
    want = np.asarray(itemset_counts_ref_blocked(
        jnp.asarray(tx), jnp.asarray(tgt), jnp.asarray(wts)))
    n_chunks = -(-n // chunk)
    rows: List[Row] = []
    tag = f"disk[N={n},K={k},W={W},chunk={chunk}]"

    out = np.asarray(streaming_counts(tx, tgt, wts, chunk_rows=chunk))
    assert (out == want).all()
    us_ram = timeit(lambda: np.asarray(
        streaming_counts(tx, tgt, wts, chunk_rows=chunk)))
    rows.append((f"{tag}/all_ram", us_ram, f"chunks={n_chunks}"))
    if record is not None:
        record.append({"variant": "all_ram", "chunk_rows": chunk,
                       "us_per_sweep": us_ram, "n_chunks": n_chunks,
                       "match": True})

    spill_dir = tempfile.mkdtemp(prefix="repro-bench-spill-")
    try:
        db = SpilledDB.spill(ItemVocab(tuple(range(32 * W))), tx, wts,
                             n, C, spill_dir, chunk_rows=chunk)
        assert db.n_chunks == n_chunks   # real spills, same grid as all-RAM

        for prefetch, variant in ((True, "spilled_prefetch"),
                                  (False, "spilled_sync")):
            out = np.asarray(spilled_counts(db, tgt, prefetch=prefetch))
            match = bool((out == want).all())
            assert match, variant        # bit-identical to the all-RAM sweep
            us = timeit(lambda: np.asarray(
                spilled_counts(db, tgt, prefetch=prefetch)))
            rows.append((f"{tag}/{variant}", us,
                         f"slowdown_vs_ram={us / max(us_ram, 1e-9):.2f}x"))
            if record is not None:
                record.append({"variant": variant, "chunk_rows": chunk,
                               "us_per_sweep": us, "n_chunks": n_chunks,
                               "match": match})
            if prefetch:
                us_pre = us

        hit_ratio = _prefetch_hit_ratio(db, tgt)
    finally:
        shutil.rmtree(spill_dir, ignore_errors=True)

    # the acceptance envelope: overlapped disk reads must not cost more than
    # MAX_SLOWDOWN of the all-RAM sweep (ratio HIGHER is better, 1.0 = free).
    # The smoke problem is too small for overlap to amortize the prefetch
    # thread's fixed cost (sub-ms segments), so smoke only sanity-bounds it;
    # the full-size record is what the perfgate pins.
    overlap = us_ram / max(us_pre, 1e-9)
    rows.append((f"{tag}/overlap", us_pre,
                 f"ram_over_spilled={overlap:.2f};hit_ratio={hit_ratio:.2f}"))
    if record is not None:
        record.append({"variant": "overlap", "ratio": overlap,
                       "hit_ratio": hit_ratio, "max_slowdown": MAX_SLOWDOWN})
    envelope = 10.0 if smoke else MAX_SLOWDOWN
    assert overlap >= 1.0 / envelope, (
        f"spilled+prefetch sweep {us_pre:.0f}us exceeds "
        f"{envelope}x the all-RAM sweep {us_ram:.0f}us")
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_disk.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem with a forced multi-segment spill; "
                         "asserts only, no JSON record")
    args = ap.parse_args()

    record: Optional[List[dict]] = None if args.smoke else []
    rows = run(record, smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.smoke:
        print("disk smoke OK (spilled == all-RAM bit-identical, "
              "overlap envelope holds)")
        return

    payload = {
        "bench": "disk_tier",
        "backend": jax.default_backend(),
        "problem": {"n": N, "k": K, "w": W, "c": C, "chunk_rows": CHUNK},
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
