"""Unified-driver mining-loop benchmark: driver shims vs the legacy loops.

PR 3 consolidated the four level-synchronous mining loops into ONE driver
(``repro/mining/driver.py``) over the ``CountBackend`` protocol.  This bench
proves the refactor is perf-neutral (or better): it replays the PRE-refactor
dense and streaming loops (replicated verbatim below — they no longer exist
in ``src/``) against the driver-backed entry points on the same problem, and
records wall-time PER LEVEL on both engines plus end-to-end totals.

  PYTHONPATH=src python -m benchmarks.mine_loop [--json BENCH_mine.json]
  PYTHONPATH=src python -m benchmarks.mine_loop --smoke   # CI sanity check

Exactness is asserted for every variant (identical frequent dicts), so the
record doubles as a parity smoke.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.apriori import apriori_gen
from repro.mining import (DenseBackend, DenseDB, StreamingBackend,
                          StreamingDB, encode_targets, mine_frequent_backend)
from repro.kernels.itemset_count import itemset_counts

from .common import Row

N, M, P, MIN_COUNT, CHUNK_ROWS = 30_000, 18, 0.3, 2400, 4096
SMOKE = (2_000, 12, 0.3, 220, 512)
REPEATS = 3


def _transactions(n: int, m: int, p: float, seed: int = 0) -> List[List[int]]:
    rng = np.random.default_rng(seed)
    mat = rng.random((n, m)) < p
    return [np.flatnonzero(row).tolist() for row in mat]


# --------------------------------------------------------------------------
# The PRE-refactor loops, replicated as baselines (deleted from src/ by the
# consolidation; kept here so the perf record keeps comparing against them).
# --------------------------------------------------------------------------

def legacy_dense_mine(db: DenseDB, min_count: float, max_len: int,
                      level_times: List[float]) -> Dict[Tuple[int, ...], int]:
    import jax.numpy as jnp

    t0 = time.perf_counter()
    w = np.asarray(db.weights)
    bits_np = np.asarray(db.bits)
    out: Dict[Tuple[int, ...], int] = {}
    frequent = set()
    for c, a in enumerate(db.vocab.items):
        bit = (bits_np[:, c >> 5] >> np.uint32(c & 31)) & 1
        cnt = int((bit[:, None] * w).sum(axis=0).sum())
        if cnt >= min_count:
            frequent.add(frozenset([a]))
            out[(a,)] = cnt
    level_times.append(time.perf_counter() - t0)
    k = 1
    while frequent and (max_len == 0 or k < max_len):
        t0 = time.perf_counter()
        cands = apriori_gen(frequent, k)
        if not cands:
            break
        itemsets = [tuple(sorted(s, key=repr)) for s in cands]
        masks = encode_targets(itemsets, db.vocab)
        counts = np.asarray(itemset_counts(db.bits, jnp.asarray(masks),
                                           db.weights))
        frequent = set()
        for itemset, row in zip(itemsets, counts):
            cnt = int(row.sum())
            if cnt >= min_count:
                frequent.add(frozenset(itemset))
                out[itemset] = cnt
        k += 1
        level_times.append(time.perf_counter() - t0)
    return out


def legacy_streaming_mine(db: StreamingDB, min_count: float, max_len: int,
                          level_times: List[float]
                          ) -> Dict[Tuple[int, ...], int]:
    from repro.mining import streaming_counts

    def count_level(itemsets):
        masks = encode_targets(itemsets, db.vocab)
        return np.asarray(streaming_counts(db.bits, masks, db.weights,
                                           chunk_rows=db.chunk_rows))

    def absorb(itemsets, rows):
        frequent = set()
        for itemset, row in zip(itemsets, rows):
            cnt = int(row.sum())
            if cnt >= min_count:
                frequent.add(frozenset(itemset))
                out[itemset] = cnt
        return frequent

    out: Dict[Tuple[int, ...], int] = {}
    t0 = time.perf_counter()
    singles = [(a,) for a in db.vocab.items]
    frequent = absorb(singles, count_level(singles)) if singles else set()
    level_times.append(time.perf_counter() - t0)
    level = 1
    while frequent and (max_len == 0 or level < max_len):
        t0 = time.perf_counter()
        cands = apriori_gen(frequent, level)
        if not cands:
            break
        itemsets = [tuple(sorted(s, key=repr)) for s in cands]
        frequent = absorb(itemsets, count_level(itemsets))
        level += 1
        level_times.append(time.perf_counter() - t0)
    return out


def _driver_mine(backend, min_count: float, max_len: int,
                 level_times: List[float]) -> Dict[Tuple[int, ...], int]:
    marks = [time.perf_counter()]

    def on_level(level, n_cands, n_freq):
        marks.append(time.perf_counter())

    got = mine_frequent_backend(backend, min_count, max_len=max_len,
                                on_level=on_level)
    level_times.extend(b - a for a, b in zip(marks, marks[1:]))
    return got


def _best_run(fn, repeats: int):
    """(total_seconds, per-level seconds, result) of the fastest repeat."""
    best = None
    for _ in range(repeats):
        levels: List[float] = []
        t0 = time.perf_counter()
        got = fn(levels)
        total = time.perf_counter() - t0
        if best is None or total < best[0]:
            best = (total, levels, got)
    return best


def run(record: Optional[List[dict]] = None, smoke: bool = False,
        repeats: int = REPEATS) -> List[Row]:
    n, m, p, min_count, chunk_rows = SMOKE if smoke else (N, M, P, MIN_COUNT,
                                                          CHUNK_ROWS)
    max_len = 2 if smoke else 0          # smoke: one generated level suffices
    tx = _transactions(n, m, p)
    ddb = DenseDB.encode(tx)
    sdb = StreamingDB.encode(tx, chunk_rows=chunk_rows)

    variants = [
        ("dense/legacy", lambda lv: legacy_dense_mine(ddb, min_count,
                                                      max_len, lv)),
        ("dense/driver", lambda lv: _driver_mine(DenseBackend(ddb), min_count,
                                                 max_len, lv)),
        ("streaming/legacy", lambda lv: legacy_streaming_mine(
            sdb, min_count, max_len, lv)),
        ("streaming/driver", lambda lv: _driver_mine(
            StreamingBackend(sdb), min_count, max_len, lv)),
    ]

    rows: List[Row] = []
    results: Dict[str, dict] = {}
    totals: Dict[str, float] = {}
    for name, fn in variants:
        total, levels, got = _best_run(fn, repeats)
        totals[name] = total
        results[name] = got
        rows.append((f"mine_loop/{name}", total * 1e6,
                     f"levels={len(levels)};frequent={len(got)}"))
        if record is not None:
            record.append({
                "variant": name, "total_us": total * 1e6,
                "us_per_level": [t * 1e6 for t in levels],
                "n_frequent": len(got),
            })

    # exactness: the driver shims reproduce the legacy loops bit-for-bit
    assert results["dense/driver"] == results["dense/legacy"]
    assert results["streaming/driver"] == results["streaming/legacy"]
    assert results["dense/driver"] == results["streaming/driver"]

    for engine in ("dense", "streaming"):
        ratio = totals[f"{engine}/driver"] / max(totals[f"{engine}/legacy"],
                                                 1e-9)
        rows.append((f"mine_loop/{engine}/driver_vs_legacy", ratio,
                     "ratio<=1 means driver is not slower"))
        if record is not None:
            record.append({"variant": f"{engine}/driver_vs_legacy",
                           "ratio": ratio})
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_mine.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem, exactness-only sanity (no JSON)")
    ap.add_argument("--repeats", type=int, default=REPEATS)
    args = ap.parse_args()

    record: Optional[List[dict]] = None if args.smoke else []
    rows = run(record, smoke=args.smoke, repeats=args.repeats)
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.2f},{derived}")
    if args.smoke:
        print("mine-loop smoke OK (driver == legacy on both engines)")
        return

    n, m, p = N, M, P
    payload = {
        "bench": "mine_loop",
        "backend": jax.default_backend(),
        "problem": {"n": n, "m": m, "p": p, "min_count": MIN_COUNT,
                    "chunk_rows": CHUNK_ROWS},
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
