"""Autotune benchmark: tuned launch configs vs the compiled-in defaults.

Runs the two workloads the perf trajectory tracks — jit-warm micro-batched
count serving and a depth-6 GFP hybrid mine — once under the compiled-in
default launch configs and once under a tuning table swept IN-RUN for the
exact geometry buckets the default run touched.  The sweep's
keep-the-default rule (``autotune.KEEP_DEFAULT_WITHIN``) means the tuned
side can only pick a non-default config on a decisive measured win, so
``speedup = default_us / tuned_us`` must sit at >= ~1.0x; the in-run floor
asserts it never collapses below ``FLOOR`` and exactness is asserted on
every path (tuned counts bit-identical to default counts).  Run as a
script it emits ``BENCH_tune.json``; ``tools/perfgate.py --suite tune``
gates the recorded speedups and tuned wall times against that baseline.

  PYTHONPATH=src python -m benchmarks.autotune [--json BENCH_tune.json]
  PYTHONPATH=src python -m benchmarks.autotune --smoke   # CI sanity check
"""
from __future__ import annotations

import json
from typing import List, Optional

import numpy as np

from repro.data import bernoulli_db
from repro.mining import DenseDB, GFPBackend, mine_frequent_backend
from repro.roofline import autotune
from repro import obs

from .common import Row, timeit
from .gfp_hybrid import _transactions
from .serve import _serve_pool

ROWS, ITEMS, POOL, BATCH = 16384, 48, 256, 64
GFP_N, GFP_M, GFP_P, GFP_MIN_COUNT = 30_000, 12, 0.55, 900
SMOKE = dict(rows=2048, pool=32, gfp_n=3_000, gfp_min_count=90)

REPEATS = 3     # timeit median-of-N per side per round
ROUNDS = 3      # re-time both sides up to this many rounds, keep the best
FLOOR = 0.9     # hard in-run floor on tuned-vs-default speedup
SWEEP_REPEATS = 3


def _serve_workload(rows: int, pool: int, seed: int = 0):
    tx, y = bernoulli_db(rows, ITEMS, p_x=0.15, p_y=0.05, seed=seed)
    rng = np.random.default_rng(seed + 1)
    keys = [tuple(rng.choice(ITEMS, size=rng.integers(1, 4),
                             replace=False).tolist())
            for _ in range(pool)]
    return tx, y, keys


def _probe_buckets() -> List[str]:
    """Geometry buckets the default run actually launched (telemetry probe)."""
    return sorted(b for b in obs.kernel_efficiency()
                  if b and b != "overflow")


def _paired_speedup(time_default, time_tuned, table) -> tuple:
    """Time both sides in the same round, up to ROUNDS rounds; keep the best
    pairing (shared-box noise hits both sides of a round equally)."""
    best = None
    for _ in range(ROUNDS):
        autotune.set_active_table(None)
        d = time_default()
        autotune.set_active_table(table)
        t = time_tuned()
        if best is None or d / t > best[2]:
            best = (d, t, d / t)
        if best[2] >= 1.0:
            break
    autotune.set_active_table(None)
    return best


def run(record: Optional[List[dict]] = None, smoke: bool = False) -> List[Row]:
    rows_n = SMOKE["rows"] if smoke else ROWS
    pool_n = SMOKE["pool"] if smoke else POOL
    gfp_n = SMOKE["gfp_n"] if smoke else GFP_N
    gfp_min = SMOKE["gfp_min_count"] if smoke else GFP_MIN_COUNT

    from repro.serve import CountServer

    obs.reset()                      # telemetry on = the geometry probe
    autotune.set_active_table(None)

    tx, y, keys = _serve_workload(rows_n, pool_n)
    gfp_db = DenseDB.encode(_transactions(gfp_n, GFP_M, GFP_P))

    # ---- default run: reference results + geometry probe -------------------
    server_default = CountServer(tx, classes=list(y), cache=False)
    want_counts = _serve_pool(server_default, keys, BATCH)
    want_frequent = mine_frequent_backend(GFPBackend(gfp_db), gfp_min)
    buckets = _probe_buckets()
    assert buckets, "default run recorded no kernel launch geometries"

    # ---- in-run sweep over exactly the buckets the workloads touched -------
    table = autotune.sweep(
        (autotune.bucket_shape(b) for b in buckets),
        repeats=SWEEP_REPEATS,
        block_ks=(128, 256) if smoke else autotune.BLOCK_K_LATTICE,
        log=None)

    rows: List[Row] = []
    tag = f"autotune[N={rows_n},pool={pool_n},gfp_n={gfp_n}]"

    # ---- serve_warm: jit-warm micro-batched serving, cache off -------------
    autotune.set_active_table(table)
    server_tuned = CountServer(tx, classes=list(y), cache=False)
    got = _serve_pool(server_tuned, keys, BATCH)
    assert all((got[k] == want_counts[k]).all() for k in keys), \
        "tuned serve counts diverged from the default path"
    d_us, t_us, speedup = _paired_speedup(
        lambda: timeit(lambda: _serve_pool(server_default, keys, BATCH),
                       repeats=REPEATS, warmup=1) / pool_n,
        lambda: timeit(lambda: _serve_pool(server_tuned, keys, BATCH),
                       repeats=REPEATS, warmup=1) / pool_n,
        table)
    assert speedup >= FLOOR, \
        f"tuned serve lost to the defaults: {speedup:.2f}x < {FLOOR}x"
    rows.append((f"{tag}/serve_warm", t_us, f"speedup={speedup:.2f}x"))
    if record is not None:
        record.append({"variant": "serve_warm", "default_us": d_us,
                       "tuned_us": t_us, "speedup": speedup,
                       "block_k": server_tuned.batcher.block_k})

    # ---- gfp_depth6: full hybrid mine, fresh backend per run ---------------
    autotune.set_active_table(table)
    got_frequent = mine_frequent_backend(GFPBackend(gfp_db), gfp_min)
    assert got_frequent == want_frequent, \
        "tuned GFP mine diverged from the default path"
    d_us, t_us, speedup = _paired_speedup(
        lambda: timeit(
            lambda: mine_frequent_backend(GFPBackend(gfp_db), gfp_min),
            repeats=REPEATS, warmup=1),
        lambda: timeit(
            lambda: mine_frequent_backend(GFPBackend(gfp_db), gfp_min),
            repeats=REPEATS, warmup=1),
        table)
    assert speedup >= FLOOR, \
        f"tuned GFP mine lost to the defaults: {speedup:.2f}x < {FLOOR}x"
    rows.append((f"{tag}/gfp_depth6", t_us, f"speedup={speedup:.2f}x"))
    if record is not None:
        derived = autotune.derived_chooser_thresholds(table)
        record.append({"variant": "gfp_depth6", "default_us": d_us,
                       "tuned_us": t_us, "speedup": speedup,
                       "gfp_host_rows": derived.get("gfp_host_rows")})
        record.append({"variant": "table", "device_kind": table.device_kind,
                       "buckets": {b: autotune._cand_key(
                           e.config.block_k, e.config.accum)
                           for b, e in table.entries.items()},
                       "derived_thresholds": derived})
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_tune.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem, exactness + floor only (no JSON)")
    args = ap.parse_args()

    record: Optional[List[dict]] = None if args.smoke else []
    rows = run(record, smoke=args.smoke)
    print("name,us,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.smoke:
        print("autotune smoke OK (tuned exact + >= floor on both workloads)")
        return

    payload = {
        "bench": "autotune",
        "backend": jax.default_backend(),
        "problem": {"rows": ROWS, "items": ITEMS, "pool": POOL,
                    "batch": BATCH, "gfp_n": GFP_N, "gfp_m": GFP_M,
                    "gfp_p": GFP_P, "gfp_min_count": GFP_MIN_COUNT},
        "floor": FLOOR,
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
