"""Counting-kernel microbenchmarks + §3.1-optimization ablation.

  * itemset_counts (Pallas, interpret on CPU) vs pure-jnp oracle across
    (N, K, W) — derived column carries achieved counting throughput and the
    TPU-target roofline estimate for the same tile schedule;
  * GFP work-counter ablation (conditional trees built / nodes visited) with
    and without data reduction (#4) and vs classic FP-growth — the paper's
    O(1)-checks argument, quantified.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import FPTree, GFPStats, ItemOrder, TISTree, gfp_growth, mine_frequent
from repro.data import bernoulli_db
from repro.kernels.itemset_count import itemset_counts, itemset_counts_ref
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS

from .common import Row, timeit


def _kernel_rows() -> List[Row]:
    import jax.numpy as jnp

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for n, k, w, c in ((4096, 256, 4, 2), (16384, 512, 4, 2), (65536, 1024, 8, 2)):
        tx = jnp.asarray(rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32)
                         & rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
        tgt = np.zeros((k, w), np.uint32)
        for i in range(k):
            b = rng.integers(0, 32 * w, size=3)
            for x in b:
                tgt[i, x >> 5] |= np.uint32(1) << np.uint32(x & 31)
        tgt = jnp.asarray(tgt)
        wts = jnp.asarray(rng.integers(0, 3, (n, c)).astype(np.int32))

        out_ref = itemset_counts_ref(tx, tgt, wts).block_until_ready()
        us_ref = timeit(lambda: itemset_counts_ref(tx, tgt, wts).block_until_ready())
        out_k = itemset_counts(tx, tgt, wts).block_until_ready()
        us_k = timeit(lambda: itemset_counts(tx, tgt, wts).block_until_ready())
        assert (np.asarray(out_ref) == np.asarray(out_k)).all()

        # TPU-target estimate: the kernel streams N*W words once per K-tile
        # and does N*K*W uint32 ops + N*K*C MACs (VPU).
        bytes_hbm = n * w * 4 * max(1, k // 256) + k * w * 4 + n * c * 4
        ops = n * k * (w + c)
        t_mem = bytes_hbm / HBM_BW
        t_cmp = ops / (PEAK_FLOPS / 2)  # VPU int ops, not MXU — conservative /2
        tag = f"kernel[N={n},K={k},W={w}]"
        rows.append((f"{tag}/jnp_oracle", us_ref, f"containments={n * k}"))
        rows.append((f"{tag}/pallas_interpret", us_k,
                     f"tpu_roofline_est_us={max(t_mem, t_cmp) * 1e6:.1f}"))
    return rows


def _gfp_ablation_rows() -> List[Row]:
    rows: List[Row] = []
    tx, _ = bernoulli_db(4000, 40, p_x=0.2, p_y=0.0, seed=3)
    counts = {}
    for t in tx:
        for a in set(t):
            counts[a] = counts.get(a, 0) + 1
    order = ItemOrder.from_counts(counts)
    tree = FPTree.build(tx, order)
    min_count = 60  # low enough that pairs/triples are frequent
    freq = mine_frequent(tx, min_count)
    targets = [k for k in freq if len(k) >= 2][:400]
    assert targets, "ablation needs multi-item targets" 

    for reduce_items, label in ((True, "gfp_with_datareduction"),
                                (False, "gfp_no_datareduction")):
        tis = TISTree(order)
        for t in targets:
            tis.insert(t, target=True)
        t0 = time.perf_counter()
        stats = gfp_growth(tis, tree, use_data_reduction=reduce_items)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"gfp_ablation/{label}", us,
                     f"ctrees={stats.conditional_trees};"
                     f"consults={stats.header_consults}"))

    t0 = time.perf_counter()
    mine_frequent(tx, min_count)
    us_full = (time.perf_counter() - t0) * 1e6
    rows.append(("gfp_ablation/full_fpgrowth_baseline", us_full,
                 f"itemsets={len(freq)};targets={len(targets)}"))
    return rows


def run() -> List[Row]:
    return _kernel_rows() + _gfp_ablation_rows()
