"""Serving benchmark: micro-batched count serving vs one-launch-per-query.

Serves a fixed pool of itemset queries through ``CountServer`` at several
micro-batch sizes with the cache off (cold) and then repeats the hottest
workload with the cache on (warm), against the naive baseline of one kernel
launch per query.  Every counting launch sweeps the whole resident bitmap
regardless of target count, so batching amortizes the sweep — the number the
perf trajectory tracks.  Run as a script it emits ``BENCH_serve.json``.

  PYTHONPATH=src python -m benchmarks.serve [--json BENCH_serve.json]
"""
from __future__ import annotations

import json
from typing import List

import numpy as np

from repro.data import bernoulli_db
from repro.kernels.itemset_count import itemset_counts
from repro.mining import DenseDB, encode_targets
from repro.serve import CountServer

from .common import Row, timeit

ROWS, ITEMS, POOL = 16384, 48, 256
BATCHES = [1, 4, 16, 64]
WARM_BATCH = 64


def _workload(seed: int = 0):
    tx, y = bernoulli_db(ROWS, ITEMS, p_x=0.15, p_y=0.05, seed=seed)
    rng = np.random.default_rng(seed + 1)
    pool = [tuple(rng.choice(ITEMS, size=rng.integers(1, 4),
                             replace=False).tolist())
            for _ in range(POOL)]
    return tx, y, pool


def _serve_pool(server: CountServer, pool, batch: int):
    results = {}
    for s in range(0, len(pool), batch):
        tickets = [(server.submit(f"c{i % 8}", [key]), key)
                   for i, key in enumerate(pool[s:s + batch])]
        got = server.flush()
        for ticket, key in tickets:
            results[key] = got[ticket][0]
    return results


def run(record: List[dict] | None = None) -> List[Row]:
    import jax.numpy as jnp

    tx, y, pool = _workload()
    ddb = DenseDB.encode(tx, classes=list(y), n_classes=2)
    masks = encode_targets(pool, ddb.vocab)
    ref = np.asarray(itemset_counts(ddb.bits, jnp.asarray(masks),
                                    ddb.weights))
    want = {key: ref[i] for i, key in enumerate(pool)}

    rows: List[Row] = []
    tag = f"serve[N={ROWS},pool={POOL}]"

    # ---- baseline: one kernel launch per query -----------------------------
    masks_d = [jnp.asarray(masks[i:i + 1]) for i in range(POOL)]

    def per_query():
        for m in masks_d:
            np.asarray(itemset_counts(ddb.bits, m, ddb.weights))

    us_base = timeit(per_query, repeats=3, warmup=1) / POOL
    rows.append((f"{tag}/per_query_launch", us_base, "baseline"))
    if record is not None:
        record.append({"variant": "per_query_launch", "batch": 1,
                       "cache": "off", "us_per_query": us_base,
                       "qps": 1e6 / us_base})

    # ---- cold micro-batched serving at several batch sizes -----------------
    us_cold = {}
    for batch in BATCHES:
        server = CountServer(tx, classes=list(y), cache=False)
        got = _serve_pool(server, pool, batch)
        assert all((got[k] == want[k]).all() for k in pool), batch
        us = timeit(lambda: _serve_pool(server, pool, batch),
                    repeats=3, warmup=1) / POOL
        us_cold[batch] = us
        speedup = us_base / us
        rows.append((f"{tag}/batch={batch}(cold)", us,
                     f"speedup_vs_per_query={speedup:.2f}x"))
        if record is not None:
            record.append({"variant": "micro_batched", "batch": batch,
                           "cache": "off", "us_per_query": us,
                           "qps": 1e6 / us,
                           "speedup_vs_per_query": speedup,
                           "beats_per_query": us < us_base})

    # ---- warm cache: repeat queries skip the device ------------------------
    server = CountServer(tx, classes=list(y), cache=True)
    got = _serve_pool(server, pool, WARM_BATCH)   # prime (all misses)
    assert all((got[k] == want[k]).all() for k in pool)
    us_warm = timeit(lambda: _serve_pool(server, pool, WARM_BATCH),
                     repeats=3, warmup=1) / POOL
    got = _serve_pool(server, pool, WARM_BATCH)   # still exact from cache
    assert all((got[k] == want[k]).all() for k in pool)
    warm_speedup = us_cold[WARM_BATCH] / us_warm
    rows.append((f"{tag}/batch={WARM_BATCH}(warm)", us_warm,
                 f"vs_cold={warm_speedup:.1f}x;hit_rate="
                 f"{server.cache.hit_rate:.2f}"))
    if record is not None:
        record.append({"variant": "micro_batched", "batch": WARM_BATCH,
                       "cache": "on", "us_per_query": us_warm,
                       "qps": 1e6 / us_warm,
                       "warm_vs_cold_speedup": warm_speedup,
                       "cache_hit_rate": server.cache.hit_rate})
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json")
    args = ap.parse_args()

    record: List[dict] = []
    rows = run(record)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "problem": {"rows": ROWS, "items": ITEMS, "pool": POOL,
                    "batches": BATCHES},
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
