"""Sharded/async serving benchmark: multi-shard throughput + flush latency.

Two measurements, written together to ``BENCH_shard.json``:

* **throughput** (subprocess, 8 forced host devices): the same micro-batched
  query workload served by the synchronous single-device ``CountServer``
  (the PR-2/PR-3 path) and by sharded stores at 1/2/4/8 shards laid over a
  host mesh (one ``resident_distributed_counts`` psum launch per flush),
  plus the host-loop all-reduce path as a mesh-less reference.  Every
  configuration's answers are asserted bit-identical to the baseline's.

* **async flush latency** (in-process): requests trickled through
  ``submit_async`` against a ``max_delay_ms`` deadline; the recorded
  distribution is the queue wait of each flushed batch's oldest request —
  the quantity the deadline trigger bounds (``latency_bounded`` allows a
  scheduler-jitter margin on top of the budget).

  PYTHONPATH=src python -m benchmarks.shard_serve [--json BENCH_shard.json]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List

from .common import Row

ROWS, ITEMS, POOL = 32768, 48, 256
BATCHES = [16, 64]
SHARDS = [1, 2, 4, 8]
MAX_DELAY_MS = 50.0
JITTER_MARGIN_MS = 25.0

_SUBPROC = r"""
import json, time
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.data import bernoulli_db
from repro.serve import CountServer

ROWS, ITEMS, POOL = %(rows)d, %(items)d, %(pool)d
BATCHES = %(batches)r
SHARDS = %(shards)r

tx, y = bernoulli_db(ROWS, ITEMS, p_x=0.15, p_y=0.05, seed=0)
rng = np.random.default_rng(1)
pool = [tuple(rng.choice(ITEMS, size=rng.integers(1, 4),
                         replace=False).tolist())
        for _ in range(POOL)]


def serve_pool(server, batch):
    results = {}
    for s in range(0, len(pool), batch):
        tickets = [(server.submit(f"c{i %% 8}", [key]), key)
                   for i, key in enumerate(pool[s:s + batch])]
        got = server.flush()
        for ticket, key in tickets:
            results[key] = got[ticket][0]
    return results


def timeit(fn, repeats=3):
    fn()                                     # warmup (compile + place)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


out = []
base_server = CountServer(tx, classes=list(y), cache=False)
want = serve_pool(base_server, BATCHES[0])
base_us = {}
for batch in BATCHES:
    us = timeit(lambda: serve_pool(base_server, batch)) / POOL
    base_us[batch] = us
    out.append({"variant": "single_device", "shards": None, "batch": batch,
                "us_per_query": us, "qps": 1e6 / us})

for n_shards in SHARDS:
    mesh = jax.make_mesh((n_shards,), ("data",),
                         devices=jax.devices()[:n_shards])
    server = CountServer(tx, classes=list(y), cache=False,
                         shards=n_shards, mesh=mesh)
    got = serve_pool(server, BATCHES[0])
    assert all((got[k] == want[k]).all() for k in pool), n_shards
    for batch in BATCHES:
        us = timeit(lambda: serve_pool(server, batch)) / POOL
        out.append({"variant": "sharded_mesh", "shards": n_shards,
                    "batch": batch, "us_per_query": us, "qps": 1e6 / us,
                    "speedup_vs_single": base_us[batch] / us,
                    "beats_single_device": us <= base_us[batch]})

# host-loop all-reduce (no mesh): the portable path, one launch per shard
server = CountServer(tx, classes=list(y), cache=False, shards=2)
got = serve_pool(server, BATCHES[0])
assert all((got[k] == want[k]).all() for k in pool)
us = timeit(lambda: serve_pool(server, BATCHES[-1])) / POOL
out.append({"variant": "sharded_host_loop", "shards": 2,
            "batch": BATCHES[-1], "us_per_query": us, "qps": 1e6 / us,
            "speedup_vs_single": base_us[BATCHES[-1]] / us})
print(json.dumps(out))
"""


def _throughput_records() -> List[dict]:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    script = _SUBPROC % {"rows": ROWS, "items": ITEMS, "pool": POOL,
                         "batches": BATCHES, "shards": SHARDS}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _latency_record() -> dict:
    import numpy as np

    from repro.data import bernoulli_db
    from repro.serve import CountServer

    tx, y = bernoulli_db(4096, 24, p_x=0.15, p_y=0.05, seed=2)
    rng = np.random.default_rng(3)
    server = CountServer(tx, classes=list(y), async_flush=True,
                         max_delay_ms=MAX_DELAY_MS, min_batch=8)
    futures = []
    for i in range(48):
        key = tuple(rng.choice(24, size=2, replace=False).tolist())
        futures.append(server.submit_async(f"c{i % 4}", [key]))
        time.sleep(0.005)            # a trickle: deadline does the flushing
    for fut in futures:
        fut.result(timeout=30)
    server.close()
    stats = server.stats()["async"]
    lat = stats["flush_latency_ms"]
    return {"variant": "async_flush", "max_delay_ms": MAX_DELAY_MS,
            "min_batch": 8, "flushes": stats["flushes"],
            "by_trigger": stats["by_trigger"],
            "flush_latency_ms": lat,
            "latency_bounded":
                lat["max"] is not None
                and lat["max"] <= MAX_DELAY_MS + JITTER_MARGIN_MS}


def run(record: List[dict] | None = None) -> List[Row]:
    rows: List[Row] = []
    tag = f"shard[N={ROWS},pool={POOL}]"
    for rec in _throughput_records():
        if record is not None:
            record.append(rec)
        name = (f"{tag}/{rec['variant']}"
                + (f"(shards={rec['shards']})" if rec["shards"] else "")
                + f"/batch={rec['batch']}")
        derived = (f"speedup_vs_single={rec['speedup_vs_single']:.2f}x"
                   if "speedup_vs_single" in rec else "baseline")
        rows.append((name, rec["us_per_query"], derived))
    lat = _latency_record()
    if record is not None:
        record.append(lat)
    d = lat["flush_latency_ms"]
    rows.append((f"{tag}/async_flush", d["p50"] or 0.0,
                 f"p95={d['p95']:.1f}ms;max={d['max']:.1f}ms;"
                 f"bounded={lat['latency_bounded']}"))
    return rows


def main() -> None:
    import argparse

    import jax

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_shard.json")
    args = ap.parse_args()

    record: List[dict] = []
    rows = run(record)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    payload = {
        "bench": "shard_serve",
        "backend": jax.default_backend(),
        "problem": {"rows": ROWS, "items": ITEMS, "pool": POOL,
                    "batches": BATCHES, "shards": SHARDS,
                    "max_delay_ms": MAX_DELAY_MS},
        "rows": record,
    }
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.json} ({len(record)} records)")


if __name__ == "__main__":
    main()
